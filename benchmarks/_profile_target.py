"""Profile driver: the sched_scale 100k workload alone (no seed leg).

    PYTHONPATH=src:. python -m repro.profile benchmarks/_profile_target.py

Used to produce the pre/post hot-spot tables for the scale work
(docs/scale.md); takes --n and --trace like run_workload.
"""
import argparse

from benchmarks.sched_scale import run_workload

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--trace", action="store_true")
    args = ap.parse_args()
    _log, stats, elapsed = run_workload(args.n, trace=args.trace)
    print(f"n={args.n} elapsed={elapsed:.2f}s makespan={stats['makespan']:.1f}")
