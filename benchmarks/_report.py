"""Shared BENCH_*.json writer: one envelope for every benchmark.

Each benchmark used to hand-roll its result dictionary, so the JSONs had
nothing in common beyond being JSON. ``write_report`` keeps every
benchmark's existing **headline keys at the top level** (dashboards and
the CI asserts read those) and adds a uniform ``"_envelope"`` block::

    {
      "bursty": {...},                  # headline keys, unchanged
      "_envelope": {
        "schema": 1,
        "bench": "interference",
        "seed": 1234,                   # or null
        "config": {...},                # the knobs the run used
        "wait_states": {...}            # obs attribution rollup (or null)
      }
    }

``wait_states`` is the :meth:`repro.obs.TraceRecorder.wait_state_summary`
rollup when the benchmark ran traced (see docs/observability.md), else
None — presence of the key is uniform so consumers need no schema probe.
"""
from __future__ import annotations

import json

SCHEMA_VERSION = 1


def make_report(headline: dict, *, bench: str, seed=None, config=None,
                wait_states=None) -> dict:
    """Headline keys stay top-level; the envelope rides under
    ``"_envelope"`` (underscore-prefixed so it sorts apart and can never
    collide with a real metric name)."""
    if "_envelope" in headline:
        raise ValueError("headline dict already carries an _envelope key")
    out = dict(headline)
    out["_envelope"] = {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "seed": seed,
        "config": config or {},
        "wait_states": wait_states,
    }
    return out


def write_report(path: str, headline: dict, *, bench: str, seed=None,
                 config=None, wait_states=None) -> dict:
    report = make_report(headline, bench=bench, seed=seed, config=config,
                         wait_states=wait_states)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report
