"""Shared BENCH_*.json writer: one envelope for every benchmark.

Each benchmark used to hand-roll its result dictionary, so the JSONs had
nothing in common beyond being JSON. ``write_report`` keeps every
benchmark's existing **headline keys at the top level** (dashboards and
the CI asserts read those) and adds a uniform ``"_envelope"`` block::

    {
      "bursty": {...},                  # headline keys, unchanged
      "_envelope": {
        "schema": 1,
        "bench": "interference",
        "seed": 1234,                   # or null
        "config": {...},                # the knobs the run used
        "wait_states": {...}            # obs attribution rollup (or null)
      }
    }

``wait_states`` is the :meth:`repro.obs.TraceRecorder.wait_state_summary`
rollup when the benchmark ran traced (see docs/observability.md), else
None — presence of the key is uniform so consumers need no schema probe.

Bench trajectory
----------------
``write_report(..., headline_metric=(name, value, direction))``
additionally appends one JSONL line to ``BENCH_history.jsonl`` (next to
the report, or ``history_path=``) keyed by bench / seed / git sha, so
successive runs build a metric trajectory.
``python -m benchmarks.run --check-regress`` (:func:`check_regress`)
compares each (bench, metric)'s latest value against the median of its
recorded priors and flags a >15% regression — ``direction`` says which
way is worse (``"min"``: lower is better, a rise regresses; ``"max"``:
higher is better, a drop regresses).
"""
from __future__ import annotations

import json
import os

SCHEMA_VERSION = 1


def _git_sha():
    try:
        import subprocess
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None
    except Exception:  # noqa: BLE001 — history works outside a checkout
        return None


def append_history(history_path: str, *, bench: str, metric: str,
                   value: float, direction: str = "min",
                   seed=None) -> dict:
    """Append one trajectory entry (JSONL: append-mode, no rewrite)."""
    if direction not in ("min", "max"):
        raise ValueError(f"direction must be 'min' or 'max', "
                         f"got {direction!r}")
    entry = {"bench": bench, "seed": seed, "git": _git_sha(),
             "metric": metric, "value": float(value),
             "direction": direction}
    with open(history_path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def read_history(history_path: str) -> list:
    """Parse a trajectory file; unparsable lines are skipped (a killed
    writer can leave a torn last line)."""
    entries = []
    if not os.path.exists(history_path):
        return entries
    with open(history_path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except ValueError:
                continue
    return entries


def check_regress(history_path: str, threshold: float = 0.15) -> list:
    """Flag every (bench, metric) whose latest value regresses more than
    ``threshold`` (fractional) against the median of its prior entries.
    Returns a list of finding dicts; groups with fewer than 2 entries are
    skipped (no trajectory to regress against)."""
    groups: dict[tuple, list] = {}
    for e in read_history(history_path):
        if not isinstance(e, dict) or "value" not in e:
            continue
        groups.setdefault((e.get("bench"), e.get("metric")), []).append(e)
    findings = []
    for (bench, metric), entries in sorted(groups.items()):
        if len(entries) < 2:
            continue
        latest = entries[-1]
        priors = sorted(e["value"] for e in entries[:-1])
        n = len(priors)
        baseline = priors[n // 2] if n % 2 \
            else 0.5 * (priors[n // 2 - 1] + priors[n // 2])
        direction = latest.get("direction", "min")
        value = latest["value"]
        if direction == "max":
            regressed = value < baseline * (1.0 - threshold)
        else:
            regressed = value > baseline * (1.0 + threshold)
        findings.append({
            "bench": bench, "metric": metric, "value": value,
            "baseline": baseline, "direction": direction,
            "n_prior": n, "regressed": regressed,
            "git": latest.get("git"),
        })
    return findings


def make_report(headline: dict, *, bench: str, seed=None, config=None,
                wait_states=None) -> dict:
    """Headline keys stay top-level; the envelope rides under
    ``"_envelope"`` (underscore-prefixed so it sorts apart and can never
    collide with a real metric name)."""
    if "_envelope" in headline:
        raise ValueError("headline dict already carries an _envelope key")
    out = dict(headline)
    out["_envelope"] = {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "seed": seed,
        "config": config or {},
        "wait_states": wait_states,
    }
    return out


def write_report(path: str, headline: dict, *, bench: str, seed=None,
                 config=None, wait_states=None, headline_metric=None,
                 history_path=None) -> dict:
    report = make_report(headline, bench=bench, seed=seed, config=config,
                         wait_states=wait_states)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    if headline_metric is not None:
        name, value, direction = headline_metric
        if history_path is None:
            history_path = os.path.join(
                os.path.dirname(os.path.abspath(path)),
                "BENCH_history.jsonl")
        append_history(history_path, bench=bench, metric=name,
                       value=value, direction=direction, seed=seed)
    return report
