"""Frozen copy of the seed scheduler + simulator (pre-optimisation).

This is the golden reference for ``benchmarks/sched_scale.py`` and
``tests/test_sched_scale.py``: the rewritten O(log n) hot path in
``repro.core.scheduler`` / ``repro.core.backends`` must produce bit-identical
``launch_log`` and ``stats()`` on the same workload. Keep this file verbatim —
it intentionally preserves the original O(ready^2) ``schedule_pass`` and the
O(running) ``_next_event_time`` scan so the speedup can be measured against
the real seed behaviour.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.core.autotune import AutoTuner
from repro.core.backends import SimBackend
from repro.core.constraints import AutoSpec, StaticSpec, is_auto
from repro.core.resources import Cluster, WorkerNode
from repro.core.scheduler import SchedulerError
from repro.core.storage_model import per_task_rate
from repro.core.task import Future, TaskInstance, TaskState, TaskType

_EPS = 1e-9


class SeedScheduler:
    """Verbatim seed ``Scheduler``: O(ready) rescan per placement round."""

    def __init__(self, cluster: Cluster,
                 launch: Callable[[TaskInstance, WorkerNode], None]):
        self.cluster = cluster
        self._launch = launch
        self.ready: list[TaskInstance] = []
        self.running: set[int] = set()
        self.tuners: dict[str, AutoTuner] = {}
        self.learning_nodes: dict[str, WorkerNode] = {}
        self.completed: list[TaskInstance] = []
        self.launch_log: list[tuple[float, str, str]] = []  # (tid, sig, worker)

    # ------------------------------------------------------------------ utils
    def tuner_for(self, task: TaskInstance) -> AutoTuner:
        sig = task.defn.signature
        if sig not in self.tuners:
            spec = task.storage_bw
            assert isinstance(spec, AutoSpec)
            # the device model the tuner reasons about: the (first) device its
            # tasks will run on. Homogeneous devices assumed per signature.
            w = self.cluster.workers[0]
            self.tuners[sig] = AutoTuner(
                sig, spec, device_bw=w.storage.bandwidth,
                io_executors=w.io_executors)
        return self.tuners[sig]

    def _acquire_learning_node(self, sig: str) -> Optional[WorkerNode]:
        node = self.learning_nodes.get(sig)
        if node is not None:
            return node
        for w in self.cluster.workers:
            if w.learning_owner is None:
                w.learning_owner = sig
                self.learning_nodes[sig] = w
                return w
        return None  # all nodes busy learning other signatures: wait

    def _release_learning_node(self, sig: str) -> None:
        node = self.learning_nodes.pop(sig, None)
        if node is not None:
            node.learning_owner = None

    def n_ready_of(self, sig: str) -> int:
        return sum(1 for t in self.ready if t.defn.signature == sig)

    @property
    def n_ready(self) -> int:
        return len(self.ready)

    # -------------------------------------------------------------- submission
    def make_ready(self, task: TaskInstance) -> None:
        self.ready.append(task)

    def make_ready_many(self, tasks) -> None:
        for t in tasks:
            self.ready.append(t)

    # -------------------------------------------------------------- scheduling
    def schedule_pass(self) -> int:
        """Try to place every ready task; returns number launched."""
        launched = 0
        progress = True
        while progress:
            progress = False
            for task in list(self.ready):
                if self._try_place(task):
                    self.ready.remove(task)
                    launched += 1
                    progress = True
        return launched

    def _try_place(self, task: TaskInstance) -> bool:
        if task.defn.task_type == TaskType.COMPUTE:
            return self._place_compute(task)
        return self._place_io(task)

    def _place_compute(self, task: TaskInstance) -> bool:
        cu = task.defn.computing_units
        for w in self.cluster.workers:
            if w.free_cpus >= cu:
                w.free_cpus -= cu
                self._start(task, w, bw=0.0)
                return True
        return False

    def _place_io(self, task: TaskInstance) -> bool:
        spec = task.storage_bw
        if is_auto(spec):
            return self._place_auto_io(task)
        bw = spec.value if isinstance(spec, StaticSpec) else 0.0
        # sanity: an unsatisfiable static constraint is a config error
        if bw > 0 and all(w.storage.bandwidth < bw for w in self.cluster.workers):
            raise SchedulerError(
                f"storageBW={bw} exceeds every device's bandwidth")
        for w in self._io_candidates(task):
            if w.learning_owner is not None:
                continue  # active-learning node: keep it isolated
            if w.free_io_executors <= 0:
                continue
            if bw > 0 and not w.storage.can_allocate(bw):
                continue
            w.free_io_executors -= 1
            if bw >= 0:
                w.storage.allocate(bw)
            self._start(task, w, bw=bw)
            return True
        return False

    def _place_auto_io(self, task: TaskInstance) -> bool:
        tuner = self.tuner_for(task)
        sig = task.defn.signature
        if tuner.learning():
            node = self._acquire_learning_node(sig)
            if node is None:
                return False
            c = tuner.current_constraint()
            if node.free_io_executors <= 0 or not node.storage.can_allocate(c):
                return False
            if not tuner.admit():
                return False  # current epoch full; wait for the next one
            node.free_io_executors -= 1
            node.storage.allocate(c)
            task.epoch = tuner.epoch
            self._start(task, node, bw=c)
            return True
        # learning done: objective fn, re-evaluated for the current backlog
        n = self.n_ready_of(sig)
        c = tuner.choose(max(1, n))
        for w in self._io_candidates(task):
            if w.learning_owner is not None:
                continue
            if w.free_io_executors <= 0 or not w.storage.can_allocate(c):
                continue
            w.free_io_executors -= 1
            w.storage.allocate(c)
            self._start(task, w, bw=c)
            return True
        return False

    def _io_candidates(self, task: TaskInstance):
        # shared working directory -> first candidate node (paper §4.2.1);
        # otherwise honour data locality (inputs' producing workers first).
        if self.cluster.shared_workdir:
            return self.cluster.workers
        pref = []
        for a in list(task.args) + list(task.kwargs.values()):
            if isinstance(a, Future) and a.task.worker is not None:
                pref.append(a.task.worker)
        rest = [w for w in self.cluster.workers if w not in pref]
        return pref + rest

    def _start(self, task: TaskInstance, worker: WorkerNode, bw: float) -> None:
        task.worker = worker
        task.granted_bw = bw
        task.state = TaskState.RUNNING
        self.running.add(task.tid)
        self.launch_log.append((task.tid, task.defn.signature, worker.name))
        self._launch(task, worker)

    # -------------------------------------------------------------- completion
    def on_complete(self, task: TaskInstance) -> None:
        """Release resources + autotune bookkeeping. The backend/runtime is
        responsible for graph completion and follow-up scheduling."""
        self.running.discard(task.tid)
        w = task.worker
        if task.defn.task_type == TaskType.COMPUTE:
            w.free_cpus += task.defn.computing_units
        else:
            w.free_io_executors += 1
            w.storage.release(task.granted_bw)
        if task.epoch is not None:
            tuner = self.tuners[task.defn.signature]
            tuner.on_task_complete(task.duration)
            if not tuner.learning():
                self._release_learning_node(task.defn.signature)
        self.completed.append(task)

    def end_of_stream(self) -> None:
        """Signal that no more tasks will be submitted (final barrier):
        lets partially-filled learning epochs conclude."""
        for sig, tuner in self.tuners.items():
            if tuner.learning():
                tuner.end_of_stream()
                if not tuner.learning():
                    self._release_learning_node(sig)

    # ---------------------------------------------------------------- sanity
    def assert_not_stuck(self) -> None:
        if self.ready and not self.running:
            # one legitimate transient: an auto task waiting for a learning
            # node held by a tuner whose epoch is waiting for more arrivals.
            self.end_of_stream()
            if self.schedule_pass() == 0 and self.ready and not self.running:
                names = [t.defn.name for t in self.ready[:5]]
                raise SchedulerError(
                    f"scheduler stuck: {len(self.ready)} ready tasks "
                    f"(e.g. {names}) but nothing running/placeable")


class SeedSimBackend(SimBackend):
    """Verbatim seed ``SimBackend``: linear scans per event.

    Subclasses the production SimBackend only so ``IORuntime.stats`` keeps
    emitting the sim fields; every method is overridden with the seed body.
    ``deadline`` (wall-clock seconds) optionally aborts a too-slow run so the
    scale benchmark can bound the quadratic baseline.
    """

    def __init__(self, deadline: float | None = None):
        self.clock = 0.0
        self._compute: dict[int, tuple[TaskInstance, float]] = {}
        self._io: dict[int, list] = {}  # tid -> [task, remaining_mb, min_end]
        self.io_busy_time = 0.0
        self.compute_busy_time = 0.0
        self.overlap_time = 0.0
        self.total_io_mb = 0.0
        self.peak_io_mbs = 0.0
        self._deadline = deadline
        self._t0 = time.monotonic()

    def now(self) -> float:
        return self.clock

    def launch(self, task: TaskInstance, worker) -> None:
        task.start_time = self.clock
        if task.defn.task_type == TaskType.COMPUTE:
            self._compute[task.tid] = (task, self.clock + max(task.sim.duration, _EPS))
        else:
            rem = max(task.sim.io_bytes, 0.0)
            min_end = self.clock + max(task.sim.duration, _EPS)
            self._io[task.tid] = [task, rem, min_end]

    def _next_event_time(self) -> float:
        t = float("inf")
        for _, end in self._compute.values():
            t = min(t, end)
        for task, rem, min_end in self._io.values():
            dev = task.worker.storage
            rate = per_task_rate(dev, dev.active_io)
            eta = self.clock + rem / rate if rate > 0 else float("inf")
            t = min(t, max(eta, min_end))
        return t

    def _advance_to(self, t: float) -> None:
        dt = t - self.clock
        if dt <= 0:
            self.clock = t
            return
        io_active = bool(self._io)
        comp_active = bool(self._compute)
        if io_active:
            self.io_busy_time += dt
        if comp_active:
            self.compute_busy_time += dt
        if io_active and comp_active:
            self.overlap_time += dt
        interval_mb = 0.0
        for rec in self._io.values():
            task, rem, _ = rec
            dev = task.worker.storage
            rate = per_task_rate(dev, dev.active_io)
            moved = min(rem, rate * dt)
            rec[1] = rem - moved
            dev.bytes_written += moved
            self.total_io_mb += moved
            interval_mb += moved
        if dt > 1e-6 and interval_mb > 0:
            self.peak_io_mbs = max(self.peak_io_mbs, interval_mb / dt)
        self.clock = t

    def _pop_due(self) -> list[TaskInstance]:
        due = []
        for tid in list(self._compute):
            task, end = self._compute[tid]
            if end <= self.clock + _EPS:
                del self._compute[tid]
                due.append(task)
        for tid in list(self._io):
            task, rem, min_end = self._io[tid]
            if rem <= 1e-6 and min_end <= self.clock + _EPS:
                del self._io[tid]
                due.append(task)
        return due

    def drain(self, predicate: Callable[[], bool]) -> None:
        rt = self.runtime
        while True:
            if self._deadline is not None and \
                    time.monotonic() - self._t0 > self._deadline:
                raise TimeoutError("seed simulation exceeded deadline")
            rt.scheduler.schedule_pass()
            if predicate():
                return
            if not self._compute and not self._io:
                if rt.scheduler.ready:
                    rt.scheduler.assert_not_stuck()
                    continue
                if predicate():
                    return
                raise SchedulerError(
                    f"simulation drained but predicate unmet "
                    f"(unfinished={rt.graph.unfinished})")
            t = self._next_event_time()
            if t == float("inf"):
                raise SchedulerError("no next event with tasks running")
            self._advance_to(t)
            for task in self._pop_due():
                task.end_time = self.clock
                for f in task.futures:
                    f.set_value(None)
                rt._handle_completion(task)
