"""Analytic per-device FLOPs / HBM-byte model for the roofline
(EXPERIMENTS.md §Roofline methodology).

Why analytic: XLA's compiled cost_analysis counts while-loop bodies ONCE
(verified by probe — see EXPERIMENTS.md), so scanned-layer programs
under-report by ~L x. The analytic model uses the 2*MACs convention to stay
comparable with XLA, counts remat recompute for train, and is validated
against XLA-counted FLOPs on small UNROLLED configs (tests/test_roofline.py).

All numbers are GLOBAL; divide by n_devices for per-device terms (ideal
sharding; redundant compute from replicated-weight fallbacks shows up as a
discrepancy against the dry-run and is discussed in §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.layers import pad_vocab

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link (conservative: 1 link)


def _attn_ctx(cfg: ModelConfig, S: int) -> int:
    return min(S, cfg.sliding_window) if cfg.sliding_window else S


def _dense_layer_macs_per_tok(cfg) -> float:
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    attn = cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
        + cfg.n_heads * hd * cfg.d_model
    if cfg.n_experts:
        ffn = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts_per_tok * 1.25 \
            + 3 * cfg.d_model * cfg.shared_d_ff + cfg.d_model * cfg.n_experts
    else:
        ffn = 3 * cfg.d_model * cfg.d_ff
    return attn + ffn


def _mamba_layer_macs_per_tok(cfg) -> float:
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    H = d_in // cfg.ssm_headdim
    N = cfg.ssm_state
    proj = D * (2 * d_in + 2 * N + H) + d_in * D
    conv = 4 * (d_in + 2 * N)
    Q = cfg.ssm_chunk
    # SSD per token: cb Q*N, intra Q*d_in, state build/apply ~ 2*N*d_in
    ssd = Q * N + Q * d_in + 2 * N * d_in
    return proj + conv + ssd


def _score_macs(cfg, S: int, n_heads=None) -> float:
    """Attention score+pv MACs per sequence (full, mask not exploited —
    matches the chunked-XLA and flash-without-block-skip lowerings)."""
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    H = n_heads or cfg.n_heads
    ctx = _attn_ctx(cfg, S)
    return 2 * S * ctx * H * hd


@dataclass
class CellCost:
    flops: float          # global, 2*MACs convention, incl. remat
    hbm_bytes: float      # global
    model_flops: float    # 6*N_active*D-style "useful" flops


def _param_bytes(cfg) -> float:
    return cfg.param_count() * 2.0  # bf16


def cell_cost(cfg: ModelConfig, cell: ShapeCell) -> CellCost:
    B, S = cell.global_batch, cell.seq_len
    D = cfg.d_model
    vpad = pad_vocab(cfg.vocab_size)
    L = cfg.n_layers

    if cell.kind in ("train", "prefill"):
        T = B * S
        if cfg.family in ("ssm",):
            layer = _mamba_layer_macs_per_tok(cfg) * T * L
            score = 0.0
        elif cfg.family == "hybrid":
            n_sites = len(range(0, L, cfg.attn_every))
            mam = _mamba_layer_macs_per_tok(cfg) * T * L
            hd = D // cfg.n_heads
            attn_tok = 2 * D * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
                + cfg.n_heads * hd * D + 3 * D * cfg.d_ff
            layer = mam + attn_tok * T * n_sites
            score = _score_macs(cfg, S) * B * n_sites
        else:
            layer = _dense_layer_macs_per_tok(cfg) * T * L
            score = _score_macs(cfg, S) * B * L
        # train: logits over all T positions; prefill: last token only
        head = (T if cell.kind == "train" else B) * D * vpad
        fwd = layer + score + head
        if cell.kind == "train":
            mult = 3.0 + (1.0 if cfg.remat else 0.0)   # fwd + bwd(2x) + remat
            flops = 2.0 * fwd * mult
            model = 6.0 * cfg.active_param_count() * T
        else:
            flops = 2.0 * fwd
            model = 2.0 * cfg.active_param_count() * T
        # HBM: params (x reads), opt state, saved activations, logits
        pb = _param_bytes(cfg)
        if cell.kind == "train":
            hbm = pb * 3                       # fwd read, bwd read, remat read
            hbm += cfg.param_count() * (8 + 8 + 4 + 4 + 2)  # m,v rw, grad rw, p w
            hbm += L * T * D * 2 * 2           # saved layer inputs w+r
            hbm += T * vpad * 4 * 2            # logits + softmax pass
            hbm += 2 * T * D * 2 * L           # layer io streams
        else:
            hbm = pb + 2 * T * D * 2 * L + T * vpad * 4 \
                + (B * _attn_ctx(cfg, S) * cfg.n_kv_heads *
                   (cfg.head_dim or D // max(cfg.n_heads, 1)) * 2 * 2 * L
                   if cfg.n_heads else 0)
        return CellCost(flops, hbm, model)

    # decode: one step, B tokens
    ctx = _attn_ctx(cfg, S)
    hd = (cfg.head_dim or D // cfg.n_heads) if cfg.n_heads else 0
    if cfg.family == "ssm":
        per_tok = _mamba_layer_macs_per_tok(cfg)
        d_in = cfg.ssm_expand * D
        per_tok += 2 * cfg.ssm_state * d_in    # state update+read dominate
        macs = per_tok * B * L
        kv_bytes = L * B * (d_in // cfg.ssm_headdim) * cfg.ssm_state \
            * cfg.ssm_headdim * 4 * 2
    elif cfg.family == "hybrid":
        n_sites = len(range(0, L, cfg.attn_every))
        macs = _mamba_layer_macs_per_tok(cfg) * B * L
        attn_tok = 2 * D * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
            + cfg.n_heads * hd * D + 3 * D * cfg.d_ff
        macs += (attn_tok + 2 * ctx * cfg.n_heads * hd) * B * n_sites
        d_in = cfg.ssm_expand * D
        kv_bytes = n_sites * B * ctx * cfg.n_kv_heads * hd * 2 * 2 \
            + L * B * (d_in // cfg.ssm_headdim) * cfg.ssm_state \
            * cfg.ssm_headdim * 4 * 2
    else:
        macs = _dense_layer_macs_per_tok(cfg) * B * L
        macs += 2 * ctx * cfg.n_heads * hd * B * L
        kv_bytes = L * B * ctx * cfg.n_kv_heads * hd * 2 * 2
    macs += B * D * vpad
    flops = 2.0 * macs
    model = 2.0 * cfg.active_param_count() * B
    hbm = _param_bytes(cfg) + kv_bytes + B * vpad * 4
    return CellCost(flops, hbm, model)


def roofline_terms(cfg: ModelConfig, cell: ShapeCell, n_devices: int,
                   collective_bytes_per_dev: float) -> dict:
    c = cell_cost(cfg, cell)
    t_comp = c.flops / n_devices / PEAK_FLOPS
    t_mem = c.hbm_bytes / n_devices / HBM_BW
    t_coll = collective_bytes_per_dev / LINK_BW
    dom = max((("compute", t_comp), ("memory", t_mem),
               ("collective", t_coll)), key=lambda kv: kv[1])
    total = max(t_comp, t_mem, t_coll)
    return {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom[0],
        "model_flops": c.model_flops, "hlo_flops": c.flops,
        "useful_ratio": c.model_flops / c.flops if c.flops else 0.0,
        "roofline_fraction": (c.model_flops / n_devices / PEAK_FLOPS) / total
        if total else 0.0,
    }
