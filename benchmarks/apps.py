"""The paper's three evaluation applications (§5.2) as task graphs on the
I/O-aware runtime, with real task-graph structure and the calibrated
MareNostrum-4 storage model (DESIGN.md §4).

Workload knobs the paper does not report (compute-task durations) are
module-level constants; EXPERIMENTS.md reports two HMMER calibrations
(gain-focused and ordering-focused) and documents the tradeoff.
"""
from __future__ import annotations

from repro.core import (Cluster, IORuntime, SimBackend, constraint,
                        expected_task_time, io, task)

# ---------------------------------------------------------------------------
# HMMER (homogeneous I/O: one checkpoint class, 290 MB each; paper §5.2.1)
# ---------------------------------------------------------------------------
HMMER_TASKS = 2304           # 48 db fragments x 48 seq fragments
HMMER_CKPT_MB = 290.0
HMMER_DUR_GAIN = 30.0        # calibration A: reproduces the ~38% static gain
HMMER_DUR_ORDER = 200.0      # calibration B: reproduces all bar orderings


def run_hmmer(mode: str, bw=None, *, n=HMMER_TASKS, dur=HMMER_DUR_ORDER,
              mb=HMMER_CKPT_MB, io_executors=225, n_workers=12) -> dict:
    """mode: baseline | io (non-constrained) | constrained (bw=static or
    'auto'/'auto(min,max,delta)')."""
    cluster = Cluster.make(n_workers=n_workers, io_executors=io_executors)
    dev = cluster.workers[0].storage

    @task(returns=1)
    def hmmpfam(frag):
        pass

    if mode == "baseline":
        @task()
        def checkpointFrag(res, i):
            pass
    elif mode == "io":
        @io
        @task()
        def checkpointFrag(res, i):
            pass
    else:
        @constraint(storageBW=bw)
        @io
        @task()
        def checkpointFrag(res, i):
            pass

    with IORuntime(cluster, backend=SimBackend()) as rt:
        for i in range(n):
            r = hmmpfam(i, duration=dur)
            if mode == "baseline":
                # I/O inside a compute task: 48 concurrent streams per node
                checkpointFrag(r, i, duration=expected_task_time(dev, 48, mb))
            else:
                checkpointFrag(r, i, io_mb=mb)
        rt.barrier(final=True)
        return rt.stats()


# ---------------------------------------------------------------------------
# Variants Discovery Pipeline (heterogeneous I/O: 5 checkpoint classes,
# paper §5.2.2 Table 1)
# ---------------------------------------------------------------------------
VARIANTS_PIPELINES = 1728
VARIANTS_CKPT_MB = {          # Table 1
    "checkpoint_fastq": 162.0,
    "checkpoint_mapped": 290.0,   # used twice: bwa_map and sort
    "checkpoint_merged": 330.0,
    "checkpoint_marked": 596.0,
    "checkpoint_grouped": 615.0,
}
VARIANTS_DUR_GAIN = 75.0     # calibration A: ~36% static gain (paper: 43%)
VARIANTS_DUR_ORDER = 300.0   # calibration B: autos beat baseline (real bwa/
#                              GATK stages run tens of minutes, hiding the
#                              strict-confinement learning epochs)
VARIANTS_STAGE_DUR = VARIANTS_DUR_GAIN


def run_variants(mode: str, bw=None, *, n=VARIANTS_PIPELINES,
                 dur=VARIANTS_STAGE_DUR, io_executors=225,
                 n_workers=12) -> dict:
    # paper §5.2.2: the NON-constrained run uses 325 I/O executors (pass
    # io_executors=325 for mode="io"); constrained/auto runs use 225 as in
    # HMMER (the paper's Fig 22b sweeps the unbounded executor count)
    cluster = Cluster.make(n_workers=n_workers, io_executors=io_executors)
    dev = cluster.workers[0].storage

    @task(returns=1)
    def stage(x):
        pass

    def make_ckpt(name):
        if mode == "baseline":
            @task()
            def ck(res, i):
                pass
        elif mode == "io":
            @io
            @task()
            def ck(res, i):
                pass
        else:
            @constraint(storageBW=bw)
            @io
            @task()
            def ck(res, i):
                pass
        ck.defn.name = name           # distinct signature per class ->
        return ck                     # separate learning phase (paper §4.2.3)

    cks = {name: make_ckpt(name) for name in VARIANTS_CKPT_MB}
    # pipeline: fastq -> map -> sort -> merge -> mark -> group, checkpoints
    # hang off each major step; the last two have no compute to hide behind
    order = ["checkpoint_fastq", "checkpoint_mapped", "checkpoint_mapped",
             "checkpoint_merged", "checkpoint_marked", "checkpoint_grouped"]
    with IORuntime(cluster, backend=SimBackend()) as rt:
        for i in range(n):
            x = i
            for si, cls in enumerate(order):
                x = stage(x, duration=dur)
                mb = VARIANTS_CKPT_MB[cls]
                if mode == "baseline":
                    cks[cls](x, i, duration=expected_task_time(dev, 48, mb))
                else:
                    cks[cls](x, i, io_mb=mb)
        rt.barrier(final=True)
        return rt.stats()


# ---------------------------------------------------------------------------
# Kmeans (iterative; learning-phase amortisation; paper §5.2.3)
# ---------------------------------------------------------------------------
KMEANS_FRAGMENTS = 500
KMEANS_CKPT_MB = 109.0
KMEANS_PS_DUR = 45.0
KMEANS_GEN_DUR = 10.0
KMEANS_RED_DUR = 5.0


def run_kmeans(mode: str, bw=None, *, iterations=1, frags=KMEANS_FRAGMENTS,
               io_executors=225, n_workers=12) -> dict:
    cluster = Cluster.make(n_workers=n_workers, io_executors=io_executors)
    dev = cluster.workers[0].storage

    @task(returns=1)
    def generate_fragment(i):
        pass

    @task(returns=1)
    def partial_sum(frag, centers):
        pass

    @task(returns=1)
    def reduce_centers(partials):
        pass

    if mode == "baseline":
        @task()
        def checkpointCenters(c, i):
            pass
    elif mode == "io":
        @io
        @task()
        def checkpointCenters(c, i):
            pass
    else:
        @constraint(storageBW=bw)
        @io
        @task()
        def checkpointCenters(c, i):
            pass

    with IORuntime(cluster, backend=SimBackend()) as rt:
        frs = [generate_fragment(i, duration=KMEANS_GEN_DUR)
               for i in range(frags)]
        centers = None
        for it in range(iterations):
            parts = [partial_sum(f, centers, duration=KMEANS_PS_DUR)
                     for f in frs]
            centers = reduce_centers(parts, duration=KMEANS_RED_DUR)
            for i in range(frags):
                if mode == "baseline":
                    checkpointCenters(
                        centers, i,
                        duration=expected_task_time(dev, 48, KMEANS_CKPT_MB))
                else:
                    checkpointCenters(centers, i, io_mb=KMEANS_CKPT_MB)
        rt.barrier(final=True)
        return rt.stats()
