"""Capacity-aware data lifecycle benchmark (ISSUE 3 tentpole evidence).

Two scenarios, one JSON (``BENCH_capacity.json``):

**Eviction** — a checkpointing step chain whose total written data is far
larger than the node-local SSD. Three variants write the same bytes:

* ``no_fast`` — no fast tier at all: every shard goes straight to the
  congested shared FS (the classic un-tiered baseline).
* ``naive_overflow`` — SSD with a finite ``capacity_gb`` but **no
  eviction**: the first steps absorb at SSD speed, then the tier is full
  forever and every later shard spills to the FS foreground path.
* ``evicting`` — the data lifecycle subsystem drains cold shards (LRU by
  last reader; the gating reader keeps the hot step protected) back to the
  FS in the shadow of compute, so the SSD keeps absorbing every burst.

The eviction variant must beat both baselines on makespan.

**Prefetch** — a CkIO-style data-loading wave: dataset shards are resident
on the shared FS at t0 (``rt.external_data``), and a chain of training
steps each consumes one shard. Without staging, every step pays the FS
read penalty inline. With ``auto_prefetch`` the runtime notices at
submission that each step's input is resident only on a slower tier than
the step's target placement and synthesizes ``rt.prefetch`` staging tasks
that pipeline ahead of the compute wave — at least 50% of the total read
time must be hidden behind compute.

Usage::

    PYTHONPATH=src python -m benchmarks.capacity \
        [--steps 12] [--out BENCH_capacity.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

from repro.core import (Cluster, IORuntime, LifecycleConfig, SimBackend,
                        StorageDevice, WorkerNode, constraint, io, task)
from repro.core.task import TaskInstance

from ._report import write_report

# NVMe-class SSD over a congested parallel FS (the bench's own calibration;
# the paper's fsync-bound numbers live in the figure benchmarks)
SSD_BW, SSD_CAP = 2000.0, 400.0
FS_BW, FS_CAP = 300.0, 50.0


def _reset_ids() -> None:
    TaskInstance._ids = itertools.count()


def two_tier_cluster(n_workers: int = 1, ssd_capacity_gb=None) -> Cluster:
    """Node-local SSD (finite) over a shared parallel FS (durable)."""
    fs = StorageDevice(name="shared-fs", bandwidth=FS_BW,
                       per_stream_cap=FS_CAP, tier="fs")
    workers = []
    for i in range(n_workers):
        ssd = StorageDevice(name=f"w{i}-ssd", bandwidth=SSD_BW,
                            per_stream_cap=SSD_CAP, tier="ssd",
                            capacity_gb=ssd_capacity_gb)
        workers.append(WorkerNode(name=f"w{i}", cpus=8, io_executors=32,
                                  tiers=[ssd, fs]))
    return Cluster(workers=workers)


# ---------------------------------------------------------------- eviction
def run_eviction_variant(mode: str, n_steps: int = 12, n_shards: int = 4,
                         shard_mb: float = 128.0, step_s: float = 2.0,
                         shard_bw: float = 200.0,
                         ssd_capacity_gb: float = 1.0) -> dict:
    """One variant of the working-set-larger-than-SSD scenario."""
    _reset_ids()
    if mode == "no_fast":
        cluster = Cluster.make(n_workers=1, cpus=8, io_executors=32,
                               device_bw=FS_BW, per_stream_cap=FS_CAP,
                               shared_storage=True)
        cfg = LifecycleConfig(enabled=True, auto_prefetch=False,
                              auto_evict=False)
    else:
        cluster = two_tier_cluster(ssd_capacity_gb=ssd_capacity_gb)
        cfg = LifecycleConfig(auto_prefetch=False,
                              auto_evict=(mode == "evicting"))

    t0 = time.perf_counter()
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        @task(returns=1)
        def step(prev, gate, i):
            pass

        @constraint(storageBW=shard_bw)
        @io
        @task(returns=1)
        def write_shard(x, i, j):
            pass

        prev, gate = None, None
        for i in range(n_steps):
            prev = step(prev, gate, i, duration=step_s)
            # snapshot-buffer reuse: the next step gates on this step's
            # shards having been absorbed by storage — and, as the shards'
            # scheduled reader, protects them from eviction until it runs
            gate = [write_shard(prev, i, j, io_mb=shard_mb)
                    for j in range(n_shards)]
        rt.barrier(final=True)
        stats = rt.stats()
    stats["wall_seconds"] = time.perf_counter() - t0
    lc = stats.get("lifecycle", {})
    by_tier = {}
    for d in stats["devices"].values():
        by_tier[d["tier"]] = by_tier.get(d["tier"], 0.0) + d["bytes_written"]
    return {
        "mode": mode,
        "makespan": stats["makespan"],
        "overlap_time": stats["overlap_time"],
        "bytes_by_tier_mb": by_tier,
        "n_evictions": lc.get("n_evictions", 0),
        "bytes_evicted_mb": lc.get("bytes_evicted_mb", 0.0),
        "peak_ssd_occupancy_mb": max(
            (d["peak_occupancy_mb"] for d in stats["devices"].values()
             if d["tier"] == "ssd" and d["capacity_mb"] is not None),
            default=0.0),
        "ssd_capacity_mb": ssd_capacity_gb * 1024.0
        if mode != "no_fast" else None,
    }


def compare_eviction(n_steps: int = 12, **kw) -> dict:
    variants = {m: run_eviction_variant(m, n_steps=n_steps, **kw)
                for m in ("no_fast", "naive_overflow", "evicting")}
    ev = variants["evicting"]["makespan"]
    report = {
        "n_steps": n_steps,
        "variants": variants,
        "speedup_vs_no_fast": variants["no_fast"]["makespan"] / ev,
        "speedup_vs_naive": variants["naive_overflow"]["makespan"] / ev,
        "eviction_beats_no_fast": ev < variants["no_fast"]["makespan"],
        "eviction_beats_naive": ev < variants["naive_overflow"]["makespan"],
    }
    # the SSD budget was honoured at every instant in both finite variants
    for m in ("naive_overflow", "evicting"):
        v = variants[m]
        assert v["peak_ssd_occupancy_mb"] <= v["ssd_capacity_mb"] + 1e-6, v
    return report


# ---------------------------------------------------------------- prefetch
def run_prefetch_variant(auto_prefetch: bool, n_shards: int = 10,
                         shard_mb: float = 300.0, step_s: float = 1.2,
                         ssd_capacity_gb: float = 8.0) -> dict:
    """Data-loading wave: shards resident on fs at t0, a training chain
    consumes one per step."""
    _reset_ids()
    cluster = two_tier_cluster(ssd_capacity_gb=ssd_capacity_gb)
    cfg = LifecycleConfig(auto_prefetch=auto_prefetch)
    t0 = time.perf_counter()
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        shards = [rt.external_data(f"shard{i}", shard_mb, "fs")
                  for i in range(n_shards)]

        @task(returns=1)
        def train(prev, shard, i):
            pass

        prev = None
        for i, s in enumerate(shards):
            prev = train(prev, s, i, duration=step_s)
        rt.barrier(final=True)
        stats = rt.stats()
        read_penalty_total = sum(t.read_penalty
                                 for t in rt.scheduler.completed
                                 if t.defn.name == "train")
    stats["wall_seconds"] = time.perf_counter() - t0
    lc = stats.get("lifecycle", {})
    return {
        "auto_prefetch": auto_prefetch,
        "makespan": stats["makespan"],
        "overlap_time": stats["overlap_time"],
        "compute_time": n_shards * step_s,
        "inline_read_time": read_penalty_total,
        "n_prefetches": lc.get("n_prefetches", 0),
        "bytes_prefetched_mb": lc.get("bytes_prefetched_mb", 0.0),
    }


def compare_prefetch(**kw) -> dict:
    base = run_prefetch_variant(False, **kw)
    pf = run_prefetch_variant(True, **kw)
    # all read time the baseline paid inline, minus what the prefetch run
    # still spends beyond pure compute, was hidden behind the compute wave
    read_total = base["inline_read_time"]
    hidden = base["makespan"] - pf["makespan"]
    overlap_frac = hidden / read_total if read_total > 0 else 0.0
    return {
        "baseline": base,
        "prefetch": pf,
        "read_time_total": read_total,
        "read_time_hidden": hidden,
        "read_overlap_frac": overlap_frac,
        "prefetch_wins": pf["makespan"] < base["makespan"],
        "overlap_at_least_half": overlap_frac >= 0.5,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--out", default="BENCH_capacity.json")
    args = ap.parse_args(argv)
    ev = compare_eviction(n_steps=args.steps)
    pf = compare_prefetch()
    report = {"eviction": ev, "prefetch": pf}
    v = ev["variants"]
    print("eviction scenario (working set >> SSD):")
    for m in ("no_fast", "naive_overflow", "evicting"):
        print(f"  {m:>15}: makespan {v[m]['makespan']:8.2f}s  "
              f"evictions {v[m]['n_evictions']:2d}  "
              f"bytes by tier {v[m]['bytes_by_tier_mb']}")
    print(f"  evicting beats naive-overflow "
          f"{ev['speedup_vs_naive']:.2f}x, no-fast "
          f"{ev['speedup_vs_no_fast']:.2f}x")
    print("prefetch scenario (data-loading wave):")
    print(f"  baseline {pf['baseline']['makespan']:.2f}s -> "
          f"auto-prefetch {pf['prefetch']['makespan']:.2f}s; "
          f"{pf['read_overlap_frac']:.0%} of {pf['read_time_total']:.1f}s "
          f"read time hidden behind compute "
          f"({pf['prefetch']['n_prefetches']} stagings)")
    assert ev["eviction_beats_naive"], "eviction must beat naive overflow"
    assert ev["eviction_beats_no_fast"], "eviction must beat the no-SSD run"
    assert pf["overlap_at_least_half"], \
        f"auto-prefetch must hide >= 50% of read time " \
        f"(got {pf['read_overlap_frac']:.0%})"
    report = write_report(args.out, report, bench="capacity",
                          config={"steps": args.steps})
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
