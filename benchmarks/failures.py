"""Tier failure-domain benchmark (ISSUE 7 tentpole evidence).

One scenario, one JSON (``BENCH_failures.json``): a training-style step
chain on a burst-buffer + shared-FS hierarchy **loses the burst buffer
mid-drain**. Each step writes snapshot shards to the fast tier while the
lifecycle subsystem drains cold shards to the durable FS in the shadow of
compute; at ``t_fail`` a seeded :class:`FailureSchedule` takes every bb
device offline, with shards still resident there and drains in flight.

Two recovery strategies over the identical workload and failure time:

* ``reroute`` — the failure-domain subsystem (failures.py): in-flight I/O
  on the dead tier fails into the bounded-retry path and re-lands on the
  FS, lost residencies are dropped, orphaned shards are re-produced via
  lineage re-runs, and the run keeps going. Must finish with **zero lost
  objects** (every non-ephemeral shard resident on a healthy device).
* ``abort_restart`` — the classic baseline: the failure aborts the job,
  which restarts from scratch on the surviving FS-only cluster. Its cost
  is ``t_fail + makespan(full rerun on fs)``.

Reroute must beat abort-and-restart on makespan. A third check pins the
inert-path guarantee: an **empty** ``FailureSchedule`` produces a launch
log bit-identical to a run with no failure wiring at all.

Usage::

    PYTHONPATH=src python -m benchmarks.failures \
        [--steps 10] [--out BENCH_failures.json]
"""
from __future__ import annotations

import argparse
import itertools
import json
import time

from repro.core import (BurstyTraffic, Cluster, FailureSchedule, IORuntime,
                        LifecycleConfig, SimBackend, StorageDevice,
                        WorkerNode, constraint, io, task)
from repro.core.task import TaskInstance
from repro.obs import perfetto

from ._report import write_report

BB_BW, BB_CAP = 1200.0, 300.0
FS_BW, FS_CAP = 300.0, 50.0


def _reset_ids() -> None:
    TaskInstance._ids = itertools.count()


def make_cluster(with_bb: bool = True, bb_capacity_gb: float = 1.0
                 ) -> Cluster:
    """Shared burst buffer (finite, fast) over a shared parallel FS
    (unlimited, durable); ``with_bb=False`` is the post-failure survivor
    topology the abort-and-restart baseline reruns on."""
    fs = StorageDevice(name="shared-fs", bandwidth=FS_BW,
                       per_stream_cap=FS_CAP, tier="fs")
    tiers = [fs]
    if with_bb:
        bb = StorageDevice(name="shared-bb", bandwidth=BB_BW,
                           per_stream_cap=BB_CAP, tier="bb",
                           capacity_gb=bb_capacity_gb)
        tiers = [bb, fs]
    workers = [WorkerNode(name="w0", cpus=8, io_executors=32, tiers=tiers)]
    return Cluster(workers=workers)


def run_variant(n_steps: int = 10, n_shards: int = 3,
                shard_mb: float = 128.0, step_s: float = 1.5,
                shard_bw: float = 150.0, with_bb: bool = True,
                failures=None, interference=None, trace=False) -> dict:
    """The step chain: compute, then a burst of snapshot shards onto the
    fastest tier; the next step gates on the previous burst so shards stay
    reader-protected until absorbed, after which eviction drains them to
    the FS behind the following compute."""
    _reset_ids()
    cluster = make_cluster(with_bb=with_bb)
    cfg = LifecycleConfig(auto_prefetch=False)
    t0 = time.perf_counter()
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg,
                   failures=failures, interference=interference,
                   trace=trace) as rt:
        @task(returns=1)
        def step(prev, gate, i):
            pass

        @constraint(storageBW=shard_bw, maxRetries=3)
        @io
        @task(returns=1)
        def write_shard(x, i, j):
            pass

        prev, gate = None, None
        for i in range(n_steps):
            prev = step(prev, gate, i, duration=step_s)
            gate = [write_shard(prev, i, j, io_mb=shard_mb)
                    for j in range(n_shards)]
        rt.barrier(final=True)
        stats = rt.stats()
        cat = rt.catalog
        tracked = [o for o in cat.objects.values() if not o.ephemeral]
        lost = len(cat.lost_objects) + sum(1 for o in tracked
                                           if not o.residency)
        on_dead = sum(1 for o in tracked for d in o.residency.values()
                      if d.health == "offline")
        launch_log = list(rt.scheduler.launch_log)
        retried = sum(1 for t in rt.scheduler.completed if t.retries > 0)
        shard_windows = sorted(
            (round(t.start_time, 6), round(t.end_time, 6))
            for t in rt.scheduler.completed
            if t.defn.name == "write_shard" and t.device is not None
            and t.device.tier == "bb")
        transitions = list(rt.failures.log) if rt.failures is not None \
            else []
    out = {
        "makespan": stats["makespan"],
        "wall_seconds": time.perf_counter() - t0,
        "n_tasks": stats["n_tasks"],
        "n_objects": len(tracked),
        "n_lost_objects": lost,
        "n_residencies_on_dead_devices": on_dead,
        "n_retried_tasks": retried,
        "n_evictions": stats.get("lifecycle", {}).get("n_evictions", 0),
        "health_transitions": transitions,
        "shard_windows": shard_windows,
    }
    return out, launch_log, rt.trace()


def compare(n_steps: int = 10, **kw) -> dict:
    # healthy reference: where the failure time lands relative to a clean
    # run, and the launch log the empty-schedule parity check pins
    healthy, log_plain, _ = run_variant(n_steps=n_steps, **kw)
    _, log_empty, _ = run_variant(n_steps=n_steps,
                                  failures=FailureSchedule([]), **kw)
    parity = log_plain == log_empty

    # fail mid-burst: the midpoint of a shard write ~40% into the healthy
    # run's bb write windows — the sim prefix up to t_fail is identical, so
    # the same shard is guaranteed in flight on the dying tier
    windows = healthy["shard_windows"]
    lo, hi = windows[int(0.4 * len(windows))]
    t_fail = round((lo + hi) / 2, 3)
    schedule = FailureSchedule([(t_fail, "bb", "offline")])
    reroute, _, _ = run_variant(n_steps=n_steps, failures=schedule, **kw)

    # abort-and-restart: the job dies at t_fail and reruns from scratch on
    # the surviving FS-only topology
    rerun, _, _ = run_variant(n_steps=n_steps, with_bb=False, **kw)
    abort_makespan = t_fail + rerun["makespan"]

    report = {
        "n_steps": n_steps,
        "t_fail": t_fail,
        "healthy": healthy,
        "reroute": reroute,
        "fs_only_rerun": rerun,
        "abort_restart_makespan": abort_makespan,
        "speedup_vs_abort_restart": abort_makespan / reroute["makespan"],
        "reroute_beats_abort_restart":
            reroute["makespan"] < abort_makespan,
        "zero_lost_objects": reroute["n_lost_objects"] == 0,
        "empty_schedule_launch_log_identical": parity,
    }
    assert reroute["n_lost_objects"] == 0, \
        f"reroute lost {reroute['n_lost_objects']} objects"
    assert reroute["n_residencies_on_dead_devices"] == 0, reroute
    assert reroute["n_retried_tasks"] > 0, \
        "the failure must actually hit in-flight work"
    assert report["reroute_beats_abort_restart"], \
        f"reroute {reroute['makespan']:.2f}s must beat abort+restart " \
        f"{abort_makespan:.2f}s"
    assert parity, "empty FailureSchedule must not perturb the launch log"
    return report


def export_perfetto(path: str, n_steps: int, t_fail: float) -> dict:
    """Rerun the reroute scenario *traced*, plus a modest bursty co-tenant
    on the burst buffer (the bench proper has no background traffic, and a
    trace without burst tracks would be a poor demo), and export Chrome
    trace-event JSON loadable at https://ui.perfetto.dev — the trace shows
    the co-tenant burst spans, the bb health transition at ``t_fail``, the
    lost-residency evictions, and the post-failure drains to the FS."""
    schedule = FailureSchedule([(t_fail, "bb", "offline")])
    cotenant = [("bb", BurstyTraffic(seed=7, on_mean=3.0, off_mean=2.0,
                                     streams=40, bw=400.0))]
    out, _, rec = run_variant(n_steps=n_steps, failures=schedule,
                              interference=cotenant, trace=True)
    blob = perfetto.dumps(rec)
    with open(path, "w") as f:
        f.write(blob)
    return {
        "path": path,
        "n_trace_events": len(json.loads(blob)["traceEvents"]),
        "wait_states": rec.wait_state_summary(),
        "makespan": out["makespan"],
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--out", default="BENCH_failures.json")
    ap.add_argument("--perfetto", metavar="OUT.json", default=None,
                    help="also rerun the reroute scenario traced (with a "
                         "bursty co-tenant) and export a Perfetto trace")
    args = ap.parse_args(argv)
    report = compare(n_steps=args.steps)
    print("burst-buffer failure mid-drain "
          f"(t_fail={report['t_fail']:.2f}s of "
          f"{report['healthy']['makespan']:.2f}s healthy makespan):")
    print(f"  reroute:       makespan {report['reroute']['makespan']:8.2f}s"
          f"  retries {report['reroute']['n_retried_tasks']:2d}"
          f"  lost objects {report['reroute']['n_lost_objects']}")
    print(f"  abort+restart: makespan {report['abort_restart_makespan']:8.2f}s"
          f"  (t_fail + {report['fs_only_rerun']['makespan']:.2f}s rerun)")
    print(f"  reroute beats abort+restart "
          f"{report['speedup_vs_abort_restart']:.2f}x; "
          f"empty-schedule launch log identical: "
          f"{report['empty_schedule_launch_log_identical']}")
    wait_states = None
    if args.perfetto:
        exported = export_perfetto(args.perfetto, n_steps=args.steps,
                                   t_fail=report["t_fail"])
        wait_states = exported.pop("wait_states")
        report["perfetto"] = exported
        print(f"perfetto trace written: {exported['path']} "
              f"({exported['n_trace_events']} events)")
    report = write_report(args.out, report, bench="failures",
                          config={"steps": args.steps},
                          wait_states=wait_states)
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
