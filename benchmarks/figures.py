"""One benchmark per paper figure (deliverable d). Each returns a list of
CSV rows (name, value, derived-metrics) and asserts the paper's qualitative
claims where applicable — the claims ARE the reproduction target.
"""
from __future__ import annotations

from .apps import (HMMER_DUR_ORDER, run_hmmer, run_kmeans,
                   run_variants)

STATIC_SWEEP = [2, 4, 8, 16, 32, 64, 128, 256]


# ----------------------------------------------------------- Fig 10 (+11)
def fig10_hmmer(dur=HMMER_DUR_ORDER, calibration="ordering"):
    rows = []
    res = {}
    res["baseline"] = run_hmmer("baseline", dur=dur)
    res["non-constrained"] = run_hmmer("io", dur=dur, io_executors=500)
    for c in STATIC_SWEEP:
        res[f"static-{c}"] = run_hmmer("constrained", bw=c, dur=dur)
    res["auto-unbounded"] = run_hmmer("constrained", bw="auto", dur=dur)
    res["auto(2,256,2)"] = run_hmmer("constrained", bw="auto(2,256,2)", dur=dur)
    base = res["baseline"]["makespan"]
    for name, st in res.items():
        rows.append((f"fig10_hmmer_{calibration}/{name}",
                     round(st["makespan"], 1),
                     f"rel={st['makespan'] / base:.3f},"
                     f"thr={st['io_throughput_mbs']:.0f}MBs,"
                     f"avg_io_t={st['avg_io_task_time']:.1f}"))
    # paper claims (Fig 10): non-constrained worse than baseline; U-shaped
    # static sweep with an interior optimum; static-256 drastically bad
    statics = {c: res[f"static-{c}"]["makespan"] for c in STATIC_SWEEP}
    best_c = min(statics, key=statics.get)
    assert res["non-constrained"]["makespan"] > base, "Fig10: non-constr < baseline?"
    assert 2 < best_c < 256, "Fig10: optimum not interior"
    assert statics[256] > statics[best_c] * 3, "Fig10: c=256 not drastic"
    if calibration == "ordering":
        assert res["auto-unbounded"]["makespan"] < base, "Fig10: auto !< baseline"
        assert res["auto(2,256,2)"]["makespan"] < base
        assert res["auto-unbounded"]["makespan"] <= res["auto(2,256,2)"]["makespan"]
    gain = 1 - statics[best_c] / base
    rows.append((f"fig10_hmmer_{calibration}/best_static_gain",
                 round(gain, 4), f"best_c={best_c}"))
    return rows, res


# ----------------------------------------------------------- Fig 11
def fig11_throughput(res=None):
    if res is None:
        _, res = fig10_hmmer(dur=HMMER_DUR_ORDER)
    rows = [(f"fig11_hmmer_throughput/{n}",
             round(st["io_throughput_mbs"], 1),
             f"peak={st.get('peak_io_mbs', 0):.0f}MBs")
            for n, st in res.items() if "baseline" not in n]
    thr = {n: st["io_throughput_mbs"] for n, st in res.items()}
    statics = {c: thr[f"static-{c}"] for c in STATIC_SWEEP}
    peak_c = max(statics, key=statics.get)
    # paper: throughput peaks at the optimal constraint (8) and declines on
    # both sides; the non-constrained run (all I/O piling onto the first
    # candidate node, §5.2.2) is worse than every constraint that preserves
    # parallelism (2..64; at 128/256 parallelism is 3/1 tasks per node and
    # raw throughput legitimately drops below even the congested run)
    assert peak_c == 8, f"Fig11: peak at {peak_c} != 8"
    assert thr["non-constrained"] < min(statics[c] for c in [2, 4, 8, 16, 32, 64])
    assert all(statics[c] <= statics[8] for c in STATIC_SWEEP)
    # "auto constraints achieve peak I/O throughput similar to the optimal
    # constraint" — peak sustained rate, post-learning (blended average
    # includes the deliberately-congested early epochs)
    assert res["auto-unbounded"]["peak_io_mbs"] > 0.8 * statics[peak_c]
    return rows


# ----------------------------------------------------------- Fig 12
def fig12_learning_phase():
    rows = []
    st_u = run_hmmer("constrained", bw="auto", dur=HMMER_DUR_ORDER)
    st_b = run_hmmer("constrained", bw="auto(2,256,2)", dur=HMMER_DUR_ORDER)
    tu = st_u["tuners"]["checkpointFrag"]
    tb = st_b["tuners"]["checkpointFrag"]
    for i, (c, t) in enumerate(tu["history"]):
        rows.append((f"fig12a_unbounded/epoch{i + 1}", c, f"avg_io_t={t:.2f}s"))
    for i, (c, t) in enumerate(tb["history"]):
        rows.append((f"fig12b_bounded/epoch{i + 1}", c, f"avg_io_t={t:.2f}s"))
    # paper Fig 12a: epochs 2,4,8,16; stop after the 4th (violation, not
    # registered); final choice 8. Fig 12b: 8 epochs (2..256); choice 8.
    assert [c for c, _ in tu["history"]] == [2.0, 4.0, 8.0, 16.0]
    assert sorted(tu["registry"]) == [2.0, 4.0, 8.0]
    assert tu["modal_choice"] == 8.0
    assert [c for c, _ in tb["history"]] == [2.0, 4.0, 8.0, 16.0, 32.0,
                                             64.0, 128.0, 256.0]
    # "during most of the execution time the final constraint value of the
    # bounded and the unbounded auto constraint is the same (8)" §5.2.1 —
    # the bounded registry's ties for tiny final backlogs resolve to the
    # highest constraint, exactly the paper's re-adjustment caveat
    assert tb["modal_choice"] == 8.0
    rows.append(("fig12/unbounded_choice", tu["modal_choice"],
                 f"last={tu['last_choice']}"))
    rows.append(("fig12/bounded_choice", tb["modal_choice"],
                 f"last={tb['last_choice']}"))
    return rows


# ----------------------------------------------------------- Fig 14 (+T2)
def fig14_variants(dur=None, calibration="gain"):
    from .apps import VARIANTS_DUR_GAIN, VARIANTS_DUR_ORDER
    dur = dur or (VARIANTS_DUR_GAIN if calibration == "gain"
                  else VARIANTS_DUR_ORDER)
    rows = []
    res = {}
    res["baseline"] = run_variants("baseline", dur=dur)
    res["non-constrained"] = run_variants("io", io_executors=325, dur=dur)
    for c in [2, 4, 8, 16, 32, 64]:
        res[f"static-{c}"] = run_variants("constrained", bw=c, dur=dur)
    res["auto-unbounded"] = run_variants("constrained", bw="auto", dur=dur)
    res["auto(2,256,2)"] = run_variants("constrained", bw="auto(2,256,2)",
                                        dur=dur)
    base = res["baseline"]["makespan"]
    for name, st in res.items():
        rows.append((f"fig14_variants_{calibration}/{name}",
                     round(st["makespan"], 1),
                     f"rel={st['makespan'] / base:.3f}"))
    statics = {c: res[f"static-{c}"]["makespan"] for c in [2, 4, 8, 16, 32, 64]}
    best_c = min(statics, key=statics.get)
    gain = 1 - statics[best_c] / base
    rows.append((f"fig14_variants_{calibration}/best_static_gain",
                 round(gain, 4), f"best_c={best_c}"))
    # per-class constraints (paper Table 2: each class has its own phase)
    tuners = res["auto-unbounded"]["tuners"]
    for cls, summ in sorted(tuners.items()):
        rows.append((f"table2_constraints_{calibration}/{cls}",
                     summ["modal_choice"], f"epochs={len(summ['history'])}"))
    assert res["non-constrained"]["makespan"] > base
    assert len(tuners) == 5, "five separate learning phases expected"
    if calibration == "ordering":
        assert res["auto-unbounded"]["makespan"] < base
        assert res["auto(2,256,2)"]["makespan"] < base
    return rows


# ----------------------------------------------------------- Fig 21
def fig21_kmeans():
    rows = []
    rel = {}
    for iters in (1, 3, 6):
        base = run_kmeans("baseline", iterations=iters)["makespan"]
        auto_u = run_kmeans("constrained", bw="auto", iterations=iters)
        auto_b = run_kmeans("constrained", bw="auto(2,256,2)",
                            iterations=iters)
        for name, st in (("auto-unbounded", auto_u), ("auto(2,256,2)", auto_b)):
            r = st["makespan"] / base
            rel[(iters, name)] = r
            rows.append((f"fig21_kmeans/iters{iters}/{name}",
                         round(st["makespan"], 1), f"rel={r:.3f}"))
        rows.append((f"fig21_kmeans/iters{iters}/baseline", round(base, 1),
                     "rel=1.0"))
        if iters == 1:
            tu = auto_u["tuners"]["checkpointCenters"]
            learned = sum(min(int(450 // c), 225) for c, _ in tu["history"])
            rows.append(("fig21_kmeans/unbounded_learning_tasks", learned,
                         "paper: 435 (we stop one epoch earlier: 421)"))
            tb = auto_b["tuners"]["checkpointCenters"]
            learned_b = sum(min(int(450 // c), 225) for c, _ in tb["history"])
            rows.append(("fig21_kmeans/bounded_learning_tasks", learned_b,
                         "paper: 446"))
    # paper: 1 iteration -> no auto benefit; gains appear with more
    # iterations and grow
    assert rel[(1, "auto-unbounded")] >= 0.98
    assert rel[(3, "auto-unbounded")] < rel[(1, "auto-unbounded")]
    assert rel[(6, "auto-unbounded")] <= rel[(3, "auto-unbounded")]
    return rows


# ----------------------------------------------------------- Fig 22
def fig22_hyperparameters():
    rows = []
    runs = {
        "auto(2,256,2)": ("constrained", "auto(2,256,2)", 225),
        "auto(4,16,2)": ("constrained", "auto(4,16,2)", 225),
        "auto(4,256,4)": ("constrained", "auto(4,256,4)", 225),
        "unbounded-225exec": ("constrained", "auto", 225),
        "unbounded-112exec": ("constrained", "auto", 112),
        "unbounded-56exec": ("constrained", "auto", 56),
    }
    out = {}
    for name, (mode, bw, execs) in runs.items():
        st = run_hmmer(mode, bw=bw, dur=HMMER_DUR_ORDER, io_executors=execs)
        out[name] = st["makespan"]
        rows.append((f"fig22a_hmmer/{name}", round(st["makespan"], 1),
                     f"choice={st['tuners']['checkpointFrag']['last_choice']}"))
    # paper: tighter bounds auto(4,16,2) beat auto(2,256,2); fewer I/O
    # executors start the unbounded phase nearer the optimum and win
    assert out["auto(4,16,2)"] <= out["auto(2,256,2)"]
    assert out["unbounded-56exec"] <= out["unbounded-225exec"]
    for name, (mode, bw, execs) in runs.items():
        st = run_variants(mode, bw=bw, io_executors=execs)
        rows.append((f"fig22b_variants/{name}", round(st["makespan"], 1), ""))
    return rows
