"""Co-tenant interference benchmark (ISSUE 4 tentpole evidence).

Three sections, one JSON (``BENCH_interference.json``):

**Bursty co-tenant (headline)** — a training-step chain checkpoints through
auto-constrained I/O on a shared burst buffer over a parallel FS, while a
second tenant hammers the burst buffer with seeded stochastic bursts. Two
variants run under the *same* background trace:

* ``isolation`` — the paper's tuner as-is: the constraint curve is
  calibrated once (whenever the learning epochs happen to run) and trusted
  for the rest of the run; every tier-agnostic write goes to the nominally
  fastest tier. Co-tenant bursts make both the curve and the tier ranking
  stale.
* ``adaptive`` — drift-adaptive tuning (windowed observed-vs-predicted
  monitor, recalibration with a decayed prior) plus the measured tier
  objective (compare learned per-tier T(n, c) curves, price the eviction
  drain of a nearly-full fast tier).

The adaptive variant must beat isolation by >= 1.2x makespan.

**Capacity co-tenant** — the same chain against a *finite* burst buffer
that a co-tenant keeps partially filled: capacity interference triggers
our evictions and capacity-blocks grants; the adaptive variant's eviction
pricing routes around the squeezed tier.

**Zero-interference parity** — the same workload with an engine carrying
no traffic models produces a bit-identical launch log to a run with no
engine at all (the subsystem is provably inert when disabled).

Usage::

    PYTHONPATH=src python -m benchmarks.interference \
        [--steps 60] [--seed 12061] [--out BENCH_interference.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

from repro.core import (BurstyTraffic, Cluster, DriftConfig, IORuntime,
                        LifecycleConfig, SimBackend, StorageDevice,
                        WorkerNode, constraint, io, task)
from repro.core.task import TaskInstance

from ._report import write_report

# a DataWarp-like shared burst buffer over a congested parallel FS; the bb
# is nominally ~2.7x faster, so the nameplate walk always picks it
BB_BW, BB_CAP_STREAM = 800.0, 80.0
FS_BW, FS_CAP_STREAM = 300.0, 30.0


def _reset_ids() -> None:
    TaskInstance._ids = itertools.count()


def shared_two_tier(n_workers: int = 2, bb_capacity_gb=None) -> Cluster:
    bb = StorageDevice(name="burst-buffer", bandwidth=BB_BW,
                       per_stream_cap=BB_CAP_STREAM, tier="bb",
                       capacity_gb=bb_capacity_gb)
    fs = StorageDevice(name="shared-fs", bandwidth=FS_BW,
                       per_stream_cap=FS_CAP_STREAM, tier="fs")
    return Cluster(workers=[
        WorkerNode(name=f"w{i}", cpus=4, io_executors=16, tiers=[bb, fs])
        for i in range(n_workers)])


def cotenant_trace(seed: int, capacity_mb: float = 0.0):
    """The shared background trace: long heavy bursts, short quiet gaps —
    a bulk-checkpointing co-tenant that owns most of the burst buffer's
    effective bandwidth while it is on."""
    return [("bb", BurstyTraffic(seed=seed, on_mean=8.0, off_mean=2.0,
                                 streams=120, bw=600.0,
                                 capacity_mb=capacity_mb))]


def run_variant(adaptive: bool, n_steps: int, seed: int,
                step_s: float = 0.5, ckpt_mb: float = 80.0,
                shards: int = 6, bb_capacity_gb=None,
                capacity_mb: float = 0.0, interference=True,
                trace=False) -> dict:
    _reset_ids()
    cluster = shared_two_tier(bb_capacity_gb=bb_capacity_gb)
    kwargs = {}
    if interference == "empty":
        kwargs["interference"] = []  # an engine with no traffic models
    elif interference:
        kwargs["interference"] = cotenant_trace(seed,
                                                capacity_mb=capacity_mb)
    if adaptive:
        kwargs["drift"] = DriftConfig(window=10, min_observations=5,
                                      threshold=1.5)
        kwargs["tier_objective"] = True
    if bb_capacity_gb is not None:
        kwargs["lifecycle"] = LifecycleConfig(auto_prefetch=False)
    t0 = time.perf_counter()
    with IORuntime(cluster, backend=SimBackend(), trace=trace,
                   **kwargs) as rt:
        @task(returns=1)
        def step(prev, i):
            pass

        @constraint(storageBW="auto")
        @io
        @task(returns=1)
        def ckpt(x, i, j):
            pass

        prev = None
        for i in range(n_steps):
            prev = step(prev, i, duration=step_s)
            for j in range(shards):
                ckpt(prev, i, j, io_mb=ckpt_mb)
        rt.barrier(final=True)
        stats = rt.stats()
        launch_log = list(rt.scheduler.launch_log)
        waits = stats.get("wait_states")
    by_tier = {}
    for d in cluster.devices:
        by_tier[d.tier] = by_tier.get(d.tier, 0.0) + d.bytes_written
    tuners = stats["tuners"]
    lc = stats.get("lifecycle", {})
    return {
        "adaptive": adaptive,
        "makespan": stats["makespan"],
        "overlap_time": stats["overlap_time"],
        "bytes_by_tier_mb": by_tier,
        "n_recalibrations": sum(t["n_recalibrations"]
                                for t in tuners.values()),
        "tuner_keys": sorted(tuners),
        "n_evictions": lc.get("n_evictions", 0),
        "wall_seconds": time.perf_counter() - t0,
        "wait_states": waits,  # None unless trace=True
        "_launch_log": launch_log,  # stripped before JSON
    }


def compare_bursty(n_steps: int, seed: int) -> dict:
    """Both variants run *traced* (tracing is pure reads — see the parity
    section and tests/test_obs.py — so the speedup comparison is
    unperturbed) and carry their wait-state attribution: isolation's
    latency should pool in ``bandwidth`` waits on the contended burst
    buffer, adaptive's should not."""
    base = run_variant(False, n_steps, seed, trace=True)
    adapt = run_variant(True, n_steps, seed, trace=True)
    speedup = base["makespan"] / adapt["makespan"]
    return {
        "seed": seed,
        "n_steps": n_steps,
        "isolation": {k: v for k, v in base.items() if k != "_launch_log"},
        "adaptive": {k: v for k, v in adapt.items() if k != "_launch_log"},
        "speedup": speedup,
        "adaptive_wins_1_2x": speedup >= 1.2,
    }


def compare_capacity(n_steps: int, seed: int) -> dict:
    """Capacity interference: the co-tenant also fills the (finite) burst
    buffer while it bursts, so occupancy pressure and watermark evictions
    hit the isolation variant's tier of choice."""
    kw = dict(bb_capacity_gb=1.0, capacity_mb=640.0, ckpt_mb=120.0,
              shards=4)
    base = run_variant(False, n_steps, seed, **kw)
    adapt = run_variant(True, n_steps, seed, **kw)
    return {
        "isolation": {k: v for k, v in base.items() if k != "_launch_log"},
        "adaptive": {k: v for k, v in adapt.items() if k != "_launch_log"},
        "speedup": base["makespan"] / adapt["makespan"],
    }


def parity_check(n_steps: int) -> dict:
    """With all traffic models disabled the launch log must be
    bit-identical to a run with no engine attached at all."""
    plain = run_variant(False, n_steps, seed=0, interference=False)
    empty = run_variant(False, n_steps, seed=0, interference="empty")
    return {
        "identical_launch_log":
            empty["_launch_log"] == plain["_launch_log"],
        "identical_makespan": empty["makespan"] == plain["makespan"],
        "n_launches": len(empty["_launch_log"]),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seed", type=int, default=12061)
    ap.add_argument("--out", default="BENCH_interference.json")
    args = ap.parse_args(argv)
    bursty = compare_bursty(args.steps, args.seed)
    capacity = compare_capacity(max(10, args.steps // 2), args.seed)
    parity = parity_check(min(20, args.steps))
    b = bursty
    print("bursty co-tenant on the shared burst buffer:")
    print(f"  isolation: makespan {b['isolation']['makespan']:8.2f}s  "
          f"bytes by tier {b['isolation']['bytes_by_tier_mb']}")
    print(f"  adaptive : makespan {b['adaptive']['makespan']:8.2f}s  "
          f"bytes by tier {b['adaptive']['bytes_by_tier_mb']}  "
          f"recalibrations {b['adaptive']['n_recalibrations']}")
    print(f"  speedup {b['speedup']:.2f}x (need >= 1.2x)")
    c = capacity
    print("capacity co-tenant (finite bb the co-tenant keeps filling):")
    print(f"  isolation: makespan {c['isolation']['makespan']:8.2f}s  "
          f"evictions {c['isolation']['n_evictions']}")
    print(f"  adaptive : makespan {c['adaptive']['makespan']:8.2f}s  "
          f"evictions {c['adaptive']['n_evictions']}  "
          f"speedup {c['speedup']:.2f}x")
    print(f"zero-interference parity: launch log identical = "
          f"{parity['identical_launch_log']} "
          f"({parity['n_launches']} launches)")
    for name in ("isolation", "adaptive"):
        ws = b[name]["wait_states"]
        print(f"wait-state attribution ({name}): "
              f"min task coverage {ws['min_task_coverage']:.4f}, "
              f"residual {ws['residual']:.3f}s of "
              f"{ws['total_latency']:.1f}s total")
        # acceptance bar: attribution accounts for >= 95% of *every*
        # task's end-to-end latency, residual reported above
        assert ws["min_task_coverage"] >= 0.95, \
            f"{name}: wait attribution covers only " \
            f"{ws['min_task_coverage']:.3f} of some task's latency"
    assert b["adaptive_wins_1_2x"], \
        f"adaptive must beat isolation by >= 1.2x (got {b['speedup']:.2f}x)"
    assert parity["identical_launch_log"] and parity["identical_makespan"], \
        "disabled traffic models must be bit-identical to no engine"
    report = write_report(
        args.out, {"bursty": bursty, "capacity": capacity, "parity": parity},
        bench="interference", seed=args.seed,
        config={"steps": args.steps},
        wait_states=b["adaptive"]["wait_states"])
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
