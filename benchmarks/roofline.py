"""Roofline table (deliverable g): single-pod terms for every runnable
(arch x shape) cell. Collective bytes come from the dry-run artifacts
(trip-count-aware HLO parse, per-device); FLOPs/HBM from the analytic model
(benchmarks/analytic.py — XLA cost_analysis counts loop bodies once, see
EXPERIMENTS.md §Roofline methodology).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES, cell_supported, get_config

from .analytic import roofline_terms

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cell(arch: str, shape: str, mesh: str = "single", tag: str = ""):
    p = ARTIFACTS / f"{arch}__{shape}__{mesh}{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_row(arch: str, shape: str, mesh: str = "single", tag: str = ""):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, why = cell_supported(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skip", "why": why}
    rec = load_cell(arch, shape, mesh, tag)
    if rec is None or rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": "missing"}
    n_dev = rec["n_devices"]
    coll = rec["collectives"]["total_bytes"]
    terms = roofline_terms(cfg, cell, n_dev, coll)
    return {"arch": arch, "shape": shape, "status": "ok", "n_dev": n_dev,
            "hlo_flops_reported_per_dev": rec["flops"],
            "compile_s": rec.get("compile_s"), **terms}


def full_table(mesh: str = "single"):
    rows = []
    for a in ARCHS:
        for s in SHAPES:
            rows.append(roofline_row(a, s, mesh))
    return rows


def emit_rows():
    out = []
    for r in full_table():
        key = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            out.append((key, r["status"], r.get("why", "")))
            continue
        out.append((
            key,
            round(r["roofline_fraction"], 4),
            (f"dom={r['dominant']},comp={r['compute_s']:.4f}s,"
             f"mem={r['memory_s']:.4f}s,coll={r['collective_s']:.4f}s,"
             f"useful={r['useful_ratio']:.3f}")))
    # optimized-strategy records where present (EXPERIMENTS.md §Perf)
    for a in ARCHS:
        for s, tag, label in [("train_4k", "__it4", "dp_fsdp"),
                              ("decode_32k", "__it5", "tp_serve"),
                              ("train_4k", "__it6", "moe_psum_reorder")]:
            r = roofline_row(a, s, "single", tag)
            if r["status"] != "ok":
                continue
            out.append((f"roofline_opt/{a}/{s}",
                        round(r["roofline_fraction"], 4),
                        f"strategy={label},dom={r['dominant']}"))
    return out


if __name__ == "__main__":
    for name, val, extra in emit_rows():
        print(f"{name},{val},{extra}")
