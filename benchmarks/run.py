"""Benchmark harness entry point: one function per paper table/figure plus
the roofline table. Prints ``name,value,derived`` CSV (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig10,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig10,fig11,fig12,fig14,"
                         "fig21,fig22,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest sweeps (fig22 variants half)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from . import figures
    from .apps import HMMER_DUR_GAIN
    from .roofline import emit_rows

    rows = []
    t0 = time.time()

    def want(name):
        return only is None or name in only

    if want("fig10"):
        r, res = figures.fig10_hmmer()
        rows += r
        if want("fig11"):
            rows += figures.fig11_throughput(res)
        # calibration A: reproduces the paper's headline static gain
        r2, _ = figures.fig10_hmmer(dur=HMMER_DUR_GAIN, calibration="gain")
        rows += r2
    elif want("fig11"):
        rows += figures.fig11_throughput()
    if want("fig12"):
        rows += figures.fig12_learning_phase()
    if want("fig14"):
        rows += figures.fig14_variants(calibration="gain")
        rows += figures.fig14_variants(calibration="ordering")
    if want("fig21"):
        rows += figures.fig21_kmeans()
    if want("fig22") and not args.quick:
        rows += figures.fig22_hyperparameters()
    if want("roofline"):
        rows += emit_rows()

    print("name,value,derived")
    for name, val, extra in rows:
        print(f"{name},{val},{extra}")
    print(f"# {len(rows)} rows in {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
