"""Benchmark harness entry point: one function per paper table/figure plus
the roofline table. Prints ``name,value,derived`` CSV (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig10,...]

``--check-regress`` instead audits the recorded bench trajectory
(``BENCH_history.jsonl``, appended by ``write_report(...,
headline_metric=)``) and exits 1 when any (bench, metric)'s latest value
regresses more than ``--regress-threshold`` vs the median of its priors.
"""
from __future__ import annotations

import argparse
import sys
import time


def _check_regress(history: str, threshold: float) -> int:
    from ._report import check_regress
    findings = check_regress(history, threshold)
    if not findings:
        print(f"no bench trajectory with >=2 entries in {history} — "
              f"nothing to check")
        return 0
    bad = 0
    print(f"{'bench':<16} {'metric':<28} {'latest':>12} {'baseline':>12} "
          f"{'dir':>4}  verdict")
    for f in findings:
        verdict = "REGRESSED" if f["regressed"] else "ok"
        bad += f["regressed"]
        print(f"{f['bench']:<16} {f['metric']:<28} {f['value']:>12.4g} "
              f"{f['baseline']:>12.4g} {f['direction']:>4}  {verdict} "
              f"(n_prior={f['n_prior']})")
    if bad:
        print(f"{bad} metric(s) regressed >{threshold:.0%} vs trajectory",
              file=sys.stderr)
        return 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig10,fig11,fig12,fig14,"
                         "fig21,fig22,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest sweeps (fig22 variants half)")
    ap.add_argument("--check-regress", action="store_true",
                    help="audit BENCH_history.jsonl for headline-metric "
                         "regressions instead of running benchmarks")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="trajectory file for --check-regress")
    ap.add_argument("--regress-threshold", type=float, default=0.15,
                    help="fractional regression tolerance (default 0.15)")
    args = ap.parse_args(argv)
    if args.check_regress:
        return _check_regress(args.history, args.regress_threshold)
    only = set(args.only.split(",")) if args.only else None

    from . import figures
    from .apps import HMMER_DUR_GAIN
    from .roofline import emit_rows

    rows = []
    t0 = time.time()

    def want(name):
        return only is None or name in only

    if want("fig10"):
        r, res = figures.fig10_hmmer()
        rows += r
        if want("fig11"):
            rows += figures.fig11_throughput(res)
        # calibration A: reproduces the paper's headline static gain
        r2, _ = figures.fig10_hmmer(dur=HMMER_DUR_GAIN, calibration="gain")
        rows += r2
    elif want("fig11"):
        rows += figures.fig11_throughput()
    if want("fig12"):
        rows += figures.fig12_learning_phase()
    if want("fig14"):
        rows += figures.fig14_variants(calibration="gain")
        rows += figures.fig14_variants(calibration="ordering")
    if want("fig21"):
        rows += figures.fig21_kmeans()
    if want("fig22") and not args.quick:
        rows += figures.fig22_hyperparameters()
    if want("roofline"):
        rows += emit_rows()

    print("name,value,derived")
    for name, val, extra in rows:
        print(f"{name},{val},{extra}")
    print(f"# {len(rows)} rows in {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
