"""Scheduler/simulator scale benchmark (ISSUE 1 tentpole evidence).

Two claims, one JSON:

* **Golden equivalence** — on a 1k-task mixed compute/I/O workload the
  rewritten hot path (indexed ready queues + heap event queue) produces a
  bit-identical ``launch_log`` and ``stats()`` to the frozen seed
  implementation (``benchmarks/_seed_impl.py``). Tuner ``choice_counts`` /
  ``last_choice`` / ``modal_choice`` are excluded from the comparison: the
  seed counted every *failed placement attempt* as a "choice", the rewrite
  counts granted placements (an intentional fix) — ``registry`` and
  ``history`` remain bitwise identical.
* **Speedup** — at 100k tasks the rewrite must be >= 10x faster wall-clock
  than the seed. The seed is O(ready^2), so it runs under a wall-clock
  deadline; if it blows through the deadline the recorded speedup is the
  proven lower bound.

ISSUE 10 additions (sharded control plane, docs/scale.md):

* ``--shards N`` runs the same workload through the ShardedScheduler with
  per-chain ``shard_key`` anchors and records the sharded wall clock —
  the 1M-task < 60 s headline run is
  ``--n-tasks 1000000 --shards 4 --check-regress`` (``--check-regress``
  exits non-zero when the sharded leg misses ``--deadline``, default 60 s).
  The seed comparison is skipped above ``--seed-max-n`` (the O(ready^2)
  seed would need hours there; the 100k default already proves the bound).
* **Traced-overhead pin** — the memoized blocked-head diagnosis keeps a
  traced run within ``TRACED_RATIO_MAX`` x of the untraced wall clock on
  the same workload (before memoization a traced contended run re-walked
  every worker per round); asserted on every invocation.
* ``--parity --shards N`` runs the symmetric lockstep DAG (full-worker
  compute chains + locality-anchored checkpoints) at shards 1 and N and
  asserts bit-identical launch logs — the CI 2-shard golden-parity smoke.

Usage::

    PYTHONPATH=src python -m benchmarks.sched_scale \
        [--n-tasks 100000] [--golden-n 1000] [--shards 1] \
        [--check-regress] [--deadline 60] [--parity] \
        [--out BENCH_sched_scale.json]
"""
from __future__ import annotations

import argparse
import contextlib
import gc
import itertools
import time

from repro.core import Cluster, IORuntime, SimBackend, constraint, io, task
from repro.core.scheduler import Scheduler
from repro.core.task import TaskInstance

from ._report import write_report
from ._seed_impl import SeedScheduler, SeedSimBackend

GOLDEN_N = 1_000
LARGE_N = 100_000
TRACED_N = 20_000          # workload for the traced-overhead pin
TRACED_RATIO_MAX = 5.0     # traced wall clock may cost at most this factor
SEED_MAX_N = 200_000       # beyond this the seed comparison is skipped


def _reset_ids() -> None:
    """Fresh tid space so launch logs from separate runs are comparable."""
    TaskInstance._ids = itertools.count()


@contextlib.contextmanager
def _gc_quiesced():
    """Suspend CPython's cyclic collector for a timed leg.

    The launch log and completed-task list keep every task object alive
    for the whole run, so each gen-2 collection rescans an ever-growing
    heap for garbage it can never find — at 1M tasks that is ~15 s of
    pure rescan overhead growing superlinearly with n. Plain refcounting
    frees everything those logs don't hold; the ``collect()`` on exit
    reclaims the task<->future cycles once the leg is over. Applied
    identically to seed and rewrite legs, so speedups stay comparable.
    """
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()
            gc.collect()


def _make_cluster() -> Cluster:
    # small cluster so a big submission wave keeps a deep ready backlog —
    # exactly the regime where the seed's O(ready) rescan per event blows up
    return Cluster.make(n_workers=4, cpus=8, io_executors=32)


def run_workload(n_tasks: int, scheduler_cls=Scheduler, backend=None,
                 trace=False, shards: int = 1, n_workers: int = 0):
    """Mixed compute/I/O workload: compute stages feeding static- and
    auto-constrained checkpoints (deterministic durations/sizes).
    ``trace=True`` wires an obs TraceRecorder (the determinism tests use
    this to pin that tracing never perturbs the launch log).
    ``shards > 1`` runs the sharded control plane with per-chain
    ``shard_key`` anchors (shards == 1 passes no shard kwargs at all, so
    the golden comparison workload stays byte-identical to the seed's)."""
    _reset_ids()
    # n_workers=0 keeps the canonical 4-worker golden cluster; the sharded
    # scale leg passes a wider cluster so each shard owns a BLOCK of
    # workers (the scale-out shape the control plane is for) rather than
    # a single worker per shard
    cluster = _make_cluster() if not n_workers else \
        Cluster.make(n_workers=n_workers, cpus=8, io_executors=32)
    backend = backend or SimBackend()

    @task(returns=1)
    def stage(i):
        pass

    @constraint(storageBW=8)
    @io
    @task()
    def ck_static(x, i):
        pass

    @constraint(storageBW="auto")
    @io
    @task()
    def ck_auto(x, i):
        pass

    t0 = time.perf_counter()
    with _gc_quiesced(), IORuntime(cluster, backend=backend,
                                   scheduler_cls=scheduler_cls, trace=trace,
                                   shards=shards) as rt:
        for i in range(n_tasks // 2):
            if shards > 1:
                r = stage(i, duration=1.0 + (i % 7) * 0.25, shard_key=i)
                if i % 3 == 2:
                    ck_auto(r, i, io_mb=40.0, shard_key=i)
                else:
                    ck_static(r, i, io_mb=40.0, shard_key=i)
            else:
                r = stage(i, duration=1.0 + (i % 7) * 0.25)
                if i % 3 == 2:
                    ck_auto(r, i, io_mb=40.0)
                else:
                    ck_static(r, i, io_mb=40.0)
        rt.barrier(final=True)
        elapsed = time.perf_counter() - t0
        return rt.scheduler.launch_log, rt.stats(), elapsed


def run_symmetric(n_chains: int, depth: int, shards: int = 1,
                  n_workers: int = 4):
    """Symmetric lockstep DAG for shard-count parity: full-worker compute
    chains (uniform durations) feeding locality-placed static checkpoints,
    each chain anchored by its own ``shard_key``. On this workload the
    shard-confined placement IS the global first-fit placement, so launch
    logs are bit-identical across shard counts (docs/scale.md)."""
    _reset_ids()
    cluster = Cluster.make(n_workers=n_workers, cpus=8, io_executors=32)
    cluster.shared_workdir = False  # I/O follows producer locality

    @constraint(computingUnits=8)
    @task(returns=1)
    def stage(x, i):
        pass

    @constraint(storageBW=8)
    @io
    @task()
    def ck(x, i):
        pass

    t0 = time.perf_counter()
    with _gc_quiesced(), IORuntime(cluster, shards=shards) as rt:
        futs = [0] * n_chains
        for _ in range(depth):
            for i in range(n_chains):
                futs[i] = stage(futs[i], i, duration=1.0, shard_key=i)
                ck(futs[i], i, io_mb=40.0, shard_key=i)
        rt.barrier(final=True)
        elapsed = time.perf_counter() - t0
        return rt.scheduler.launch_log, rt.stats(), elapsed


def _normalize_stats(stats: dict) -> dict:
    """Drop the tuner bookkeeping whose counting semantics intentionally
    changed (see module docstring); everything else must match bitwise."""
    out = dict(stats)
    out["tuners"] = {
        sig: {k: v for k, v in summary.items()
              if k in ("signature", "phase", "registry", "history")}
        for sig, summary in stats.get("tuners", {}).items()
    }
    return out


def golden_compare(n_tasks: int = GOLDEN_N) -> dict:
    """Run seed and rewrite on the same workload; assert identical results."""
    seed_log, seed_stats, seed_s = run_workload(
        n_tasks, scheduler_cls=SeedScheduler, backend=SeedSimBackend())
    new_log, new_stats, new_s = run_workload(n_tasks)
    identical_log = seed_log == new_log
    identical_stats = _normalize_stats(seed_stats) == _normalize_stats(new_stats)
    if not identical_log:
        diff = next(((i, a, b) for i, (a, b)
                     in enumerate(zip(seed_log, new_log)) if a != b),
                    "one log is a prefix of the other")
        raise AssertionError(f"launch_log diverged at {diff} "
                             f"(lens {len(seed_log)}/{len(new_log)})")
    if not identical_stats:
        a, b = _normalize_stats(seed_stats), _normalize_stats(new_stats)
        keys = [k for k in a if a[k] != b.get(k)]
        raise AssertionError(f"stats diverged in fields {keys}: "
                             f"{[(a[k], b[k]) for k in keys]}")
    return {
        "n_tasks": n_tasks,
        "identical_launch_log": True,
        "identical_stats": True,
        "makespan": new_stats["makespan"],
        "seed_seconds": seed_s,
        "new_seconds": new_s,
    }


def scale_run(n_tasks: int = LARGE_N, seed_deadline_factor: float = 30.0,
              with_seed: bool = True) -> dict:
    new_log, new_stats, new_s = run_workload(n_tasks)
    out = {
        "n_tasks": n_tasks,
        "n_launched": len(new_log),
        "makespan": new_stats["makespan"],
        "new_seconds": new_s,
    }
    if not with_seed:
        out.update(seed_seconds=None, seed_timed_out=None, speedup=None,
                   speedup_is_lower_bound=None)
        return out
    deadline = max(60.0, seed_deadline_factor * new_s)
    seed_timed_out = False
    t0 = time.perf_counter()
    try:
        seed_log, seed_stats, seed_s = run_workload(
            n_tasks, scheduler_cls=SeedScheduler,
            backend=SeedSimBackend(deadline=deadline))
    except TimeoutError:
        seed_timed_out = True
        seed_s = time.perf_counter() - t0
    else:
        assert seed_log == new_log, "100k launch logs diverged"
        assert _normalize_stats(seed_stats) == _normalize_stats(new_stats)
    out.update(seed_seconds=seed_s, seed_timed_out=seed_timed_out,
               speedup=seed_s / new_s, speedup_is_lower_bound=seed_timed_out)
    return out


def shard_scale_run(n_tasks: int, shards: int,
                    workers_per_shard: int = 4) -> dict:
    """The sharded leg: same workload, shard_key-anchored chains, N-shard
    control plane over a cluster where each shard owns a block of
    ``workers_per_shard`` workers (the scale-out shape sharding models —
    one worker per shard would measure confinement, not the control
    plane). Reports wall clock plus the control-plane rollup (bus
    counters, lease invariant check)."""
    n_workers = shards * workers_per_shard
    log, stats, new_s = run_workload(n_tasks, shards=shards,
                                     n_workers=n_workers)
    sh = stats.get("shards", {})
    violations = sh.get("lease_violations", [])
    assert not violations, f"lease invariants violated: {violations}"
    return {
        "n_tasks": n_tasks,
        "shards": shards,
        "n_workers": n_workers,
        "n_launched": len(log),
        "makespan": stats["makespan"],
        "new_seconds": new_s,
        "bus": sh.get("bus"),
        "cross_shard_edges": sh.get("cross_shard_edges"),
        "local_edges": sh.get("local_edges"),
    }


def traced_overhead(n_tasks: int = TRACED_N) -> dict:
    """Traced-vs-untraced pin for the memoized blocked-head diagnosis: a
    traced run of the contended workload must stay within
    ``TRACED_RATIO_MAX`` x of the untraced wall clock, and tracing must
    not perturb the launch log."""
    log_plain, _, plain_s = run_workload(n_tasks)
    log_traced, _, traced_s = run_workload(n_tasks, trace=True)
    assert log_traced == log_plain, "tracing perturbed the launch log"
    ratio = traced_s / plain_s if plain_s > 0 else float("inf")
    assert ratio <= TRACED_RATIO_MAX, (
        f"traced run cost {ratio:.1f}x the untraced wall clock at "
        f"{n_tasks} tasks (budget {TRACED_RATIO_MAX}x) — blocked-head "
        f"diagnosis memoization regressed (scheduler._diagnose_block)")
    return {"n_tasks": n_tasks, "untraced_seconds": plain_s,
            "traced_seconds": traced_s, "ratio": ratio,
            "budget": TRACED_RATIO_MAX}


def shard_parity(shards: int, n_chains: int = 16, depth: int = 5) -> dict:
    """CI golden-parity smoke: the symmetric lockstep DAG must produce the
    same launch log at 1 shard and at ``shards`` shards."""
    log1, stats1, _ = run_symmetric(n_chains, depth, shards=1)
    logn, statsn, _ = run_symmetric(n_chains, depth, shards=shards)
    if log1 != logn:
        diff = next(((i, a, b) for i, (a, b)
                     in enumerate(zip(log1, logn)) if a != b),
                    "one log is a prefix of the other")
        raise AssertionError(
            f"shard parity broken at shards={shards}: first divergence "
            f"{diff} (lens {len(log1)}/{len(logn)})")
    assert stats1["makespan"] == statsn["makespan"]
    return {"shards": shards, "n_launched": len(log1),
            "identical_launch_log": True, "makespan": stats1["makespan"]}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", "--n-tasks", dest="n", type=int, default=LARGE_N)
    ap.add_argument("--golden-n", type=int, default=GOLDEN_N)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--check-regress", action="store_true",
                    help="exit non-zero when the (sharded) scale leg "
                         "misses --deadline seconds of wall clock")
    ap.add_argument("--deadline", type=float, default=60.0)
    ap.add_argument("--parity", action="store_true",
                    help="only run the symmetric shard-parity smoke "
                         "(CI fast tier)")
    ap.add_argument("--out", default="BENCH_sched_scale.json")
    args = ap.parse_args(argv)

    if args.parity:
        shards = args.shards if args.shards > 1 else 2
        parity = shard_parity(shards)
        print(f"parity @ shards={shards}: {parity['n_launched']} launches "
              f"bit-identical to shards=1 (makespan {parity['makespan']})")
        report = write_report(
            args.out, {"parity": parity}, bench="sched_scale_parity",
            config={"shards": shards},
            headline_metric=("parity_n_launched", parity["n_launched"],
                             "max"))
        print(f"wrote {args.out}")
        return report

    # the sharded headline leg runs FIRST: wall-clock at the 1M scale is
    # sensitive to allocator/heap history, and the deadline-checked leg
    # deserves the fresh heap rather than one fragmented by the golden,
    # traced and unsharded legs that precede it logically
    shard = None
    if args.shards > 1:
        shard = shard_scale_run(args.n, args.shards)
        print(f"sharded @ {args.n} x {args.shards} shards: "
              f"{shard['new_seconds']:.2f}s "
              f"(cross-shard edges {shard['cross_shard_edges']})")
    golden = golden_compare(args.golden_n)
    print(f"golden @ {args.golden_n}: launch_log + stats identical "
          f"(seed {golden['seed_seconds']:.2f}s, new {golden['new_seconds']:.2f}s)")
    traced = traced_overhead()
    print(f"traced overhead @ {traced['n_tasks']}: "
          f"{traced['ratio']:.2f}x (budget {TRACED_RATIO_MAX}x)")
    with_seed = args.n <= SEED_MAX_N
    scale = scale_run(args.n, with_seed=with_seed)
    if with_seed:
        tag = ">=" if scale["speedup_is_lower_bound"] else "="
        print(f"scale @ {args.n}: new {scale['new_seconds']:.2f}s, "
              f"seed {scale['seed_seconds']:.2f}s"
              f"{' (timed out)' if scale['seed_timed_out'] else ''} "
              f"-> speedup {tag} {scale['speedup']:.1f}x")
    else:
        print(f"scale @ {args.n}: new {scale['new_seconds']:.2f}s "
              f"(seed comparison skipped above {SEED_MAX_N})")
    results = {"golden": golden, "scale": scale, "traced": traced}
    headline = ("scale_new_seconds", scale["new_seconds"], "min")
    if shard is not None:
        results["shard_scale"] = shard
        headline = ("shard_scale_new_seconds", shard["new_seconds"], "min")
    report = write_report(
        args.out, results, bench="sched_scale",
        config={"n": args.n, "golden_n": args.golden_n,
                "shards": args.shards},
        headline_metric=headline)
    print(f"wrote {args.out}")
    if args.check_regress:
        budget_leg = results.get("shard_scale", scale)
        if budget_leg["new_seconds"] > args.deadline:
            raise SystemExit(
                f"REGRESSION: scale leg took "
                f"{budget_leg['new_seconds']:.2f}s "
                f"> deadline {args.deadline:.0f}s")
        print(f"check-regress: {budget_leg['new_seconds']:.2f}s "
              f"<= {args.deadline:.0f}s deadline")
    return report


if __name__ == "__main__":
    main()
