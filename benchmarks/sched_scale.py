"""Scheduler/simulator scale benchmark (ISSUE 1 tentpole evidence).

Two claims, one JSON:

* **Golden equivalence** — on a 1k-task mixed compute/I/O workload the
  rewritten hot path (indexed ready queues + heap event queue) produces a
  bit-identical ``launch_log`` and ``stats()`` to the frozen seed
  implementation (``benchmarks/_seed_impl.py``). Tuner ``choice_counts`` /
  ``last_choice`` / ``modal_choice`` are excluded from the comparison: the
  seed counted every *failed placement attempt* as a "choice", the rewrite
  counts granted placements (an intentional fix) — ``registry`` and
  ``history`` remain bitwise identical.
* **Speedup** — at 100k tasks the rewrite must be >= 10x faster wall-clock
  than the seed. The seed is O(ready^2), so it runs under a wall-clock
  deadline; if it blows through the deadline the recorded speedup is the
  proven lower bound.

Usage::

    PYTHONPATH=src python -m benchmarks.sched_scale \
        [--n 100000] [--golden-n 1000] [--out BENCH_sched_scale.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

from repro.core import Cluster, IORuntime, SimBackend, constraint, io, task
from repro.core.scheduler import Scheduler
from repro.core.task import TaskInstance

from ._report import write_report
from ._seed_impl import SeedScheduler, SeedSimBackend

GOLDEN_N = 1_000
LARGE_N = 100_000


def _reset_ids() -> None:
    """Fresh tid space so launch logs from separate runs are comparable."""
    TaskInstance._ids = itertools.count()


def _make_cluster() -> Cluster:
    # small cluster so a big submission wave keeps a deep ready backlog —
    # exactly the regime where the seed's O(ready) rescan per event blows up
    return Cluster.make(n_workers=4, cpus=8, io_executors=32)


def run_workload(n_tasks: int, scheduler_cls=Scheduler, backend=None,
                 trace=False):
    """Mixed compute/I/O workload: compute stages feeding static- and
    auto-constrained checkpoints (deterministic durations/sizes).
    ``trace=True`` wires an obs TraceRecorder (the determinism tests use
    this to pin that tracing never perturbs the launch log)."""
    _reset_ids()
    cluster = _make_cluster()
    backend = backend or SimBackend()

    @task(returns=1)
    def stage(i):
        pass

    @constraint(storageBW=8)
    @io
    @task()
    def ck_static(x, i):
        pass

    @constraint(storageBW="auto")
    @io
    @task()
    def ck_auto(x, i):
        pass

    t0 = time.perf_counter()
    with IORuntime(cluster, backend=backend,
                   scheduler_cls=scheduler_cls, trace=trace) as rt:
        for i in range(n_tasks // 2):
            r = stage(i, duration=1.0 + (i % 7) * 0.25)
            if i % 3 == 2:
                ck_auto(r, i, io_mb=40.0)
            else:
                ck_static(r, i, io_mb=40.0)
        rt.barrier(final=True)
        elapsed = time.perf_counter() - t0
        return rt.scheduler.launch_log, rt.stats(), elapsed


def _normalize_stats(stats: dict) -> dict:
    """Drop the tuner bookkeeping whose counting semantics intentionally
    changed (see module docstring); everything else must match bitwise."""
    out = dict(stats)
    out["tuners"] = {
        sig: {k: v for k, v in summary.items()
              if k in ("signature", "phase", "registry", "history")}
        for sig, summary in stats.get("tuners", {}).items()
    }
    return out


def golden_compare(n_tasks: int = GOLDEN_N) -> dict:
    """Run seed and rewrite on the same workload; assert identical results."""
    seed_log, seed_stats, seed_s = run_workload(
        n_tasks, scheduler_cls=SeedScheduler, backend=SeedSimBackend())
    new_log, new_stats, new_s = run_workload(n_tasks)
    identical_log = seed_log == new_log
    identical_stats = _normalize_stats(seed_stats) == _normalize_stats(new_stats)
    if not identical_log:
        diff = next(((i, a, b) for i, (a, b)
                     in enumerate(zip(seed_log, new_log)) if a != b),
                    "one log is a prefix of the other")
        raise AssertionError(f"launch_log diverged at {diff} "
                             f"(lens {len(seed_log)}/{len(new_log)})")
    if not identical_stats:
        a, b = _normalize_stats(seed_stats), _normalize_stats(new_stats)
        keys = [k for k in a if a[k] != b.get(k)]
        raise AssertionError(f"stats diverged in fields {keys}: "
                             f"{[(a[k], b[k]) for k in keys]}")
    return {
        "n_tasks": n_tasks,
        "identical_launch_log": True,
        "identical_stats": True,
        "makespan": new_stats["makespan"],
        "seed_seconds": seed_s,
        "new_seconds": new_s,
    }


def scale_run(n_tasks: int = LARGE_N, seed_deadline_factor: float = 30.0) -> dict:
    new_log, new_stats, new_s = run_workload(n_tasks)
    deadline = max(60.0, seed_deadline_factor * new_s)
    seed_timed_out = False
    t0 = time.perf_counter()
    try:
        seed_log, seed_stats, seed_s = run_workload(
            n_tasks, scheduler_cls=SeedScheduler,
            backend=SeedSimBackend(deadline=deadline))
    except TimeoutError:
        seed_timed_out = True
        seed_s = time.perf_counter() - t0
    else:
        assert seed_log == new_log, "100k launch logs diverged"
        assert _normalize_stats(seed_stats) == _normalize_stats(new_stats)
    return {
        "n_tasks": n_tasks,
        "n_launched": len(new_log),
        "makespan": new_stats["makespan"],
        "new_seconds": new_s,
        "seed_seconds": seed_s,
        "seed_timed_out": seed_timed_out,
        "speedup": seed_s / new_s,
        "speedup_is_lower_bound": seed_timed_out,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=LARGE_N)
    ap.add_argument("--golden-n", type=int, default=GOLDEN_N)
    ap.add_argument("--out", default="BENCH_sched_scale.json")
    args = ap.parse_args(argv)

    golden = golden_compare(args.golden_n)
    print(f"golden @ {args.golden_n}: launch_log + stats identical "
          f"(seed {golden['seed_seconds']:.2f}s, new {golden['new_seconds']:.2f}s)")
    scale = scale_run(args.n)
    tag = ">=" if scale["speedup_is_lower_bound"] else "="
    print(f"scale @ {args.n}: new {scale['new_seconds']:.2f}s, "
          f"seed {scale['seed_seconds']:.2f}s"
          f"{' (timed out)' if scale['seed_timed_out'] else ''} "
          f"-> speedup {tag} {scale['speedup']:.1f}x")
    report = write_report(
        args.out, {"golden": golden, "scale": scale}, bench="sched_scale",
        config={"n": args.n, "golden_n": args.golden_n},
        headline_metric=("scale_new_seconds", scale["new_seconds"], "min"))
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
