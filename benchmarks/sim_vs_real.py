"""Sim-vs-real validation benchmark: does calibration shrink model error?

Runs one DAG three ways on a two-tier cluster (ssd + fs):

1. **measured** — ``RealBackend(tier_dirs=)`` writing real files (+fsync)
   into per-tier temp directories, traced, TelemetryHub collecting
   per-device throughput samples across concurrency waves k=1..8;
2. **predicted (default)** — ``SimBackend`` with the stock
   ``StorageDevice`` parameters (450/8 ssd, 300/4 fs MB/s), which bear no
   relation to what the temp filesystem actually delivers;
3. **predicted (fitted)** — ``SimBackend`` again, after
   :func:`repro.obs.telemetry.fit_tiers` turned the measured samples into
   per-tier ``{bandwidth, per_stream_cap, congestion_alpha}`` and
   :func:`apply_tier_config` fed them back into the cluster.

Acceptance (asserted here, pinned in ``BENCH_simreal.json``): the median
per-task |relative duration error| of the fitted config is **strictly
lower** than the default's on the same DAG, every active device produced
at least one telemetry sample, and the per-tier fitted-vs-configured
bandwidth is reported.

  PYTHONPATH=src python -m benchmarks.sim_vs_real [--quick] \\
      [--out BENCH_simreal.json] [--perfetto OUT.json]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile

from repro.core import IORuntime, RealBackend, SimBackend, io, task
from repro.core.resources import Cluster, StorageDevice, WorkerNode
from repro.obs import compare as obs_compare
from repro.obs import perfetto
from repro.obs.telemetry import apply_tier_config, fit_tiers

from ._report import write_report

FULL_WAVES = (1, 1, 2, 2, 4, 4, 8, 8)
QUICK_WAVES = (1, 2, 4)
FULL_MB = 16.0
QUICK_MB = 4.0


def make_cluster() -> Cluster:
    """One worker, two tiers with the stock (deliberately wrong for a temp
    filesystem) congestion parameters."""
    ssd = StorageDevice(name="ssd0", tier="ssd")               # 450 / 8
    fs = StorageDevice(name="fs0", bandwidth=300.0,
                       per_stream_cap=4.0, tier="fs")
    return Cluster(workers=[WorkerNode(name="w0", cpus=2,
                                       io_executors=16,
                                       tiers=[ssd, fs])])


def _make_writer(sig: str):
    """A tier-pinned I/O task that writes ``mb`` MB (+fsync) into
    ``dirpath`` — a real transfer under RealBackend, a modelled one (via
    ``io_mb=``) under SimBackend."""
    chunk = b"\0" * (1 << 20)

    def _write(dirpath, name, mb):
        path = os.path.join(dirpath, name)
        with open(path, "wb") as f:
            whole = int(mb)
            for _ in range(whole):
                f.write(chunk)
            frac = mb - whole
            if frac > 0:
                f.write(b"\0" * int(frac * (1 << 20)))
            f.flush()
            os.fsync(f.fileno())
        return path

    _write.__name__ = sig
    return io(task(returns=1)(_write))


def run_dag(rt, tier_dirs: dict, mb: float, waves) -> None:
    """Concurrency waves per tier: k parallel writes on each tier, a
    wait_on barrier between waves so the telemetry sees clean depths."""
    writers = {"ssd": _make_writer("ssd_write"),
               "fs": _make_writer("fs_write")}
    n = 0
    for k in waves:
        wave = []
        for tier, writer in writers.items():
            for _ in range(k):
                wave.append(writer(
                    tier_dirs.get(tier, ""), f"{tier}-{n}.bin", mb,
                    io_mb=mb, storage_tier=tier))
                n += 1
        rt.wait_on(*wave)
    rt.barrier(final=True)


def run_real(tier_base: str, mb: float, waves) -> IORuntime:
    cluster = make_cluster()
    tier_dirs = {}
    for tier in cluster.tier_names():
        d = os.path.join(tier_base, tier)
        os.makedirs(d, exist_ok=True)
        tier_dirs[tier] = d
    rt = IORuntime(cluster, backend=RealBackend(tier_dirs=tier_dirs),
                   trace=True)
    with rt:
        run_dag(rt, tier_dirs, mb, waves)
    return rt


def run_sim(mb: float, waves, tier_config=None) -> IORuntime:
    cluster = make_cluster()
    if tier_config:
        apply_tier_config(cluster, tier_config)
    rt = IORuntime(cluster, backend=SimBackend())
    with rt:
        run_dag(rt, {}, mb, waves)
    return rt


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller writes + fewer waves (CI smoke)")
    ap.add_argument("--mb", type=float, default=None,
                    help="MB per write (default 16, quick 4)")
    ap.add_argument("--out", default="BENCH_simreal.json")
    ap.add_argument("--perfetto", default=None, metavar="OUT.json",
                    help="export the measured run's Chrome trace-event "
                         "JSON")
    ap.add_argument("--tier-base", default=None,
                    help="directory for real tier I/O (default: fresh "
                         "temp dir, removed afterwards)")
    args = ap.parse_args(argv)

    waves = QUICK_WAVES if args.quick else FULL_WAVES
    mb = args.mb if args.mb is not None else \
        (QUICK_MB if args.quick else FULL_MB)
    tier_base = args.tier_base or tempfile.mkdtemp(prefix="simreal_")
    cleanup = args.tier_base is None

    try:
        real_rt = run_real(tier_base, mb, waves)
    finally:
        if cleanup:
            shutil.rmtree(tier_base, ignore_errors=True)
    stats = real_rt.stats()
    telemetry = stats["telemetry"]
    active = {name: d for name, d in telemetry["devices"].items()
              if d["n_ops"] > 0}
    assert active, "real run produced no telemetry samples"
    for name, d in active.items():
        assert d["n_samples"] >= 1, f"device {name} has no samples"

    sim_default = run_sim(mb, waves)
    rep_default = obs_compare.duration_error_report(sim_default, real_rt)

    fitted_cfg = fit_tiers(real_rt.backend.telemetry)
    sim_fitted = run_sim(mb, waves, tier_config=fitted_cfg)
    rep_fitted = obs_compare.duration_error_report(sim_fitted, real_rt)

    med_default = rep_default["median_abs_rel_error"]
    med_fitted = rep_fitted["median_abs_rel_error"]
    assert med_default is not None and med_fitted is not None
    assert med_fitted < med_default, (
        f"calibration did not shrink the model error: fitted "
        f"{med_fitted:.3g} vs default {med_default:.3g}")

    tier_fit = obs_compare.tier_fit_report(real_rt, sim_default.cluster)
    tiers = {}
    for tier, entry in tier_fit.items():
        f, c = entry.get("fitted"), entry.get("configured")
        tiers[tier] = {
            "configured_bw": c["bandwidth"] if c else None,
            "fitted_bw": f["bandwidth"] if f else None,
            "configured_stream": c["per_stream_cap"] if c else None,
            "fitted_stream": f["per_stream_cap"] if f else None,
            "fitted_alpha": f["congestion_alpha"] if f else None,
            "n_samples": f["n_samples"] if f else 0,
        }

    headline = {
        "median_rel_error_default": med_default,
        "median_rel_error_fitted": med_fitted,
        "error_reduction": med_default / med_fitted
        if med_fitted > 0 else float("inf"),
        "n_pairs": rep_default["n_pairs"],
        "n_telemetry_devices": len(active),
        "tiers": tiers,
    }
    print(f"sim-vs-real: median |rel err| default {med_default:.3g} -> "
          f"fitted {med_fitted:.3g} "
          f"({headline['error_reduction']:.1f}x tighter) over "
          f"{rep_default['n_io_pairs']} I/O pairs")
    for tier, t in sorted(tiers.items()):
        if t["fitted_bw"] is not None:
            print(f"  {tier:<4} bandwidth configured "
                  f"{t['configured_bw']:.0f} MB/s -> fitted "
                  f"{t['fitted_bw']:.0f} MB/s "
                  f"(per-stream {t['configured_stream']:.0f} -> "
                  f"{t['fitted_stream']:.0f})")

    report = write_report(
        args.out, headline, bench="sim_vs_real",
        config={"mb": mb, "waves": list(waves), "quick": args.quick},
        wait_states=stats.get("wait_states"),
        headline_metric=("median_rel_error_fitted", med_fitted, "min"))
    if args.perfetto:
        with open(args.perfetto, "w") as f:
            f.write(perfetto.dumps(real_rt.recorder))
        print(f"perfetto trace written: {args.perfetto}")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
