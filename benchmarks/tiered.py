"""Tiered-storage benchmark: burst-buffer checkpoint drain vs shared-FS.

The scenario is the pod-scale checkpoint loop: a chain of training steps
periodically snapshots ``n_shards`` shards. The snapshot buffer is reused,
so the step after a checkpoint is gated on the shards having been *absorbed*
by storage (written out of memory) — the classic burst-buffer motivation.

* **baseline** — one shared parallel-FS device for everyone
  (``Cluster.make(shared_storage=True)``): absorption means writing through
  the congested FS, so every checkpoint stalls the step chain behind it.
* **tiered** — ``Cluster.make_tiered`` (node-local SSD → burst buffer →
  shared FS): shards are absorbed by the fast tier in a fraction of the
  time, and runtime-generated **drain** I/O tasks (``rt.drain``) write them
  back to the shared FS asynchronously, overlapping with all subsequent
  compute. Both runs end with every byte durably on the FS tier.

The tiered makespan must beat the baseline; the JSON records both, the
overlap gained, and per-tier byte occupancy.

Usage::

    PYTHONPATH=src python -m benchmarks.tiered \
        [--steps 80] [--out BENCH_tiered.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

from repro.core import Cluster, IORuntime, SimBackend, constraint, io, task
from repro.core.task import TaskInstance

from ._report import write_report

# NVMe-class SSD over a DataWarp-like burst buffer over a congested
# parallel FS: the bench's own calibration (the paper's fsync-bound SSD
# numbers live in the default Cluster.make / figure benchmarks)
SSD_BW, SSD_CAP = 1500.0, 200.0
BB_BW, BB_CAP = 4000.0, 400.0
FS_BW, FS_CAP = 600.0, 50.0


def _reset_ids() -> None:
    TaskInstance._ids = itertools.count()


def run_scenario(tiered: bool, n_steps: int = 80, ckpt_every: int = 10,
                 n_shards: int = 8, shard_mb: float = 128.0,
                 step_s: float = 0.5, shard_bw: float = 50.0,
                 drain_bw: float = 70.0, n_workers: int = 4) -> dict:
    """One run; returns stats + scenario bookkeeping."""
    _reset_ids()
    if tiered:
        cluster = Cluster.make_tiered(
            n_workers=n_workers, cpus=8, io_executors=32,
            ssd_bw=SSD_BW, ssd_stream_cap=SSD_CAP,
            bb_bw=BB_BW, bb_stream_cap=BB_CAP,
            fs_bw=FS_BW, fs_stream_cap=FS_CAP)
    else:
        cluster = Cluster.make(
            n_workers=n_workers, cpus=8, io_executors=32,
            device_bw=FS_BW, per_stream_cap=FS_CAP, shared_storage=True)

    t0 = time.perf_counter()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @task(returns=1)
        def step(prev, gate, i):
            pass

        @constraint(storageBW=shard_bw)
        @io
        @task(returns=1)
        def write_shard(x, i, j):
            pass

        prev, gate = None, None
        for i in range(n_steps):
            prev = step(prev, gate, i, duration=step_s)
            if (i + 1) % ckpt_every == 0:
                # snapshot buffer reuse: the next step waits until every
                # shard left memory — absorbed by the fastest tier available
                absorbed = [write_shard(prev, i, j, io_mb=shard_mb)
                            for j in range(n_shards)]
                gate = absorbed
                if tiered:
                    # write-back to the durable FS tier rides in the shadow
                    # of the remaining compute; nothing waits on it before
                    # the final barrier
                    for a in absorbed:
                        rt.drain(a, to_tier="fs", from_tier="ssd",
                                 io_mb=shard_mb, storage_bw=drain_bw)
        rt.barrier(final=True)
        stats = rt.stats()
    stats["wall_seconds"] = time.perf_counter() - t0
    stats["fs_mb"] = sum(d["bytes_written"]
                         for d in stats["devices"].values()
                         if d["tier"] == "fs")
    return stats


def compare(n_steps: int = 80, **kw) -> dict:
    base = run_scenario(tiered=False, n_steps=n_steps, **kw)
    tier = run_scenario(tiered=True, n_steps=n_steps, **kw)
    # both runs persisted the same bytes to the durable FS tier
    assert abs(base["fs_mb"] - tier["fs_mb"]) < 1e-6, \
        (base["fs_mb"], tier["fs_mb"])
    speedup = base["makespan"] / tier["makespan"]
    return {
        "n_steps": n_steps,
        "baseline": {
            "makespan": base["makespan"],
            "overlap_time": base["overlap_time"],
            "io_busy_time": base["io_busy_time"],
            "devices": base["devices"],
        },
        "tiered": {
            "makespan": tier["makespan"],
            "overlap_time": tier["overlap_time"],
            "io_busy_time": tier["io_busy_time"],
            "devices": tier["devices"],
        },
        "fs_mb_durable": base["fs_mb"],
        "speedup": speedup,
        "tiered_wins": tier["makespan"] < base["makespan"],
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--out", default="BENCH_tiered.json")
    args = ap.parse_args(argv)
    report = compare(n_steps=args.steps)
    b, t = report["baseline"], report["tiered"]
    print(f"baseline (shared FS only): makespan {b['makespan']:.2f}s, "
          f"overlap {b['overlap_time']:.2f}s")
    print(f"tiered (ssd->bb->fs + drains): makespan {t['makespan']:.2f}s, "
          f"overlap {t['overlap_time']:.2f}s")
    print(f"speedup {report['speedup']:.2f}x "
          f"({report['fs_mb_durable']:.0f} MB durable on FS in both)")
    assert report["tiered_wins"], "tiered run must beat the baseline"
    report = write_report(args.out, report, bench="tiered",
                          config={"steps": args.steps})
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
