"""Burst-buffer checkpointing on the real backend.

A tiny train loop snapshots its state every few steps. With
``CheckpointManager(fast_dir=...)`` each shard is written (fsync'd) to the
fast tier first — absorbing the write burst at SSD/burst-buffer speed —
then drained to the durable shared directory by background drain I/O tasks;
the manifest commits on the shared side only after every shard landed, so
restarts never observe a half-drained checkpoint. ``RealBackend(tier_dirs=)``
gives the runtime the tier→directory mapping used by ``rt.drain`` /
``rt.prefetch`` for ad-hoc file movement.

Capacity-aware GC: the burst buffer is finite, so the manager trims it more
aggressively than the durable copy — ``fast_keep`` (default
``min(keep, 1)``) bounds how many steps' shards linger on the fast tier,
while ``keep`` durable checkpoints survive on the shared FS. The run prints
both directory listings at the end: the fast tier holds only the newest
step, the shared FS the full retention window.

Run:  PYTHONPATH=src python examples/burst_buffer_checkpoint.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (Cluster, IORuntime, RealBackend, StorageDevice,
                        WorkerNode, task)


@task(returns=1)
def train_step(state, i):
    return {k: v + 0.1 for k, v in state.items()}


def main():
    root = Path(tempfile.mkdtemp(prefix="bb_ckpt_"))
    bb_dir, fs_dir = root / "burst_buffer", root / "shared_fs"

    ssd = StorageDevice(name="local-ssd", bandwidth=2000, per_stream_cap=500,
                        capacity_gb=0.01)  # a deliberately tiny burst buffer
    fs = StorageDevice(name="pfs", bandwidth=400, per_stream_cap=80,
                       tier="fs")
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=4, io_executors=8,
                                          tiers=[ssd, fs])])
    # keep 3 durable checkpoints on the shared FS but only the newest step's
    # shards on the finite fast tier (fast_keep defaults to min(keep, 1))
    mgr = CheckpointManager(fs_dir, n_shards=4, fast_dir=bb_dir, drain_bw=80,
                            overrun_policy="wait", keep=3)

    state = {"w": np.random.default_rng(0).normal(size=(256, 256)),
             "b": np.zeros(256)}
    backend = RealBackend(tier_dirs={"ssd": bb_dir, "fs": fs_dir})
    with IORuntime(cluster, backend=backend) as rt:
        fut = None
        for i in range(6):
            fut = train_step(state if fut is None else fut, i)
            if (i + 1) % 2 == 0:
                snap = rt.wait_on(fut)
                mgr.save(i + 1, snap)
                print(f"step {i + 1}: checkpoint dispatched "
                      f"(fast tier: {bb_dir.name})")
        mgr.wait()

    restored, step = mgr.restore(state)
    print(f"restored step {step}: w mean {restored['w'].mean():+.4f}")
    drained = sorted(p.name for p in
                     (fs_dir / f"step_{step:08d}").glob("shard_*.bin"))
    print(f"durable shards on shared FS: {drained}")
    durable_steps = sorted(d.name for d in fs_dir.glob("step_*"))
    fast_steps = sorted(d.name for d in bb_dir.glob("step_*"))
    print(f"durable checkpoints (keep={mgr.keep}): {durable_steps}")
    print(f"fast-tier residue (fast_keep={mgr.fast_keep}): {fast_steps}")
    assert len(fast_steps) <= mgr.fast_keep  # mgr.wait() trimmed the rest


if __name__ == "__main__":
    main()
