"""Example: lower+compile one (arch x shape x mesh) cell and print its
roofline terms — the workflow behind EXPERIMENTS.md §Roofline.

  PYTHONPATH=src python examples/dryrun_one_cell.py [arch] [shape]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "tinyllama-1.1b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, "single")
    print({k: rec[k] for k in ("arch", "shape", "status") if k in rec})
    if rec["status"] == "ok":
        from benchmarks.roofline import roofline_row
        row = roofline_row(arch, shape)
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in row.items()})
