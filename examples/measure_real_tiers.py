"""Measure real storage tiers, fit the congestion model, re-simulate.

The simulator's ``StorageDevice`` parameters (bandwidth, per-stream cap,
congestion ramp) are normally taken from a spec sheet. This example
*measures* them instead: it writes concurrency waves of real files
(+fsync) into two temp-directory "tiers" under ``RealBackend``, fits
each tier's parameters from the collected telemetry samples
(``repro.obs.telemetry.fit_tiers``), prints fitted-vs-configured, then
feeds the fitted config into a ``SimBackend`` run of the same DAG — the
calibrated simulator now predicts what this machine's storage actually
delivers (see docs/observability.md).

  PYTHONPATH=src python examples/measure_real_tiers.py
"""
import os
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (Cluster, IORuntime, RealBackend, SimBackend,
                        StorageDevice, WorkerNode, io, task)
from repro.obs.telemetry import apply_tier_config, fit_tiers

WAVES = (1, 2, 4)       # concurrent writers per tier, per wave
MB_PER_WRITE = 4.0


@io
@task(returns=1)
def put(dirpath, name, mb):
    """Write ~mb MB (+fsync) when a real directory is given; under the
    simulator the body never runs and ``io_mb=`` models the transfer."""
    if not dirpath:
        return name
    path = os.path.join(dirpath, name)
    with open(path, "wb") as f:
        f.write(b"\0" * int(mb * (1 << 20)))
        f.flush()
        os.fsync(f.fileno())
    return name


def make_cluster():
    ssd = StorageDevice(name="ssd0", tier="ssd")                 # 450 / 8
    fs = StorageDevice(name="fs0", bandwidth=300.0,
                       per_stream_cap=4.0, tier="fs")
    return Cluster(workers=[WorkerNode(name="w0", cpus=2,
                                       io_executors=16,
                                       tiers=[ssd, fs])])


def run_waves(rt, tier_dirs):
    n = 0
    for k in WAVES:
        wave = []
        for tier in ("ssd", "fs"):
            for _ in range(k):
                wave.append(put(tier_dirs.get(tier, ""),
                                f"{tier}-{n}.bin", MB_PER_WRITE,
                                io_mb=MB_PER_WRITE, storage_tier=tier))
                n += 1
        rt.wait_on(*wave)
    rt.barrier(final=True)


def main():
    base = tempfile.mkdtemp(prefix="measure_tiers_")
    try:
        cluster = make_cluster()
        tier_dirs = {t: os.path.join(base, t)
                     for t in cluster.tier_names()}
        for d in tier_dirs.values():
            os.makedirs(d, exist_ok=True)
        rt = IORuntime(cluster, backend=RealBackend(tier_dirs=tier_dirs))
        with rt:
            run_waves(rt, tier_dirs)

        # guarded: under `python -m repro.lint` the runtime swaps in the
        # capture backend (no telemetry hub, no real I/O) — skip the fit
        hub = getattr(rt.backend, "telemetry", None)
        fitted = fit_tiers(hub) if hub is not None else {}
        if not fitted:
            print("no measured telemetry (capture/lint mode?) — "
                  "skipping the fit")
            return
        configured = {d.tier: d for d in cluster.devices}
        for tier, cfg in sorted(fitted.items()):
            dev = configured.get(tier)
            print(f"{tier:<4} configured {dev.bandwidth:7.0f} MB/s "
                  f"(per-stream {dev.per_stream_cap:5.1f}) -> measured "
                  f"{cfg['bandwidth']:7.0f} MB/s "
                  f"(per-stream {cfg['per_stream_cap']:6.1f}, "
                  f"ramp alpha {cfg['congestion_alpha']:.3f}, "
                  f"n={cfg['n_samples']})")

        sim_cluster = make_cluster()
        n_updated = apply_tier_config(sim_cluster, fitted)
        rt2 = IORuntime(sim_cluster, backend=SimBackend())
        with rt2:
            run_waves(rt2, {})
        print(f"calibrated sim ({n_updated} devices updated): "
              f"predicted makespan {rt2.stats()['makespan']:.3f}s vs "
              f"measured {rt.stats()['makespan']:.3f}s")
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    main()
