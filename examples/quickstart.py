"""Quickstart: the paper's programming model in 30 lines.

An I/O-intensive app (compute -> checkpoint per block) run three ways:
baseline (checkpoints are compute tasks), I/O tasks without constraints
(congestion!), and auto-tuned storage-bandwidth constraints — reproducing
the paper's core result on the calibrated MareNostrum-4 storage model.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (Cluster, IORuntime, SimBackend, constraint,
                        expected_task_time, io, task)


def run(mode):
    cluster = Cluster.make(n_workers=12, io_executors=225)
    dev = cluster.workers[0].storage

    @task(returns=1)
    def compute_block(i):
        ...

    if mode == "baseline":
        @task()
        def checkpoint(block, i): ...
    elif mode == "non-constrained":
        @io
        @task()
        def checkpoint(block, i): ...
    else:
        @constraint(storageBW="auto")   # the paper's contribution
        @io
        @task()
        def checkpoint(block, i): ...

    with IORuntime(cluster, backend=SimBackend()) as rt:
        for i in range(2304):
            b = compute_block(i, duration=200.0)
            if mode == "baseline":
                checkpoint(b, i, duration=expected_task_time(dev, 48, 290))
            else:
                checkpoint(b, i, io_mb=290.0)
        rt.barrier(final=True)
        diags = rt.lint()           # static I/O-plan analysis (docs/lint.md)
        assert not diags, [str(d) for d in diags]
        return rt.stats()


if __name__ == "__main__":
    base = run("baseline")
    for mode in ("baseline", "non-constrained", "auto"):
        st = run(mode)
        # makespan is 0.0 under capture mode (python -m repro.lint): guard
        # the result post-processing so the plan records end to end
        rel = st["makespan"] / base["makespan"] if base["makespan"] else 0.0
        line = f"{mode:16} total={st['makespan']:8.1f}s rel={rel:.2f}"
        if mode == "auto":
            t = st["tuners"].get("checkpoint")
            if t:
                line += (f"  learning epochs={[c for c, _ in t['history']]} "
                         f"-> constraint {t['modal_choice']}")
        print(line)
