"""Serving example: batched prefill+decode with I/O-task trace dumps.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_smoke_config
from repro.launch.serve import serve

if __name__ == "__main__":
    trace = tempfile.mktemp(suffix=".jsonl")
    out = serve(get_smoke_config("tinyllama-1.1b"), n_requests=6,
                prompt_len=24, max_new=8, batch=3, trace_path=trace)
    print(f"{out['requests']} requests, {out['tokens_per_s']:.1f} tok/s")
    n_lines = len(open(trace).readlines())
    print(f"trace records written by I/O tasks: {n_lines}")
    assert n_lines == out["requests"]
