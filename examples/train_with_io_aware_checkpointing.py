"""End-to-end example: train a small LM with async, auto-constrained
checkpoint shards overlapping the train steps, then kill/resume.

  PYTHONPATH=src python examples/train_with_io_aware_checkpointing.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import PRESETS, train

if __name__ == "__main__":
    ckpt = tempfile.mkdtemp(prefix="repro_ck_")
    print(f"checkpoints -> {ckpt}")
    out = train(PRESETS["5m"], steps=12, batch=2, seq=64, ckpt_dir=ckpt,
                ckpt_every=4, io_aware=True)
    print(f"phase 1: {out['steps_run']} steps, "
          f"loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")
    out = train(PRESETS["5m"], steps=20, batch=2, seq=64, ckpt_dir=ckpt,
                ckpt_every=4, io_aware=True, resume=True)
    print(f"phase 2 (resumed): {out['steps_run']} steps, "
          f"final loss {out['final_loss']:.3f}")
    assert out["steps_run"] < 20, "resume must skip completed steps"
    print("resume OK — fault-tolerant restart works")
