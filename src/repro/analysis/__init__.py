"""Static I/O-plan analysis + inline runtime sanitizer (``iolint``).

Two halves, built on the runtime's own bookkeeping so diagnostics and
runtime behaviour can never disagree:

* **Static plan analyzer** (:mod:`.capture` + :mod:`.lint`):
  ``IORuntime(backend="capture")`` (or ``rt.plan()``) records the full task
  DAG *without executing any task body*, then :func:`~.lint.lint_runtime`
  runs a rule engine over the captured plan and emits structured
  :class:`~.lint.Diagnostic`\\ s with stable codes — ``IO1xx`` constraint
  satisfiability, ``IO2xx`` capacity/lifecycle, ``IO3xx`` races and
  ordering, ``IO4xx`` determinism. CLI: ``python -m repro.lint script.py``.

* **Inline sanitizer** (:mod:`.sanitizer`, "IOSan"):
  ``SimBackend(sanitize=True)`` asserts the property-test invariants at
  every simulation event boundary (occupancy ≤ capacity, bandwidth claims
  within budget, residency↔occupancy agreement, no scheduled reader on an
  evicted object, monotonic event time) and raises
  :class:`~.sanitizer.SanitizerError` at the *first* violation with the
  offending device/task and the recent event trace, instead of a corrupted
  end state at the barrier. The checks are read-only: sanitizer-on runs
  produce bit-identical launch logs.

See docs/lint.md for the full diagnostic catalog.
"""
from .capture import CaptureBackend, PlanCapture
from .lint import Diagnostic, lint_runtime, lint_script
from .sanitizer import IOSanitizer, SanitizerError

__all__ = [
    "CaptureBackend", "PlanCapture",
    "Diagnostic", "lint_runtime", "lint_script",
    "IOSanitizer", "SanitizerError",
]
