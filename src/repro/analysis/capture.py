"""Capture mode: record the task DAG without executing it.

:class:`CaptureBackend` is a :class:`~repro.core.backends.Backend` that
never launches anything. The runtime detects it (``rt.capture_mode``) and
routes submissions past the scheduler entirely: every
:class:`~repro.core.task.TaskInstance` is recorded in a
:class:`PlanCapture` together with its *full* happens-before relation —
computed by :func:`repro.core.graph.compute_deps` *before*
``TaskGraph.add`` mutates the DataHandle bookkeeping, so edges to
already-completed producers (which ``add`` elides as satisfied) are kept.
``drain`` resolves futures to ``None`` in dependency-respecting
submission order so ``wait_on``/barriers return and the driving script
runs to completion; no task body, scheduler grant, or device accounting
ever executes.

The lint CLI (``python -m repro.lint``) sets :data:`FORCE` so that every
``IORuntime`` a script constructs — whatever backend it asked for — is
hijacked into capture mode and registered here for post-run analysis.
"""
from __future__ import annotations

import heapq
import threading

from ..core.backends import Backend
from ..core.graph import compute_deps, iter_futures
from ..core.task import TaskInstance, TaskState

#: when True (set by the repro.lint CLI), every IORuntime construction is
#: forced into capture mode regardless of the backend the script passed
FORCE = False

_registry_lock = threading.Lock()
_registry: list = []  # capture-mode runtimes constructed while FORCE was on


def set_force(on: bool) -> None:
    global FORCE
    FORCE = bool(on)


def register(runtime) -> None:
    with _registry_lock:
        _registry.append(runtime)


def registered() -> list:
    with _registry_lock:
        return list(_registry)


def clear_registry() -> None:
    with _registry_lock:
        _registry.clear()


class PlanCapture:
    """The recorded plan: tasks in submission order, the full
    happens-before relation, and the lifecycle events (pin/unpin/discard/
    external registrations) the lint rules reason about.

    Every record carries a monotonically increasing sequence number on one
    shared axis (``TaskInstance._plan_seq`` for tasks), so "submitted after
    the discard" style ordering questions are a plain comparison.
    """

    def __init__(self):
        self.tasks: list[TaskInstance] = []        # submission order
        #: consumer tid -> {producer tid: is_data} (full relation, including
        #: edges to producers that were already DONE at submission)
        self.edges: dict[int, dict[int, bool]] = {}
        #: consumer tid -> producer tids consumed through argument Futures
        #: (the data actually read — excludes DataHandle/anti ordering)
        self.future_inputs: dict[int, set[int]] = {}
        #: id(future) -> future for pins with no matching unpin yet
        self.pins: dict[int, object] = {}
        #: (seq, producer tid) for every rt.discard call
        self.discards: list[tuple[int, int]] = []
        #: external datasets: dicts with name/size_mb/tier/pinned/seq
        self.externals: list[dict] = []
        self._seq = 0

    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # ------------------------------------------------------------- recording
    def on_submit(self, task: TaskInstance) -> None:
        """Record a submission. MUST run before ``TaskGraph.add`` — dep
        computation reads the DataHandle state ``add`` is about to bump."""
        task._plan_seq = self.next_seq()
        self.edges[task.tid] = {d.tid: is_data
                                for d, is_data in compute_deps(task).items()}
        futs: set[int] = set()
        for arg in list(task.args) + list(task.kwargs.values()):
            for f in iter_futures(arg):
                futs.add(f.task.tid)
        self.future_inputs[task.tid] = futs
        self.tasks.append(task)

    def on_pin(self, fut) -> None:
        self.next_seq()
        self.pins[id(fut)] = fut

    def on_unpin(self, fut) -> None:
        self.next_seq()
        self.pins.pop(id(fut), None)

    def on_discard(self, fut) -> None:
        self.discards.append((self.next_seq(), fut.task.tid))

    def on_external(self, name: str, size_mb: float, tier: str,
                    pinned: bool) -> None:
        self.externals.append({"name": name, "size_mb": float(size_mb),
                               "tier": tier, "pinned": bool(pinned),
                               "seq": self.next_seq()})


class CaptureBackend(Backend):
    """Backend that records the plan and executes nothing.

    ``launch`` raising (rather than passing) is the load-bearing guarantee
    behind "capture mode executes no task bodies": the runtime's capture
    submit path never reaches the scheduler, so nothing can call it.
    """

    is_capture = True

    def __init__(self):
        self.capture = PlanCapture()
        self._ready: list[tuple[int]] = []  # min-heap of ready tids

    def now(self) -> float:
        return 0.0

    def launch(self, task: TaskInstance, worker) -> None:
        raise AssertionError(
            "CaptureBackend.launch called — capture mode must never "
            "execute tasks (runtime submit-path bug)")

    def mark_ready(self, task: TaskInstance) -> None:
        heapq.heappush(self._ready, (task.tid,))

    def drain(self, predicate) -> None:
        """Resolve every captured task's futures to ``None`` in dependency-
        respecting tid order, so barriers and ``wait_on`` in the driving
        script return. ``sim_fail`` injections are ignored: the plan, not
        the failure semantics, is being recorded."""
        graph = self.runtime.graph
        while self._ready:
            (tid,) = heapq.heappop(self._ready)
            task = graph.tasks[tid]
            if task.state == TaskState.DONE:
                continue
            for f in task.futures:
                if not f.resolved():
                    f.set_value(None)
            for child in graph.complete(task):
                heapq.heappush(self._ready, (child.tid,))
        if not predicate():
            raise RuntimeError(
                f"capture drain resolved every recorded task but the wait "
                f"predicate still fails (unfinished={graph.unfinished}) — "
                f"a future from another runtime is being waited on here")
