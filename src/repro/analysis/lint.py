"""Static I/O-plan rule engine: structured diagnostics over a captured DAG.

Diagnostic codes are stable API (tests and docs/lint.md key on them):

=========  ===============  ====================================================
code       category         condition
=========  ===============  ====================================================
``IO101``  constraints      static storageBW exceeds every eligible device
``IO102``  constraints      tier pin names a tier absent from the cluster
``IO103``  constraints      computingUnits exceed every worker's cpus
``IO104``  constraints      bounded auto minimum exceeds every eligible device
``IO201``  capacity         object larger than every eligible tier's capacity
``IO202``  capacity         unevictable footprint exceeds a finite tier
``IO203``  capacity         pin without a matching unpin (capacity leak)
``IO204``  capacity         finite durable tier with auto-evict (wedge)
``IO301``  race/ordering    two unordered tasks touch the same path
``IO302``  race/ordering    task reads a future after ``rt.discard`` of it
``IO303``  race/ordering    drain/prefetch with no producer dependency
``IO304``  race/ordering    manifest/commit not ordered after its shards
``IO401``  determinism      unseeded ``BurstyTraffic`` (irreproducible runs)
``IO402``  determinism      task body references an unseeded RNG source
``IO501``  failure-domains  schedule leaves the durable tier offline forever
``IO601``  sharding         dependency chain ping-pongs across shard anchors
``IO602``  sharding         shared-tier output fanned out to many shard anchors
=========  ===============  ====================================================

Feasibility predicates are shared with the scheduler
(:func:`repro.core.scheduler.eligible_devices`), so a lint diagnostic and a
submission-time ``SchedulerError`` can never disagree about what is
placeable. Full fidelity requires capture mode
(``IORuntime(backend="capture")``); linting a live runtime still runs every
rule but sees only the edges ``TaskGraph`` retained.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

from ..core.constraints import AutoSpec, StaticSpec
from ..core.graph import bind_args, iter_futures
from ..core.interference import BurstyTraffic
from ..core.scheduler import eligible_devices
from ..core.task import TaskInstance, TaskType

CATEGORIES = {"1": "constraints", "2": "capacity", "3": "race/ordering",
              "4": "determinism", "5": "failure-domains", "6": "sharding"}

_MOVER_SIGS = ("tier_drain", "tier_prefetch")


@dataclass(frozen=True)
class Diagnostic:
    """One structured finding. ``task``/``tid`` name the offending task
    (None for cluster/config-level findings like IO204/IO401)."""

    code: str
    message: str
    task: Optional[str] = None
    tid: Optional[int] = None

    @property
    def category(self) -> str:
        return CATEGORIES.get(self.code[2:3], "other")

    def __str__(self) -> str:
        loc = f" [{self.task}#{self.tid}]" if self.task is not None else ""
        return f"{self.code} ({self.category}){loc}: {self.message}"


def _diag(code: str, message: str, task: Optional[TaskInstance] = None
          ) -> Diagnostic:
    if task is None:
        return Diagnostic(code, message)
    return Diagnostic(code, message, task=task.defn.signature, tid=task.tid)


# --------------------------------------------------------------------------
# Analysis context
# --------------------------------------------------------------------------
class _Ctx:
    """Uniform view over a captured plan (full edges) or a live runtime's
    graph (partial edges: only those unfinished at submission)."""

    def __init__(self, rt):
        self.rt = rt
        self.cluster = rt.cluster
        self.catalog = rt.catalog
        cap = getattr(rt.backend, "capture", None)
        self.capture = cap
        if cap is not None:
            self.tasks = list(cap.tasks)
            self.edges = cap.edges
            self.future_inputs = cap.future_inputs
        else:
            self.tasks = [rt.graph.tasks[tid]
                          for tid in sorted(rt.graph.tasks)]
            self.edges = {t.tid: {d: True for d in t.deps}
                          for t in self.tasks}
            self.future_inputs = {}
            for t in self.tasks:
                futs = set()
                for arg in list(t.args) + list(t.kwargs.values()):
                    for f in iter_futures(arg):
                        futs.add(f.task.tid)
                self.future_inputs[t.tid] = futs
        self._order_cache: dict[tuple[int, int], bool] = {}

    def ordered_before(self, a: int, b: int) -> bool:
        """True iff task ``a`` happens-before ``b`` through recorded edges
        (data and anti edges both order). On-demand BFS with memo — the
        candidate pairs rules ask about are few, so no transitive closure
        is materialised."""
        if a == b:
            return True
        key = (a, b)
        hit = self._order_cache.get(key)
        if hit is not None:
            return hit
        found = False
        seen = {b}
        stack = [b]
        while stack:
            cur = stack.pop()
            for pred in self.edges.get(cur, ()):
                if pred == a:
                    found = True
                    stack.clear()
                    break
                if pred > a and pred not in seen:  # edges point to lower tids
                    seen.add(pred)
                    stack.append(pred)
        self._order_cache[key] = found
        return found

    def io_tasks(self) -> Iterator[TaskInstance]:
        for t in self.tasks:
            if t.defn.task_type != TaskType.COMPUTE:
                yield t


def _tier_suffix(tier: Optional[str]) -> str:
    return f" on tier {tier!r}" if tier is not None else ""


# --------------------------------------------------------------------------
# IO1xx — constraint satisfiability
# --------------------------------------------------------------------------
def _rule_io101_static_bw(ctx: _Ctx) -> Iterator[Diagnostic]:
    seen = set()
    for t in ctx.io_tasks():
        spec = t.storage_bw
        if not isinstance(spec, StaticSpec):
            continue
        tier = t.tier
        if tier is not None and not ctx.cluster.has_tier(tier):
            continue  # IO102 reports the unknown tier
        key = (t.defn.signature, spec.value, tier)
        if key in seen:
            continue
        seen.add(key)
        devs = eligible_devices(ctx.cluster, tier)
        if devs and all(d.bandwidth < spec.value for d in devs):
            cap = max(d.bandwidth for d in devs)
            yield _diag("IO101",
                        f"storageBW={spec.value:g} MB/s exceeds every "
                        f"eligible device's bandwidth"
                        f"{_tier_suffix(tier)} (max {cap:g} MB/s) — the "
                        f"task can never be granted", t)


def _rule_io102_unknown_tier(ctx: _Ctx) -> Iterator[Diagnostic]:
    seen = set()
    for t in ctx.tasks:
        tier = t.tier
        if tier is None or ctx.cluster.has_tier(tier):
            continue
        key = (t.defn.signature, tier)
        if key in seen:
            continue
        seen.add(key)
        yield _diag("IO102",
                    f"storage tier {tier!r} is not present on any worker "
                    f"(available: {ctx.cluster.tier_names()})", t)


def _rule_io103_cpu_units(ctx: _Ctx) -> Iterator[Diagnostic]:
    workers = ctx.cluster.workers
    if not workers:
        return
    max_cpus = max(w.cpus for w in workers)
    seen = set()
    for t in ctx.tasks:
        if t.defn.task_type != TaskType.COMPUTE:
            continue
        cu = t.defn.computing_units
        if cu <= max_cpus or t.defn.signature in seen:
            continue
        seen.add(t.defn.signature)
        yield _diag("IO103",
                    f"computingUnits={cu} exceeds every worker's cpus "
                    f"(max {max_cpus}) — the task can never be placed", t)


def _rule_io104_auto_min(ctx: _Ctx) -> Iterator[Diagnostic]:
    seen = set()
    for t in ctx.io_tasks():
        spec = t.storage_bw
        if not isinstance(spec, AutoSpec) or not spec.bounded:
            continue
        tier = t.tier
        if tier is not None and not ctx.cluster.has_tier(tier):
            continue
        key = (t.defn.signature, spec.min, tier)
        if key in seen:
            continue
        seen.add(key)
        devs = eligible_devices(ctx.cluster, tier)
        if devs and all(d.bandwidth < spec.min for d in devs):
            cap = max(d.bandwidth for d in devs)
            yield _diag("IO104",
                        f"auto constraint lower bound min={spec.min:g} MB/s "
                        f"exceeds every eligible device's bandwidth"
                        f"{_tier_suffix(tier)} (max {cap:g} MB/s) — no "
                        f"learning epoch can ever be granted", t)


# --------------------------------------------------------------------------
# IO2xx — capacity / lifecycle
# --------------------------------------------------------------------------
def _capacity_enforced(ctx: _Ctx) -> bool:
    return ctx.catalog is not None and ctx.catalog.enabled


def _rule_io201_oversized_object(ctx: _Ctx) -> Iterator[Diagnostic]:
    if not _capacity_enforced(ctx):
        return
    seen = set()
    for t in ctx.io_tasks():
        mb = t.sim.io_bytes
        if mb <= 0:
            continue
        tier = t.tier
        if tier is not None and not ctx.cluster.has_tier(tier):
            continue
        key = (t.defn.signature, mb, tier)
        if key in seen:
            continue
        seen.add(key)
        devs = eligible_devices(ctx.cluster, tier)
        caps = [d.capacity_mb for d in devs]
        if caps and all(c is not None and mb > c for c in caps):
            yield _diag("IO201",
                        f"output footprint io_mb={mb:g} exceeds every "
                        f"eligible device's total capacity"
                        f"{_tier_suffix(tier)} (max "
                        f"{max(caps):.0f} MB) — not grantable even after "
                        f"evicting everything", t)


def _rule_io202_unevictable_footprint(ctx: _Ctx) -> Iterator[Diagnostic]:
    if not _capacity_enforced(ctx):
        return
    cat = ctx.catalog
    auto_evict = cat.config.auto_evict
    pinned_tids = set()
    if ctx.capture is not None:
        for fut in ctx.capture.pins.values():
            pinned_tids.add(fut.task.tid)
    per_tier: dict[str, float] = {}
    first: dict[str, TaskInstance] = {}
    for t in ctx.io_tasks():
        mb = t.sim.io_bytes
        tier = t.tier
        if mb <= 0 or tier is None or not ctx.cluster.has_tier(tier):
            continue
        if t.defn.signature in _MOVER_SIGS:
            continue  # movements don't create new footprint on top of the
        #               payload's (the catalog aliases, not duplicates)
        if auto_evict and t.tid not in pinned_tids:
            continue  # evictable: watermark pressure can clear it
        per_tier[tier] = per_tier.get(tier, 0.0) + mb
        first.setdefault(tier, t)
    if ctx.capture is not None:
        for ext in ctx.capture.externals:
            if ext["pinned"] or not auto_evict:
                tier = ext["tier"]
                per_tier[tier] = per_tier.get(tier, 0.0) + ext["size_mb"]
    for tier, mb in sorted(per_tier.items()):
        caps = [d.capacity_mb for d in eligible_devices(ctx.cluster, tier)]
        if not caps or any(c is None for c in caps):
            continue
        total = sum(caps)
        if mb > total + 1e-6:
            why = "pinned" if auto_evict else \
                "unevictable (auto_evict is off)"
            yield _diag("IO202",
                        f"peak footprint of {why} data on tier {tier!r} "
                        f"reaches {mb:.0f} MB but the tier's total "
                        f"capacity is {total:.0f} MB — the run will wedge "
                        f"capacity-blocked", first.get(tier))


def _rule_io203_pin_leak(ctx: _Ctx) -> Iterator[Diagnostic]:
    if ctx.capture is None:
        return
    for fut in ctx.capture.pins.values():
        t = fut.task
        yield _diag("IO203",
                    f"pin without a matching unpin: the object produced by "
                    f"{t.defn.signature}#{t.tid} stays exempt from "
                    f"eviction forever (a capacity leak on its tier) — "
                    f"call rt.unpin(...) once the data stops being hot", t)


def _rule_io204_finite_durable(ctx: _Ctx) -> Iterator[Diagnostic]:
    for msg in getattr(ctx.catalog, "config_errors", ()):
        yield Diagnostic("IO204", msg)


# --------------------------------------------------------------------------
# IO3xx — races / ordering
# --------------------------------------------------------------------------
#: parameter names treated as file paths; ``src``-flavoured ones are reads,
#: everything else a write (conservative: flags write-write and write-read)
_PATH_PARAMS = {"path", "file", "filename", "fname", "dest", "dst", "out",
                "output", "target", "manifest", "src", "source"}
_READ_PARAMS = {"src", "source", "src_path", "source_path", "src_file"}


def _path_args(task: TaskInstance) -> Iterator[tuple[str, bool]]:
    """(path, is_write) for every path-like string argument."""
    for pname, arg in bind_args(task):
        if not isinstance(arg, str) or not arg:
            continue
        base = pname.lower()
        if base in _PATH_PARAMS or base.endswith(("_path", "_file", "_dir")):
            yield arg, base not in _READ_PARAMS


def _rule_io301_path_races(ctx: _Ctx) -> Iterator[Diagnostic]:
    by_path: dict[str, list[tuple[TaskInstance, bool]]] = {}
    for t in ctx.io_tasks():
        for path, is_write in _path_args(t):
            by_path.setdefault(path, []).append((t, is_write))
    for path, touches in sorted(by_path.items()):
        if len(touches) < 2:
            continue
        reported = False
        for i in range(len(touches)):
            if reported:
                break
            a, a_w = touches[i]
            for b, b_w in touches[i + 1:]:
                if a.tid == b.tid or not (a_w or b_w):
                    continue  # read-read never races
                lo, hi = (a, b) if a.tid < b.tid else (b, a)
                if ctx.ordered_before(lo.tid, hi.tid):
                    continue
                kind = "write-write" if (a_w and b_w) else "write-read"
                yield _diag("IO301",
                            f"{kind} race on path {path!r}: "
                            f"{lo.defn.signature}#{lo.tid} and "
                            f"{hi.defn.signature}#{hi.tid} touch it with "
                            f"no happens-before edge — pass a future "
                            f"between them or use distinct paths", hi)
                reported = True  # one report per path is enough signal
                break


def _rule_io302_read_after_discard(ctx: _Ctx) -> Iterator[Diagnostic]:
    if ctx.capture is None:
        return
    for dseq, ptid in ctx.capture.discards:
        for t in ctx.tasks:
            if getattr(t, "_plan_seq", 0) <= dseq:
                continue
            if ptid in ctx.future_inputs.get(t.tid, ()):
                yield _diag("IO302",
                            f"{t.defn.signature}#{t.tid} reads the output "
                            f"of task #{ptid} after rt.discard() promised "
                            f"it would never be read again — eviction may "
                            f"delete it without the durable drain; drop "
                            f"the discard or reorder the reader before "
                            f"it", t)
                break  # first offending reader per discard


def _rule_io303_payloadless_mover(ctx: _Ctx) -> Iterator[Diagnostic]:
    for t in ctx.io_tasks():
        if t.defn.signature not in _MOVER_SIGS:
            continue
        if t._datalife is not None:
            continue  # runtime-synthesized eviction/staging movers are
        #               ordered by the lifecycle machinery itself
        if t.sim.io_bytes <= 0 or ctx.future_inputs.get(t.tid):
            continue
        verb = "drains" if t.defn.signature == "tier_drain" else "prefetches"
        yield _diag("IO303",
                    f"{t.defn.signature}#{t.tid} {verb} "
                    f"{t.sim.io_bytes:g} MB with no dependency on a "
                    f"producer: the movement can race whatever writes the "
                    f"data it moves — pass the payload Future "
                    f"(rt.drain(fut, ...))", t)


def _commit_like(sig: str) -> bool:
    s = sig.lower()
    return "commit" in s or "manifest" in s


def _shard_like(sig: str) -> bool:
    return "shard" in sig.lower()


def _rule_io304_manifest_order(ctx: _Ctx) -> Iterator[Diagnostic]:
    """A commit/manifest task must be ordered after every shard task
    submitted since the previous commit (the checkpoint protocol: a
    manifest that lands before its shards are durable publishes a
    checkpoint a restart cannot read)."""
    window: list[TaskInstance] = []
    for t in ctx.tasks:
        sig = t.defn.signature
        if _commit_like(sig):
            for s in window:
                if not ctx.ordered_before(s.tid, t.tid):
                    yield _diag("IO304",
                                f"commit/manifest task runs with no "
                                f"ordering after shard task "
                                f"{s.defn.signature}#{s.tid}: the manifest "
                                f"could publish a checkpoint whose shards "
                                f"are not yet durable — pass the shard "
                                f"futures into the commit task", t)
                    break
            window = []
        elif _shard_like(sig):
            window.append(t)


# --------------------------------------------------------------------------
# IO4xx — determinism
# --------------------------------------------------------------------------
def _rule_io401_unseeded_bursts(ctx: _Ctx) -> Iterator[Diagnostic]:
    eng = ctx.rt.interference
    if eng is None:
        return
    seen = set()
    for b in getattr(eng, "_bindings", ()):
        m = b.model
        if not isinstance(m, BurstyTraffic) or getattr(m, "seeded", True):
            continue
        if id(m) in seen:
            continue
        seen.add(id(m))
        yield Diagnostic("IO401",
                         f"BurstyTraffic bound to device "
                         f"{b.device.name!r} has no seed: the burst train "
                         f"is drawn from OS entropy, so runs are not "
                         f"reproducible — pass seed=<int>")


_RNG_NAMES = frozenset({"random", "uuid1", "uuid4", "urandom",
                        "getrandbits", "token_bytes", "token_hex",
                        "SystemRandom"})


def _code_rng_use(code, depth: int = 0) -> Optional[str]:
    hit = _RNG_NAMES.intersection(code.co_names)
    if hit:
        return sorted(hit)[0]
    if depth < 3:
        for const in code.co_consts:
            if hasattr(const, "co_names"):
                inner = _code_rng_use(const, depth + 1)
                if inner is not None:
                    return inner
    return None


def _rule_io402_rng_in_body(ctx: _Ctx) -> Iterator[Diagnostic]:
    seen = set()
    for t in ctx.tasks:
        sig = t.defn.signature
        if sig in seen:
            continue
        seen.add(sig)
        code = getattr(t.defn.fn, "__code__", None)
        if code is None:
            continue
        name = _code_rng_use(code)
        if name is not None:
            yield _diag("IO402",
                        f"task body references unseeded RNG source "
                        f"{name!r}: its output differs run to run — seed "
                        f"a generator outside the task and pass it in as "
                        f"an argument", t)


# --------------------------------------------------------------------------
# IO5xx — failure domains
# --------------------------------------------------------------------------
def _rule_io501_durable_tier_killed(ctx: _Ctx) -> Iterator[Diagnostic]:
    """The failure schedule takes every device of the catalog's durable
    tier offline and never brings one back: eviction drains and emergency
    re-drains have nowhere durable to land, so recovery queues forever
    (the run ends in a SchedulerError, or quiesces with undurable data)."""
    eng = getattr(ctx.rt, "failures", None)
    if eng is None:
        return
    cat = ctx.catalog
    if cat is None or not cat.enabled or cat.durable_tier is None:
        return
    devs = [d for d in ctx.cluster.devices if d.tier == cat.durable_tier]
    if devs and all(eng.final_state(d) == "offline" for d in devs):
        names = [d.name for d in devs]
        yield Diagnostic(
            "IO501",
            f"the failure schedule leaves every device of the durable tier "
            f"{cat.durable_tier!r} offline with no recovery ({names}): "
            f"eviction drains and emergency re-drains have nowhere durable "
            f"to land — add a recovery event or pick another durable_tier")


# --------------------------------------------------------------------------
# IO6xx — sharding (core.shardplane, docs/scale.md)
# --------------------------------------------------------------------------
def _shared_tier_names(cluster) -> set:
    """Tiers backed by a device that two or more workers reference — the
    lease-brokered cross-shard resources (per-worker SSDs never qualify).
    Matches :func:`repro.core.shardplane.shared_devices` for any shard
    count >= 2, so the diagnostics are shard-count-agnostic."""
    refs: dict[int, int] = {}
    tier_of: dict[int, Optional[str]] = {}
    for w in cluster.workers:
        for dev in w.tiers:
            refs[id(dev)] = refs.get(id(dev), 0) + 1
            tier_of[id(dev)] = dev.tier
    return {tier_of[i] for i, n in refs.items() if n > 1}


def _rule_io601_shard_pingpong(ctx: _Ctx) -> Iterator[Diagnostic]:
    """A dependency chain whose ``shard_key=`` anchors alternate workers:
    under any shard count that separates those anchor workers, every edge
    of the chain is a cross-shard DEP_DONE message and the consumer's
    placement loses its producer's locality. Anchors are compared at the
    *worker* level (``key % n_workers``), which is what makes the finding
    independent of the shard count the plan eventually runs with."""
    from ..core.shardplane import anchor_worker  # lazy: keep lint importable
    n_workers = len(ctx.cluster.workers)
    if n_workers < 2:
        return
    by_tid = {t.tid: t for t in ctx.tasks}
    seen = set()
    for t in ctx.tasks:
        if t.shard_key is None:
            continue
        a = anchor_worker(t.shard_key, n_workers)
        for ptid in ctx.future_inputs.get(t.tid, ()):
            p = by_tid.get(ptid)
            if p is None or p.shard_key is None:
                continue
            pa = anchor_worker(p.shard_key, n_workers)
            if pa == a:
                continue
            key = (p.defn.signature, t.defn.signature)
            if key in seen:
                continue
            seen.add(key)
            yield _diag(
                "IO601",
                f"shard_key={t.shard_key!r} anchors this task to worker "
                f"{a} but its producer {p.defn.signature}#{p.tid} is "
                f"anchored to worker {pa} (shard_key={p.shard_key!r}): the "
                f"chain ping-pongs across shards — every edge becomes a "
                f"cross-shard message and placement loses producer "
                f"locality; use one shard_key along a dependency chain", t)


def _rule_io602_shared_tier_fanout(ctx: _Ctx) -> Iterator[Diagnostic]:
    """An I/O task pinned to a *shared* tier (burst buffer / shared FS)
    whose readers are anchored to two or more distinct workers: its output
    object's residency updates broadcast to every shard, and all reader
    shards contend for the one lease-brokered device. Often intended —
    shared tiers are the designed cross-shard channel — but worth flagging
    when a per-worker tier would do."""
    from ..core.shardplane import anchor_worker  # lazy: keep lint importable
    n_workers = len(ctx.cluster.workers)
    if n_workers < 2:
        return
    shared = _shared_tier_names(ctx.cluster)
    if not shared:
        return
    reader_anchors: dict[int, set] = {}   # producer tid -> anchor workers
    for t in ctx.tasks:
        if t.shard_key is None:
            continue
        a = anchor_worker(t.shard_key, n_workers)
        for ptid in ctx.future_inputs.get(t.tid, ()):
            reader_anchors.setdefault(ptid, set()).add(a)
    seen = set()
    for t in ctx.io_tasks():
        if t.tier not in shared:
            continue
        anchors = reader_anchors.get(t.tid, ())
        if len(anchors) < 2:
            continue
        sig = t.defn.signature
        if sig in seen:
            continue
        seen.add(sig)
        yield _diag(
            "IO602",
            f"output pinned to shared tier {t.tier!r} is read by tasks "
            f"anchored to {len(anchors)} distinct workers "
            f"({sorted(anchors)}): every reader shard contends for the "
            f"one lease-brokered device and the object's residency "
            f"updates broadcast to all shards — expected for a designed "
            f"cross-shard exchange, otherwise keep the chain on one "
            f"shard_key or a per-worker tier", t)


_RULES = (
    _rule_io101_static_bw, _rule_io102_unknown_tier, _rule_io103_cpu_units,
    _rule_io104_auto_min,
    _rule_io201_oversized_object, _rule_io202_unevictable_footprint,
    _rule_io203_pin_leak, _rule_io204_finite_durable,
    _rule_io301_path_races, _rule_io302_read_after_discard,
    _rule_io303_payloadless_mover, _rule_io304_manifest_order,
    _rule_io401_unseeded_bursts, _rule_io402_rng_in_body,
    _rule_io501_durable_tier_killed,
    _rule_io601_shard_pingpong, _rule_io602_shared_tier_fanout,
)


def lint_runtime(rt) -> list[Diagnostic]:
    """Run every rule over the runtime's recorded plan. Deterministic
    output: sorted by (code, tid)."""
    ctx = _Ctx(rt)
    out: list[Diagnostic] = []
    for rule in _RULES:
        out.extend(rule(ctx))
    out.sort(key=lambda d: (d.code, d.tid if d.tid is not None else -1))
    return out


def lint_script(path: str, argv=None) -> tuple[list[Diagnostic], list[str]]:
    """Execute ``path`` under forced capture and lint every IORuntime it
    constructs. Returns ``(diagnostics, notes)`` — notes are harness
    observations (script raised after capture, nothing captured, ...), not
    diagnostics. Task bodies never run; script-level code does."""
    import runpy
    import sys

    from . import capture as cap

    cap.clear_registry()
    cap.set_force(True)
    notes: list[str] = []
    old_argv = sys.argv
    sys.argv = [path] + list(argv or [])
    try:
        runpy.run_path(path, run_name="__main__")
    except SystemExit as e:
        if e.code not in (0, None):
            notes.append(f"{path}: exited with status {e.code}")
    except BaseException as e:  # noqa: BLE001 — scripts may do anything;
        #                         the captured plan is still worth linting
        notes.append(f"{path}: raised {type(e).__name__} after capture "
                     f"({e}) — values are None under capture; guard "
                     f"result post-processing")
    finally:
        sys.argv = old_argv
        cap.set_force(False)
    runtimes = cap.registered()
    cap.clear_registry()
    if not runtimes:
        notes.append(f"{path}: no IORuntime constructed — nothing captured")
    diags: list[Diagnostic] = []
    for rt in runtimes:
        diags.extend(lint_runtime(rt))
    return diags, notes
