"""IOSan — inline runtime sanitizer for the discrete-event simulator.

``SimBackend(sanitize=True)`` calls :meth:`IOSanitizer.check` at every
event boundary of the simulation loop. The checks are the property-test
invariants (tests/test_properties.py) asserted *online*:

* device occupancy never exceeds capacity; no accounting counter negative;
* bandwidth claims (grants + co-tenant) never exceed the device budget;
* catalog residency agrees with device ``used_mb`` on every finite device;
* no scheduled reader on an object with no residency left (evicted);
* the simulation clock is monotonic; the scheduler's running set matches
  task states.

Every check is a pure read of runtime state — a sanitizer-on run produces
a launch log bit-identical to sanitizer-off. The first violation raises
:class:`SanitizerError` carrying the offending device/task and the recent
event trace (launch/complete ring buffer), instead of letting the
corruption surface as a confusing end-state assertion at the barrier.
"""
from __future__ import annotations

from collections import deque

from ..core.task import TaskState


class SanitizerError(AssertionError):
    """First invariant violation found by IOSan, with event trace."""


class IOSanitizer:
    """Event-boundary invariant checker driven by ``SimBackend``."""

    def __init__(self, trace_depth: int = 32):
        self.trace: deque = deque(maxlen=trace_depth)
        self.last_clock = float("-inf")
        self.n_checks = 0

    # ------------------------------------------------------------ event trace
    def record(self, kind: str, **info) -> None:
        self.trace.append((kind, info))

    def _fail(self, backend, msg: str) -> None:
        lines = [f"IOSan: {msg}",
                 f"  at t={backend.clock:.6f} "
                 f"(after {self.n_checks} clean checks)"]
        if self.trace:
            lines.append("  recent events (oldest first):")
            for kind, info in self.trace:
                detail = ", ".join(f"{k}={v}" for k, v in info.items())
                lines.append(f"    {kind}: {detail}")
        raise SanitizerError("\n".join(lines))

    # ---------------------------------------------------------------- checks
    def check(self, backend) -> None:
        """Assert every invariant; called by the sim loop at each event
        boundary. Read-only."""
        rt = backend.runtime
        if backend.clock < self.last_clock - 1e-9:
            self._fail(backend,
                       f"event time went backwards: {backend.clock} after "
                       f"{self.last_clock}")
        self.last_clock = backend.clock
        for dev in rt.cluster.devices:
            for msg in dev.check_invariants():
                self._fail(backend, msg)
        cat = rt.catalog
        if cat is not None and cat.enabled:
            self._check_catalog(backend, cat)
        graph = rt.graph
        for tid in rt.scheduler.running:
            t = graph.tasks.get(tid)
            if t is None or t.state != TaskState.RUNNING:
                state = "missing" if t is None else t.state.value
                self._fail(backend,
                           f"scheduler running-set lists task #{tid} but "
                           f"its graph state is {state}")
        self.n_checks += 1

    def _check_catalog(self, backend, cat) -> None:
        # residency <-> occupancy agreement: on every finite device, the
        # resident objects' sizes must sum to exactly what the device
        # accounts as committed (in-flight writers live in reserved_mb)
        for dev in cat._finite_devs:
            resident = cat._resident.get(id(dev), ())
            total = sum(o.size_mb for o in resident)
            if abs(total - dev.used_mb) > 1e-6:
                self._fail(backend,
                           f"residency/occupancy disagree on {dev.name}: "
                           f"resident objects sum to {total:.3f} MB but "
                           f"used_mb={dev.used_mb:.3f} "
                           f"({len(tuple(resident))} objects)")
        # an offline device holds nothing: on_device_offline must have
        # dropped every residency at the transition
        for dev in cat.cluster.devices:
            if dev.health != "offline":
                continue
            stale = cat._resident.get(id(dev))
            if stale:
                self._fail(backend,
                           f"offline device {dev.name} still lists "
                           f"{len(stale)} resident object(s): "
                           f"{sorted(o.name for o in stale)}")
        # no scheduled reader on an evicted object: eviction must never
        # select an object a submitted-but-unfinished consumer will read
        # (an object mid-recovery after a device failure is exempt — its
        # readers are exactly what the lineage re-run will re-feed)
        for obj in cat.objects.values():
            if obj.readers and not obj.residency and not obj.staging \
                    and not obj.recovering:
                self._fail(backend,
                           f"scheduled reader(s) {sorted(obj.readers)} on "
                           f"object {obj.name!r} with no residency left "
                           f"(evicted under a reader)")
