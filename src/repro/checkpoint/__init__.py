from .manager import CheckpointManager
