"""Checkpoint manager: async sharded saves routed through the I/O-aware
runtime (THE paper integration), atomic manifest commit, latest-valid
discovery for restart, elastic re-sharding restore.

Each shard write is an I/O task (``@io`` + ``storageBW="auto"`` by default):
it overlaps with subsequent train steps, and the auto-tuner learns how many
shards may write concurrently before the storage device congests — exactly
the paper's checkpointFrag scenario (§5.2.1).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from ..core import IORuntime, constraint, current_runtime, io, task
from .serializer import (flatten_with_paths, plan_shards, read_shard,
                         unflatten_like, write_shard)


@constraint(storageBW="auto", maxRetries=2)
@io
@task(returns=1)
def _write_shard_task(path_str, entries):
    return write_shard(Path(path_str), entries)


@io
@task(returns=1)
def _commit_task(manifest_path, step, frags, t0):
    frags = [f for f in frags]
    manifest = {"step": step, "shards": frags, "version": 1,
                "save_seconds": time.monotonic() - t0}
    tmp = Path(str(manifest_path) + ".tmp")
    tmp.write_text(json.dumps(manifest, indent=1))
    os.replace(tmp, manifest_path)  # atomic: manifest-last commit
    return manifest


class CheckpointManager:
    def __init__(self, directory, n_shards: int = 8,
                 overrun_policy: str = "skip", keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        self.overrun_policy = overrun_policy  # skip | wait
        self.keep = keep
        self._in_flight = None  # (step, commit future)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, sync: bool = False) -> bool:
        """Async save via the ambient IORuntime; sync=True (or no runtime)
        writes inline. Returns False if skipped due to an in-flight save."""
        rt = current_runtime()
        if self._in_flight is not None and rt is not None:
            prev_step, fut = self._in_flight
            if not fut.resolved():
                if self.overrun_policy == "skip" and not sync:
                    return False
                rt.wait_on(fut)
            self._in_flight = None

        host_leaves = [(k, np.asarray(jax.device_get(v)))
                       for k, v in flatten_with_paths(tree)]
        step_dir = self.dir / f"step_{step:08d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        plan = plan_shards(host_leaves, self.n_shards)
        t0 = time.monotonic()
        if rt is None or sync:
            frags = [write_shard(step_dir / f"shard_{i:04d}.bin", entries)
                     for i, entries in enumerate(plan) if entries]
            manifest = {"step": step, "shards": frags, "version": 1,
                        "save_seconds": time.monotonic() - t0}
            tmp = step_dir / "MANIFEST.json.tmp"
            tmp.write_text(json.dumps(manifest, indent=1))
            os.replace(tmp, step_dir / "MANIFEST.json")
        else:
            futs = [_write_shard_task(str(step_dir / f"shard_{i:04d}.bin"),
                                      entries,
                                      io_mb=sum(a.nbytes for _, a in entries)
                                      / 1e6)
                    for i, entries in enumerate(plan) if entries]
            commit = _commit_task(step_dir / "MANIFEST.json", step, futs, t0)
            self._in_flight = (step, commit)
        self._gc()
        return True

    def wait(self):
        rt = current_runtime()
        if self._in_flight is not None and rt is not None:
            rt.wait_on(self._in_flight[1])
            self._in_flight = None

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "MANIFEST.json").exists():
                try:
                    json.loads((d / "MANIFEST.json").read_text())
                    out.append(int(d.name.split("_")[1]))
                except (json.JSONDecodeError, ValueError):
                    continue  # torn manifest -> checkpoint doesn't exist
        return out

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None):
        """Rebuild the pytree; if ``shardings`` given, device_put each leaf
        with its (possibly different-mesh) sharding — elastic restart."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.dir}")
        step_dir = self.dir / f"step_{step:08d}"
        manifest = json.loads((step_dir / "MANIFEST.json").read_text())
        by_key: dict = {}
        for frag in manifest["shards"]:
            read_shard(step_dir / frag["file"], frag, by_key)
        tree = unflatten_like(like_tree, by_key)
        # dtypes: stored as raw numpy (bf16 saved as uint16 view? no — numpy
        # has no bf16; leaves were converted via device_get -> ml_dtypes)
        tree = jax.tree.map(
            lambda new, old: np.asarray(new).astype(old.dtype)
            if str(new.dtype) != str(old.dtype) else new, tree, like_tree)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, step

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
