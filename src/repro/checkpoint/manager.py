"""Checkpoint manager: async sharded saves routed through the I/O-aware
runtime (THE paper integration), atomic manifest commit, latest-valid
discovery for restart, elastic re-sharding restore.

Each shard write is an I/O task (``@io`` + ``storageBW="auto"`` by default):
it overlaps with subsequent train steps, and the auto-tuner learns how many
shards may write concurrently before the storage device congests — exactly
the paper's checkpointFrag scenario (§5.2.1).

Burst-buffer mode (``fast_dir=``): shards are first written to a fast tier
(node-local SSD / burst buffer directory), then *drained* to the shared
``directory`` by runtime-generated drain I/O tasks that overlap with
subsequent compute; the manifest commits on the shared FS only after every
shard has landed there (manifest-last stays atomic), so a restart never
sees a checkpoint whose shards still live only in the volatile fast tier.
On a tiered cluster the drain tasks carry a ``storage_tier="fs"`` hint so
the simulator/scheduler charges them to the shared-FS device.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import warnings
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from ..core import constraint, current_runtime, io, task
from ..core.runtime import copy_fsync
from .serializer import (flatten_with_paths, plan_shards, read_shard,
                         unflatten_like, write_shard)


@constraint(storageBW="auto", maxRetries=2)
@io
@task(returns=1)
def _write_shard_task(path_str, entries):
    return write_shard(Path(path_str), entries)


@constraint(maxRetries=2)
@io
@task(returns=1)
def _drain_shard_task(frag, src_path, dst_path):
    """Copy one shard from the fast tier to the shared FS (fsync'd), passing
    the manifest fragment through so the commit can depend on the drain."""
    copy_fsync(src_path, dst_path)
    return frag


def _write_manifest_atomic(manifest_path, manifest: dict) -> None:
    """Crash-atomic manifest publish: write tmp, fsync it, rename over the
    final name, fsync the directory. Without the two fsyncs (copy_fsync's
    pattern) "manifest-last" is not crash-consistent on a real FS — the
    rename can be durable while the manifest bytes (or the directory entry)
    are still only in the page cache, publishing a checkpoint a restart
    cannot read."""
    manifest_path = Path(manifest_path)
    tmp = Path(str(manifest_path) + ".tmp")
    with open(tmp, "w") as f:
        f.write(json.dumps(manifest, indent=1))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, manifest_path)  # atomic: manifest-last commit
    dfd = os.open(str(manifest_path.parent), os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


@io
@task(returns=1)
def _commit_task(manifest_path, step, frags, t0):
    frags = [f for f in frags]
    manifest = {"step": step, "shards": frags, "version": 1,
                "save_seconds": time.monotonic() - t0}
    _write_manifest_atomic(manifest_path, manifest)
    return manifest


class CheckpointManager:
    """``directory`` is the durable (shared-FS) home of checkpoints.
    ``fast_dir`` enables burst-buffer mode: async saves write shards there
    first and drain them to ``directory`` in the background; ``drain_bw``
    optionally throttles each drain stream (static MB/s or "auto") so the
    write-back doesn't congest the shared FS.

    Capacity-aware GC: the fast tier is finite (it's a burst buffer), so it
    is trimmed more aggressively than the durable copy — ``fast_keep``
    bounds how many steps' shards stay there (default ``min(keep, 1)``:
    only the in-flight/most recent save, since every older step is already
    durable on ``directory`` and restart never reads the fast tier)."""

    def __init__(self, directory, n_shards: int = 8,
                 overrun_policy: str = "skip", keep: int = 3,
                 fast_dir=None, drain_bw=None, fast_keep=None,
                 fast_tier: str = "bb"):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        self.overrun_policy = overrun_policy  # skip | wait
        self.keep = keep
        self.fast_dir = Path(fast_dir) if fast_dir is not None else None
        if self.fast_dir is not None:
            self.fast_dir.mkdir(parents=True, exist_ok=True)
        self.drain_bw = drain_bw
        if fast_keep is not None and fast_keep < 0:
            raise ValueError(f"fast_keep must be >= 0, got {fast_keep}")
        self.fast_keep = min(keep, 1) if fast_keep is None else int(fast_keep)
        self.fast_tier = fast_tier  # tier label backing fast_dir: when every
        #                             device of it is offline, saves reroute
        #                             shards to the shared FS directly
        self._in_flight = None  # (step, commit future)

    def _fast_tier_offline(self, rt) -> bool:
        """True when the cluster models the fast tier and every device
        backing it is offline — writing the burst there would just fail
        into retries that can never land, so ``save`` reroutes."""
        if rt is None:
            return False
        devs = [d for d in rt.cluster.devices if d.tier == self.fast_tier]
        return bool(devs) and all(d.health == "offline" for d in devs)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, sync: bool = False) -> bool:
        """Async save via the ambient IORuntime; sync=True (or no runtime)
        writes inline. Returns False if skipped due to an in-flight save."""
        rt = current_runtime()
        if self._in_flight is not None and rt is not None:
            prev_step, fut = self._in_flight
            if not fut.resolved():
                if self.overrun_policy == "skip" and not sync:
                    return False
                rt.wait_on(fut)
            self._in_flight = None

        host_leaves = [(k, np.asarray(jax.device_get(v)))
                       for k, v in flatten_with_paths(tree)]
        step_dir = self.dir / f"step_{step:08d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        plan = plan_shards(host_leaves, self.n_shards)
        t0 = time.monotonic()
        if rt is None or sync:
            mode = "sync"
            frags = [write_shard(step_dir / f"shard_{i:04d}.bin", entries)
                     for i, entries in enumerate(plan) if entries]
            manifest = {"step": step, "shards": frags, "version": 1,
                        "save_seconds": time.monotonic() - t0}
            _write_manifest_atomic(step_dir / "MANIFEST.json", manifest)
        elif self.fast_dir is None or self._fast_tier_offline(rt):
            # flat mode — also the failure-domain reroute: with the fast
            # tier dead, shards write straight to the durable directory
            # (fs-hinted so the scheduler charges the shared FS device)
            mode = "reroute" if self.fast_dir is not None else "flat"
            fs_hint = "fs" if self.fast_dir is not None \
                and rt.cluster.has_tier("fs") else None
            futs = [_write_shard_task(str(step_dir / f"shard_{i:04d}.bin"),
                                      entries,
                                      io_mb=sum(a.nbytes for _, a in entries)
                                      / 1e6, storage_tier=fs_hint)
                    for i, entries in enumerate(plan) if entries]
            commit = _commit_task(step_dir / "MANIFEST.json", step, futs, t0)
            self._in_flight = (step, commit)
        else:
            # burst-buffer mode: absorb the write burst on the fast tier,
            # drain to the shared FS asynchronously, commit manifest-last on
            # the shared FS once every shard has landed there
            mode = "burst-buffer"
            fast_step = self.fast_dir / f"step_{step:08d}"
            fast_step.mkdir(parents=True, exist_ok=True)
            fs_hint = "fs" if rt.cluster.has_tier("fs") else None
            drained = []
            for i, entries in enumerate(plan):
                if not entries:
                    continue
                name = f"shard_{i:04d}.bin"
                mb = sum(a.nbytes for _, a in entries) / 1e6
                wf = _write_shard_task(str(fast_step / name), entries,
                                       io_mb=mb)
                drained.append(_drain_shard_task(
                    wf, str(fast_step / name), str(step_dir / name),
                    io_mb=mb, storage_tier=fs_hint,
                    storage_bw=self.drain_bw))
            commit = _commit_task(step_dir / "MANIFEST.json", step,
                                  drained, t0)
            self._in_flight = (step, commit)
        rec = getattr(rt, "recorder", None)
        if rec is not None:
            rec.on_ckpt("save", step, mode,
                        sum(1 for entries in plan if entries))
        self._gc()
        return True

    def wait(self):
        rt = current_runtime()
        if self._in_flight is not None and rt is not None:
            step = self._in_flight[0]
            rt.wait_on(self._in_flight[1])
            self._in_flight = None
            rec = getattr(rt, "recorder", None)
            if rec is not None:
                rec.on_ckpt("wait", step, "async", 0)
            # the last save just became durable: one final fast-tier trim
            self._gc()

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "MANIFEST.json").exists():
                try:
                    json.loads((d / "MANIFEST.json").read_text())
                    out.append(int(d.name.split("_")[1]))
                except (json.JSONDecodeError, ValueError):
                    continue  # torn manifest -> checkpoint doesn't exist
        return out

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _check_step_durable(self, step: int) -> Optional[BaseException]:
        """Verify every shard the manifest names exists with the declared
        size; returns the violation (an IOError) or None when intact. A
        vanished shard (fast-tier loss after a partial drain) used to
        surface as a raw FileNotFoundError out of ``restore``."""
        step_dir = self.dir / f"step_{step:08d}"
        try:
            manifest = json.loads((step_dir / "MANIFEST.json").read_text())
        except (OSError, json.JSONDecodeError, ValueError) as e:
            return IOError(f"step {step}: unreadable manifest ({e})")
        for frag in manifest["shards"]:
            path = step_dir / frag["file"]
            if not path.exists():
                return IOError(
                    f"shard {path} missing (manifest names it with "
                    f"{frag['total_bytes']} bytes)")
            size = path.stat().st_size
            if size != frag["total_bytes"]:
                return IOError(f"shard {path} truncated: "
                               f"{size} != {frag['total_bytes']}")
        return None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None):
        """Rebuild the pytree; if ``shardings`` given, device_put each leaf
        with its (possibly different-mesh) sharding — elastic restart.

        Every candidate step is verified shard-complete before it is read;
        when the newest step is torn (a shard vanished or truncated — e.g.
        fast-tier loss after a partial drain) and no explicit ``step`` was
        requested, restore warns and falls back to the next-older durable
        step instead of crashing."""
        if step is not None:
            candidates = [step]
        else:
            candidates = list(reversed(self.steps()))
        if not candidates:
            raise FileNotFoundError(f"no valid checkpoint under {self.dir}")
        chosen = None
        err: Optional[BaseException] = None
        for i, s in enumerate(candidates):
            e = self._check_step_durable(s)
            if e is None:
                chosen = s
                if i > 0:
                    warnings.warn(
                        f"checkpoint step {candidates[0]} is torn ({err}); "
                        f"falling back to older durable step {s}",
                        RuntimeWarning, stacklevel=2)
                break
            if err is None:
                err = e
        if chosen is None:
            raise err  # newest (or requested) step torn, nothing older
        step = chosen
        step_dir = self.dir / f"step_{step:08d}"
        manifest = json.loads((step_dir / "MANIFEST.json").read_text())
        rt = current_runtime()
        rec = getattr(rt, "recorder", None)
        if rec is not None:
            rec.on_ckpt("restore", step, "durable",
                        len(manifest["shards"]))
        by_key: dict = {}
        for frag in manifest["shards"]:
            read_shard(step_dir / frag["file"], frag, by_key)
        tree = unflatten_like(like_tree, by_key)
        # dtypes: stored as raw numpy (bf16 saved as uint16 view? no — numpy
        # has no bf16; leaves were converted via device_get -> ml_dtypes)
        tree = jax.tree.map(
            lambda new, old: np.asarray(new).astype(old.dtype)
            if str(new.dtype) != str(old.dtype) else new, tree, like_tree)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, step

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
            if self.fast_dir is not None:
                shutil.rmtree(self.fast_dir / f"step_{s:08d}",
                              ignore_errors=True)
        if self.fast_dir is None:
            return
        # capacity-aware fast-tier GC: the burst buffer is finite, so it is
        # trimmed to fast_keep steps — but only steps already durable on the
        # shared directory (manifest committed), and never the in-flight
        # save whose shards may still be draining
        fast_steps = sorted(
            int(d.name.split("_")[1]) for d in self.fast_dir.glob("step_*"))
        durable = set(steps)
        in_flight = self._in_flight[0] if self._in_flight else None
        candidates = [s for s in fast_steps
                      if s in durable and s != in_flight]
        trim = candidates[:-self.fast_keep] if self.fast_keep else candidates
        # a superseded step that never became durable is a failed save (its
        # drains are dead; saves are serialized, so anything older than the
        # newest dispatched step is final) — its shards would otherwise leak
        # on the finite fast tier forever
        newest = in_flight if in_flight is not None else \
            (max(durable) if durable else None)
        if newest is not None:
            trim = trim + [s for s in fast_steps
                           if s not in durable and s < newest]
        for s in trim:
            shutil.rmtree(self.fast_dir / f"step_{s:08d}",
                          ignore_errors=True)
