"""Sharded pytree serialization.

Leaves are flattened with stable key paths, packed into N balanced shard
files of raw bytes, described by a manifest (written LAST -> atomic commit:
a checkpoint without a valid manifest does not exist). Restore validates
sizes and can re-shard onto any mesh (elastic restart, DESIGN.md §7).
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path

import jax
import numpy as np


def flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def plan_shards(leaves, n_shards: int):
    """Greedy size-balanced assignment: [(shard_idx, [(key, leaf), ...])]."""
    n_shards = max(1, n_shards)
    sizes = [0] * n_shards
    plan = [[] for _ in range(n_shards)]
    for key, leaf in sorted(leaves, key=lambda kl: -kl[1].nbytes):
        i = sizes.index(min(sizes))
        plan[i].append((key, leaf))
        sizes[i] += leaf.nbytes
    return plan


def write_shard(path: Path, entries) -> dict:
    """Write one shard file; returns manifest fragment. fsync'd (the paper's
    experiments bypass page cache the same way)."""
    meta = {}
    offset = 0
    with open(path, "wb") as f:
        for key, arr in entries:
            arr = np.asarray(arr)        # (ascontiguousarray would promote
            data = arr.tobytes()         #  0-d scalars to 1-d)
            f.write(data)
            meta[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                         "offset": offset, "nbytes": len(data)}
            offset += len(data)
        f.flush()
        os.fsync(f.fileno())
    return {"file": path.name, "entries": meta, "total_bytes": offset}


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # bf16 & friends (ships with jax)
        return np.dtype(getattr(ml_dtypes, name))


def read_shard(path: Path, frag: dict, out: dict) -> None:
    blob = path.read_bytes()
    if len(blob) != frag["total_bytes"]:
        raise IOError(f"shard {path} truncated: "
                      f"{len(blob)} != {frag['total_bytes']}")
    for key, m in frag["entries"].items():
        buf = blob[m["offset"]:m["offset"] + m["nbytes"]]
        out[key] = np.frombuffer(buf, dtype=_np_dtype(m["dtype"])) \
            .reshape(m["shape"])


def unflatten_like(tree, by_key: dict):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, old in paths:
        key = jax.tree_util.keystr(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_key[key]
        if tuple(arr.shape) != tuple(old.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {old.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
