"""``python -m repro.compare <script.py>`` — sim-vs-real validation CLI.

Runs the script's DAG twice with forced tracing (the ``repro.trace``
hijack) plus *forced backend substitution* (``obs.FORCE_BACKEND``):

1. **predicted leg** — every ``IORuntime`` the script constructs runs
   under a fresh ``SimBackend`` (a script that already asked for the
   simulator keeps its own backend, sanitizer flags and all);
2. **measured leg** — the same runtimes run under
   ``RealBackend(tier_dirs=)`` pointed at per-tier temp directories
   (``--tier-base``), executing the task bodies for real and collecting
   TelemetryHub throughput samples.

The two completed-task populations are aligned by (signature, submission
rank) and the per-task / per-tier / per-device model error is reported
(``repro.obs.compare``), together with the fitted-vs-configured
bandwidth per tier. ``--fit OUT.json`` additionally writes the fitted
tier config and re-runs the predicted leg with it applied — the
calibrated error is reported next to the default one (the sim_vs_real
benchmark asserts it shrinks).

Exit status: 0 on success, 2 on harness errors (missing file, script
crash, no runtime constructed, leg mismatch).
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
import tempfile

from . import obs
from .obs import compare as obs_compare
from .obs import perfetto
from .obs.telemetry import apply_tier_config, fit_tiers


def _reset_ids() -> None:
    """Fresh id spaces per leg so submission-rank alignment is exact even
    for scripts that rely on tids (e.g. in file names)."""
    from .core.datalife import DataObject
    from .core.task import DataHandle, TaskInstance
    TaskInstance._ids = itertools.count()
    DataHandle._ids = itertools.count()
    DataObject._ids = itertools.count()


def _sim_factory(tier_config=None):
    def factory(cluster, requested):
        from .core.backends import SimBackend
        if tier_config:
            apply_tier_config(cluster, tier_config)
        if isinstance(requested, SimBackend) and not tier_config:
            return None  # keep the script's own simulator (sanitize= etc.)
        return SimBackend()
    return factory


def _real_factory(tier_base: str):
    def factory(cluster, requested):
        from .core.backends import RealBackend
        if isinstance(requested, RealBackend):
            return None  # the script already runs for real; keep its dirs
        tier_dirs = {}
        for tier in cluster.tier_names():
            d = os.path.join(tier_base, tier)
            os.makedirs(d, exist_ok=True)
            tier_dirs[tier] = d
        return RealBackend(tier_dirs=tier_dirs)
    return factory


def _run_leg(path: str, factory) -> tuple[list, list[str]]:
    """Execute ``path`` once with forced tracing + backend substitution."""
    import runpy

    _reset_ids()
    obs.RUNS.clear()
    obs.FORCE = True
    obs.FORCE_BACKEND = factory
    notes: list[str] = []
    old_argv = sys.argv
    sys.argv = [path]
    try:
        runpy.run_path(path, run_name="__main__")
    except SystemExit as e:
        if e.code not in (0, None):
            notes.append(f"{path}: exited with status {e.code}")
    except BaseException as e:  # noqa: BLE001 — report what ran anyway
        notes.append(f"{path}: raised {type(e).__name__} ({e})")
    finally:
        sys.argv = old_argv
        obs.FORCE = False
        obs.FORCE_BACKEND = None
    runs = list(obs.RUNS)
    obs.RUNS.clear()
    return runs, notes


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.compare",
        description="Run a script once under SimBackend and once under "
                    "RealBackend(tier_dirs=) and report the sim-vs-real "
                    "model error (see docs/observability.md).")
    parser.add_argument("script", metavar="script.py",
                        help="Python script to run under both backends")
    parser.add_argument("--tier-base", metavar="DIR",
                        help="base directory for per-tier real I/O "
                             "(default: a fresh temp directory)")
    parser.add_argument("--fit", metavar="OUT.json",
                        help="write the fitted tier config and re-run the "
                             "predicted leg with it applied")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable report (one JSON doc)")
    parser.add_argument("--perfetto", metavar="OUT.json",
                        help="export the measured leg's Chrome trace-event "
                             "JSON (per runtime; -1, -2, ... suffixes)")
    args = parser.parse_args(argv)

    path = args.script
    if not os.path.isfile(path):
        print(f"repro.compare: no such file: {path}", file=sys.stderr)
        return 2
    tier_base = args.tier_base or tempfile.mkdtemp(prefix="repro_compare_")

    status = 0
    sim_runs, notes = _run_leg(path, _sim_factory())
    real_runs, real_notes = _run_leg(path, _real_factory(tier_base))
    for note in notes + real_notes:
        print(f"note: {note}", file=sys.stderr)
        status = 2
    if not sim_runs or not real_runs:
        print(f"repro.compare: {path}: no IORuntime constructed — "
              f"nothing to compare", file=sys.stderr)
        return 2
    if len(sim_runs) != len(real_runs):
        print(f"repro.compare: {path}: leg mismatch — {len(sim_runs)} "
              f"sim runtime(s) vs {len(real_runs)} real; the script must "
              f"construct the same runtimes under both backends",
              file=sys.stderr)
        return 2

    fitted_cfg = None
    fitted_runs: list = []
    if args.fit:
        # fit from every measured runtime's hub, merged (later runtimes
        # win ties — same tier labels measure the same directories)
        fitted_cfg = {}
        for _, rt in real_runs:
            hub = getattr(rt.backend, "telemetry", None)
            if hub is not None:
                fitted_cfg.update(fit_tiers(hub))
        with open(args.fit, "w") as f:
            json.dump({"script": path, "tiers": fitted_cfg}, f, indent=2,
                      sort_keys=True)
        print(f"fitted tier config written: {args.fit}", file=sys.stderr)
        if fitted_cfg:
            fitted_runs, fit_notes = _run_leg(
                path, _sim_factory(tier_config=fitted_cfg))
            for note in fit_notes:
                print(f"note: {note}", file=sys.stderr)
                status = 2

    doc = []
    for i, ((label, sim_rt), (_, real_rt)) in enumerate(
            zip(sim_runs, real_runs), start=1):
        rep = obs_compare.duration_error_report(sim_rt, real_rt)
        fit_rep = obs_compare.tier_fit_report(real_rt, sim_rt.cluster)
        entry = {"script": path, "runtime": label, "report": rep,
                 "tier_fit": fit_rep}
        if fitted_runs and i <= len(fitted_runs):
            frep = obs_compare.duration_error_report(
                fitted_runs[i - 1][1], real_rt)
            entry["report_fitted"] = frep
        if args.as_json:
            slim = dict(entry)
            slim["report"] = {k: v for k, v in rep.items() if k != "tasks"}
            if "report_fitted" in entry:
                slim["report_fitted"] = {
                    k: v for k, v in entry["report_fitted"].items()
                    if k != "tasks"}
            doc.append(slim)
        else:
            print(f"== {path} {label} ==")
            print(obs_compare.format_report(rep, fit_rep))
            if "report_fitted" in entry:
                fmed = entry["report_fitted"]["median_abs_rel_error"]
                dmed = rep["median_abs_rel_error"]
                print("calibrated median |rel err|: "
                      + (f"{fmed:.3g}" if fmed is not None else "n/a")
                      + (f" (default {dmed:.3g})"
                         if dmed is not None else ""))
            print()
        if args.perfetto:
            rec = real_rt.recorder
            if rec is not None:
                root, ext = os.path.splitext(args.perfetto)
                out = args.perfetto if len(real_runs) == 1 \
                    else f"{root}-{i}{ext or '.json'}"
                with open(out, "w") as f:
                    f.write(perfetto.dumps(rec))
                print(f"perfetto trace written: {out}", file=sys.stderr)
    if args.as_json:
        print(json.dumps(doc, indent=2, sort_keys=True))
    return status


if __name__ == "__main__":
    raise SystemExit(main())
