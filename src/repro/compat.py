"""Version compatibility shims for jax.

The repo targets the modern ``jax.shard_map`` API; on jax <= 0.4.x that
entry point lives in ``jax.experimental.shard_map`` (keyword ``check_rep``
instead of ``check_vma``). Import :func:`shard_map` from here instead of
from jax directly.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5
    shard_map = jax.shard_map
except AttributeError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map  # type: ignore

# the no-check kwarg rename (check_rep -> check_vma) happened independently
# of shard_map's promotion to the jax namespace: detect by signature
try:
    _VMA_KW = ("check_vma" if "check_vma"
               in inspect.signature(shard_map).parameters else "check_rep")
except (TypeError, ValueError):  # pragma: no cover - exotic wrapper
    _VMA_KW = "check_rep"


def shard_map_no_check(f, mesh, in_specs, out_specs):
    """shard_map with replication/VMA checking disabled, across jax versions
    (the keyword was renamed check_rep -> check_vma)."""
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **{_VMA_KW: False})


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` across jax versions: 0.4.x returns a
    one-element list of dicts, newer jax returns the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def tpu_compiler_params():
    """``pallas.tpu.CompilerParams`` class across jax versions (it was named
    ``TPUCompilerParams`` until jax 0.5.x)."""
    from jax.experimental.pallas import tpu as pltpu
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
