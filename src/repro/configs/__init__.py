"""Architecture registry: --arch <id> resolves here."""
from . import (granite_20b, granite_34b, hubert_xlarge,
               llava_next_mistral_7b, mamba2_2_7b, mixtral_8x22b,
               qwen2_moe_a2_7b, smollm_360m, tinyllama_1_1b, zamba2_1_2b)
from .base import SHAPES, ModelConfig, ShapeCell, cell_supported

_MODULES = {
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "smollm-360m": smollm_360m,
    "granite-34b": granite_34b,
    "granite-20b": granite_20b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "mixtral-8x22b": mixtral_8x22b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "zamba2-1.2b": zamba2_1_2b,
    "hubert-xlarge": hubert_xlarge,
    "mamba2-2.7b": mamba2_2_7b,
}

ARCHS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()
