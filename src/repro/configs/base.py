"""ModelConfig + the assigned input-shape grid (DESIGN.md §6).

Every architecture file exports ``CONFIG`` (full size, exercised only via
the dry-run) and ``smoke_config()`` (reduced, runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0           # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    moe_aux_weight: float = 0.01
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 6
    # attention
    causal: bool = True
    sliding_window: int = 0     # 0 = full attention
    rope_theta: float = 10000.0
    # input mode: tokens | embeds (audio frontend stub) | vlm (patch stub)
    input_mode: str = "tokens"
    vision_seq: int = 1152      # VLM: patch-embedding prefix length
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: object = jnp.bfloat16
    remat: bool = True
    unroll_layers: bool = False   # python loop instead of lax.scan (used by
    #                               the analytic-roofline validation probe)
    use_flash: bool = False
    use_ssd_kernel: bool = False
    decode_batch_replicated: bool = False

    # which shape cells run (DESIGN.md §6: skips are per-spec, documented)
    supports_decode: bool = True
    subquadratic: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embedding included once)."""
        D, L = self.d_model, self.n_layers
        n = 0
        emb = self.vocab_size * D
        if self.input_mode in ("tokens", "vlm"):
            n += emb * (1 if self.tie_embeddings else 2)
        else:
            n += self.vocab_size * D  # classifier head
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * D
            H = d_in // self.ssm_headdim
            per = D * (2 * d_in + 2 * self.ssm_state + H) + d_in * D \
                + 4 * (d_in + 2 * self.ssm_state)
            n += per * L
            if self.family == "hybrid":
                hd = D // self.n_heads
                attn = 2 * D * (self.n_heads + 2 * self.n_kv_heads) * hd \
                    + self.n_heads * hd * D
                n += attn + 3 * D * self.d_ff
            return n
        hd = self.head_dim or D // self.n_heads
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * hd \
            + self.n_heads * hd * D
        if self.n_experts:
            ffn = 3 * D * self.moe_d_ff * self.n_experts \
                + 3 * D * self.shared_d_ff + D * self.n_experts
        else:
            ffn = 3 * D * self.d_ff
        n += (attn + ffn) * L
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        dense = self.replace(n_experts=0, d_ff=0)
        n = dense.param_count()
        D, L = self.d_model, self.n_layers
        n += (3 * D * self.moe_d_ff * self.n_experts_per_tok
              + 3 * D * self.shared_d_ff + D * self.n_experts) * L
        return n


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(supported, reason-if-not) per the assignment's skip rules."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full attention is quadratic at 500k (per spec)"
    return True, ""
