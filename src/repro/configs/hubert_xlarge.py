"""hubert-xlarge [audio]: encoder-only (bidirectional); the conv waveform
frontend is a STUB — input_specs provides precomputed frame embeddings (per
assignment). No decode shapes. [arXiv:2106.07447; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder", input_mode="embeds",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, causal=False, supports_decode=False,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=32, remat=False)
