"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, anyres vision frontend
as a STUB (input_specs provides precomputed patch embeddings, per
assignment). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", input_mode="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, vision_seq=1152,
    subquadratic=False,  # full attention -> long_500k skipped (DESIGN §6)
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, vision_seq=8, remat=False)
