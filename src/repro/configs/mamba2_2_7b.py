"""mamba2-2.7b [ssm]: attention-free SSD (state-space duality); decode is a
recurrent state update, so every decode shape (incl. long_500k) runs.
Vocab 50280 padded to 50304 internally. [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2,
    tie_embeddings=True, subquadratic=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=3, d_model=64, vocab_size=256,
                          ssm_state=16, ssm_headdim=16, ssm_chunk=8,
                          remat=False)
