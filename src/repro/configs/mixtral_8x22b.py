"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention (per the
assignment spec) -> SWA makes long_500k decode sub-quadratic with a rolling
W=4096 KV cache. [arXiv:2401.04088; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768,
    n_experts=8, n_experts_per_tok=2, moe_d_ff=16384,
    sliding_window=4096, subquadratic=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, moe_d_ff=128, n_experts=4,
                          n_experts_per_tok=2, vocab_size=256,
                          sliding_window=16, remat=False)
