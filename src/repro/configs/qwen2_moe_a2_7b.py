"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared experts
(modelled as one dense FFN of 4*1408). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151936,
    n_experts=60, n_experts_per_tok=4, moe_d_ff=1408, shared_d_ff=5632,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=96, moe_d_ff=96, shared_d_ff=96, n_experts=8,
                          n_experts_per_tok=4, vocab_size=256, remat=False)
