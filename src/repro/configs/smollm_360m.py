"""smollm-360m [dense]: llama-arch small; 15 heads (indivisible by a 16-way
model axis -> attention weights replicate, MLP still TP-shards).
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49152, tie_embeddings=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
                          d_ff=160, vocab_size=256, remat=False)
