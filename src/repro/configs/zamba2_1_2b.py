"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block every 6
layers (Zamba concat-with-embedding trick; per-application LoRA omitted,
DESIGN.md). [arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_state=64, ssm_headdim=64, ssm_expand=2,
    attn_every=6, subquadratic=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, ssm_state=16,
                          ssm_headdim=16, ssm_chunk=8, attn_every=2,
                          remat=False)
