"""repro.core — the paper's contribution: an I/O-aware task runtime.

Public API (PyCOMPSs-flavoured, paper §4):
    @task(returns=..., param=INOUT)   declare a task
    @io                                mark it an I/O task (overlaps compute)
    @constraint(storageBW=...)         static / "auto" / "auto(min,max,delta)"
    IORuntime(cluster, backend)        master runtime (sim or real backend)
    wait_on(fut)                       compss_wait_on
"""
from .autotune import DriftConfig
from .backends import RealBackend, SimBackend
from .constraints import AutoSpec, StaticSpec, parse_storage_bw
from .datalife import (DataCatalog, DataObject, EvictionPolicy,
                       LifecycleConfig, LRUEviction, TierCapacity)
from .failures import FailureEngine, FailureEvent, FailureSchedule
from .interference import (Burst, BurstyTraffic, ConstantTraffic,
                           InterferenceEngine, TraceTraffic, TrafficModel)
from .resources import Cluster, StorageDevice, WorkerNode
from .runtime import IORuntime, constraint, current_runtime, io, task, wait_on
from .scheduler import SchedulerError
from .storage_model import (aggregate_throughput, cross_tier_time,
                            expected_task_time, max_concurrent_tasks,
                            per_task_rate, read_floor_time)
from .task import IN, INOUT, OUT, DataHandle, Direction, Future, TaskState

# analysis itself imports the core submodules above, so its names are
# re-exported lazily (PEP 562) — an eager import here would be circular
# whenever repro.analysis is the import entry point (the lint CLI).
_ANALYSIS_EXPORTS = ("CaptureBackend", "Diagnostic", "IOSanitizer",
                     "SanitizerError")


def __getattr__(name):
    if name in _ANALYSIS_EXPORTS:
        from .. import analysis
        return getattr(analysis, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "CaptureBackend", "Diagnostic", "IOSanitizer", "SanitizerError",
    "task", "io", "constraint", "wait_on", "IORuntime", "current_runtime",
    "SimBackend", "RealBackend", "Cluster", "WorkerNode", "StorageDevice",
    "AutoSpec", "StaticSpec", "parse_storage_bw", "SchedulerError",
    "IN", "INOUT", "OUT", "Direction", "DataHandle", "Future", "TaskState",
    "DataCatalog", "DataObject", "EvictionPolicy", "LifecycleConfig",
    "LRUEviction", "TierCapacity",
    "Burst", "BurstyTraffic", "ConstantTraffic", "DriftConfig",
    "InterferenceEngine", "TraceTraffic", "TrafficModel",
    "FailureEngine", "FailureEvent", "FailureSchedule",
    "aggregate_throughput", "per_task_rate", "expected_task_time",
    "max_concurrent_tasks", "cross_tier_time", "read_floor_time",
]
