"""Automatic inference of storage-bandwidth constraints (paper §3.3, §4.2.3).

One :class:`AutoTuner` exists per auto-constrained task *signature* (the
paper assumes an I/O task produces the same workload for the whole run and
runs a separate learning phase per task). The tuner walks *learning epochs*:

* an epoch uses one constraint value ``c`` and admits up to
  ``k = min(floor(B / c), io_executors)`` concurrent tasks on the dedicated
  *active-learning node*;
* the epoch ends when every admitted task has completed; its average task
  time is recorded;
* **bounded** ``auto(min,max,delta)``: c walks min -> max multiplying by
  delta, every epoch is registered;
* **unbounded** ``auto``: c starts at ``max(1, floor(B / io_executors))`` and
  doubles; the phase continues only while ``t_i <= t_{i-1} / 2`` — the
  violating epoch is *not* registered.

After the phase, :meth:`choose` applies the objective function
``T(n, c) = ceil(n / k_c) * t_c`` (remainder counts as one extra execution
group, per paper §4.2.3C) and returns the registered constraint minimising
it; ties go to the highest constraint (least congestion). ``choose`` is
re-evaluated every time new requests arrive, so the constraint tracks the
pending-task count.

Drift adaptation (interference-aware tuning)
--------------------------------------------
The learned ``t_c`` values are a snapshot of the device *as it behaved
during calibration*. On shared tiers a co-tenant (interference.py) changes
the effective device over time, so the curve goes stale. With a
:class:`DriftConfig`, the tuner keeps a sliding window of
observed-vs-predicted time ratios for steady-phase tasks
(:meth:`AutoTuner.observe`); when the window's median leaves
``[1/threshold, threshold]`` the tuner **re-enters calibration** over the
constraints it already measured, blending each re-measured epoch with the
decayed stale value (``prior_weight``) instead of either trusting the
isolated fit or discarding history outright. The scheduler sees
``learning() == True`` again and re-runs the usual isolated learning-node
protocol — on the *interfered* device, which is the point.
"""
from __future__ import annotations

import enum
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .constraints import AutoSpec
from .storage_model import max_concurrent_tasks


@dataclass(frozen=True)
class DriftConfig:
    """Windowed observed-vs-predicted drift detector parameters.

    ``window`` steady-phase observations are kept per tuner (ratios of
    observed task time to the registry's prediction for the granted
    constraint); once at least ``min_observations`` are present and their
    median exceeds ``threshold`` (slower: congestion appeared) or falls
    below ``1/threshold`` (faster: congestion went away), the tuner
    re-enters calibration. Each re-measured constraint is blended as
    ``(1 - prior_weight) * new + prior_weight * stale``.
    """

    window: int = 12
    min_observations: int = 6
    threshold: float = 1.6
    prior_weight: float = 0.25
    #: ``"all"`` re-measures every registered constraint (a full, slower
    #: re-walk); ``"active"`` re-measures only the constraint whose
    #: observations drifted — one epoch, so the tuner tracks regime flips
    #: (bursty on/off co-tenants) without stalling its class for a full
    #: calibration each time
    recal_scope: str = "active"
    #: under the cross-tier objective, every Nth steady grant probes the
    #: runner-up tier so abandoned tiers keep producing observations (an
    #: argmin with no fresh data can never drift back)
    probe_every: int = 8

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if not (1 <= self.min_observations <= self.window):
            raise ValueError(
                f"min_observations must be in [1, window={self.window}], "
                f"got {self.min_observations}")
        if self.threshold <= 1.0:
            raise ValueError(
                f"threshold must exceed 1.0 (a ratio of 1 means the curve "
                f"is exact), got {self.threshold}")
        if not (0.0 <= self.prior_weight < 1.0):
            raise ValueError(
                f"prior_weight must be in [0, 1), got {self.prior_weight}")
        if self.recal_scope not in ("all", "active"):
            raise ValueError(
                f"recal_scope must be 'all' or 'active', got "
                f"{self.recal_scope!r}")
        if self.probe_every < 2:
            raise ValueError(
                f"probe_every must be >= 2 (1 would always probe), got "
                f"{self.probe_every}")


class Phase(enum.Enum):
    LEARNING = "learning"
    DONE = "done"


@dataclass
class Epoch:
    constraint: float
    target_k: int
    admitted: int = 0
    completed: int = 0
    total_time: float = 0.0
    closed_admission: bool = False

    @property
    def avg_time(self) -> float:
        return self.total_time / self.completed if self.completed else float("inf")

    def full(self) -> bool:
        return self.closed_admission or self.admitted >= self.target_k

    def done(self) -> bool:
        return (self.closed_admission or self.admitted >= self.target_k) \
            and self.completed >= self.admitted and self.admitted > 0


class AutoTuner:
    """Learning-phase driver + objective function for one task signature."""

    def __init__(self, signature: str, spec: AutoSpec, device_bw: float,
                 io_executors: int, drift: Optional[DriftConfig] = None):
        self.signature = signature
        self.spec = spec
        self.device_bw = float(device_bw)
        self.io_executors = int(io_executors)
        self.registry: dict[float, float] = {}   # constraint -> avg task time
        self.phase = Phase.LEARNING
        self.history: list[tuple[float, float]] = []  # (constraint, avg) per epoch
        if spec.bounded:
            start = float(spec.min)
        else:
            start = float(max(1, int(self.device_bw // max(1, self.io_executors))))
        self.epoch = self._new_epoch(start)
        self._last_choice: Optional[float] = None
        self._choice_counts: dict[float, int] = {}
        self._draining = False
        # drift adaptation (None: steady-phase observations are ignored and
        # behaviour is exactly the static paper tuner)
        self.drift = drift
        self._obs: deque = deque(maxlen=drift.window if drift else 1)
        self.n_recalibrations = 0
        self._recal_schedule: Optional[list[float]] = None  # constraints to
        #                                                     re-measure
        self._recal_idx = 0
        self._stale_prior: dict[float, float] = {}

    # -- epoch machinery ------------------------------------------------------
    def _k_for(self, c: float) -> int:
        return min(max_concurrent_tasks(self.device_bw, c), self.io_executors)

    def _new_epoch(self, c: float) -> Epoch:
        return Epoch(constraint=c, target_k=self._k_for(c))

    def learning(self) -> bool:
        return self.phase == Phase.LEARNING

    def current_constraint(self) -> float:
        return self.epoch.constraint

    def admit(self) -> bool:
        """Try to admit one task into the current epoch. Returns False when
        the epoch is full (the task must wait for the next epoch)."""
        if not self.learning() or self.epoch.full():
            return False
        self.epoch.admitted += 1
        return True

    def on_task_complete(self, duration: float) -> None:
        """Called by the scheduler when an epoch-member task finishes."""
        e = self.epoch
        e.completed += 1
        e.total_time += duration
        if e.done():
            self._advance()

    def end_of_stream(self) -> None:
        """No more tasks of this signature will arrive (barrier/shutdown):
        close admission so a partially-filled epoch can still conclude."""
        if not self.learning():
            return
        self._draining = True
        e = self.epoch
        e.closed_admission = True
        if e.admitted == 0:
            # nothing ran in this epoch; finish with whatever is registered
            self._finish()
        elif e.done():
            self._advance()

    def _advance(self) -> None:
        e = self.epoch
        self.history.append((e.constraint, e.avg_time))
        if self._recal_schedule is not None:
            # drift recalibration: re-measure the constraints already in
            # the registry, blending each with its decayed stale prior
            self._register_measurement(e.constraint, e.avg_time)
            self._recal_idx += 1
            if self._draining or self._recal_idx >= len(self._recal_schedule):
                self._finish()
            else:
                self.epoch = self._new_epoch(
                    self._recal_schedule[self._recal_idx])
            return
        if self._draining:
            # no more arrivals: register what we measured and conclude
            self.registry[e.constraint] = e.avg_time
            self._finish()
            return
        if self.spec.bounded:
            self.registry[e.constraint] = e.avg_time
            nxt = e.constraint * self.spec.delta
            if nxt > self.spec.max + 1e-9:
                self._finish()
            else:
                self.epoch = self._new_epoch(nxt)
        else:
            prev = self._prev_registered_time()
            if prev is None:
                self.registry[e.constraint] = e.avg_time
                self.epoch = self._new_epoch(e.constraint * 2.0)
            elif e.avg_time <= prev / 2.0 + 1e-12:
                self.registry[e.constraint] = e.avg_time
                self.epoch = self._new_epoch(e.constraint * 2.0)
            else:
                # continuation condition violated: epoch NOT registered
                self._finish()
        # a new epoch whose k tasks can never run (k==0 impossible; k>=1) is fine

    def _prev_registered_time(self) -> Optional[float]:
        if not self.registry:
            return None
        # last registered epoch time
        last_c = max(self.registry)  # constraints strictly increase over epochs
        return self.registry[last_c]

    def _finish(self) -> None:
        self.phase = Phase.DONE
        self._recal_schedule = None
        self._stale_prior = {}
        if not self.registry:
            # degenerate: nothing learned; fall back to the starting constraint
            self.registry[self.epoch.constraint] = self.epoch.avg_time \
                if self.epoch.completed else 1.0

    # -- drift adaptation (interference-aware tuning) --------------------------
    def _register_measurement(self, c: float, new_avg: float) -> None:
        prior = self._stale_prior.get(c)
        if prior is not None and self.drift is not None \
                and math.isfinite(new_avg):
            w = self.drift.prior_weight
            self.registry[c] = (1.0 - w) * new_avg + w * prior
        else:
            self.registry[c] = new_avg

    def observe(self, constraint: float, duration: float) -> None:
        """Steady-phase feedback: a granted task ran under ``constraint``
        and took ``duration``. Compares against the learned prediction and
        re-enters calibration when the window's median ratio drifts out of
        band. No-op without a :class:`DriftConfig`, while learning, or once
        the stream is draining (recalibrating at a final barrier would
        stall on epochs that can never fill)."""
        if self.drift is None or self.learning() or self._draining:
            return
        pred = self.registry.get(constraint)
        if pred is None or pred <= 0 or duration <= 0:
            return
        self._obs.append(duration / pred)
        cfg = self.drift
        if len(self._obs) < cfg.min_observations:
            return
        med = sorted(self._obs)[len(self._obs) // 2]
        if med > cfg.threshold or med < 1.0 / cfg.threshold:
            self._reenter_calibration(constraint)

    def _reenter_calibration(self, drifted_c: float) -> None:
        """The learned curve went stale: re-measure on the live
        (interfered) device, keeping the old values as a decayed prior.
        Scope per config: every registered constraint, or just the one
        whose observations drifted (cheap enough to track regime flips)."""
        self._obs.clear()
        self.n_recalibrations += 1
        self._stale_prior = dict(self.registry)
        if self.drift.recal_scope == "active" \
                and drifted_c in self.registry:
            self._recal_schedule = [drifted_c]
        else:
            self._recal_schedule = sorted(self.registry)
        self._recal_idx = 0
        self.phase = Phase.LEARNING
        self.epoch = self._new_epoch(self._recal_schedule[0])

    # -- objective function (paper §3.3.2) ------------------------------------
    def objective_time(self, num_tasks: int, c: float) -> float:
        k = self._k_for(c)
        t = self.registry[c]
        if num_tasks <= 0:
            return 0.0
        groups = num_tasks // k
        rem = num_tasks % k
        total = groups * t
        if rem:
            total += t  # remainder estimated as one extra execution group
        return total

    def peek_choice(self, num_tasks: int) -> float:
        """Constraint minimising T(num_tasks, c); ties -> highest c.

        Pure: safe to call on every placement attempt. Bookkeeping happens in
        :meth:`record_choice` only when the placement is actually granted, so
        ``choice_counts`` reflects launched tasks rather than retries."""
        if not self.registry:
            return self.epoch.constraint
        best_c, best_t = None, None
        for c in sorted(self.registry):
            t = self.objective_time(num_tasks, c)
            if best_t is None or t < best_t - 1e-12 or \
                    (abs(t - best_t) <= 1e-12 and c > best_c):
                best_c, best_t = c, t
        return best_c

    def record_choice(self, c: float) -> None:
        self._last_choice = c
        self._choice_counts[c] = self._choice_counts.get(c, 0) + 1

    def choose(self, num_tasks: int) -> float:
        """peek + record in one step (the paper's re-evaluated objective)."""
        c = self.peek_choice(num_tasks)
        self.record_choice(c)
        return c

    def drift_state(self) -> dict:
        """Snapshot of the steady-phase drift window: how many
        observed-vs-predicted ratios are pending and their current median
        (None until the first observation). Pure read — the telemetry
        rollup and the sim-vs-real harness surface it."""
        obs = list(self._obs)
        med = sorted(obs)[len(obs) // 2] if obs else None
        return {"n_obs": len(obs), "median_ratio": med}

    def summary(self) -> dict:
        return {
            "signature": self.signature,
            "phase": self.phase.value,
            "registry": dict(self.registry),
            "history": list(self.history),
            "last_choice": self._last_choice,
            # the constraint used for the bulk of the run (the last choice can
            # differ for a small final backlog — ties go to the highest
            # constraint, paper §4.2.3C / §5.2.1)
            "modal_choice": max(self._choice_counts,
                                key=self._choice_counts.get)
            if self._choice_counts else None,
            "choice_counts": dict(self._choice_counts),
            "n_recalibrations": self.n_recalibrations,
            "drift_enabled": self.drift is not None,
            "drift_window": self.drift_state(),
        }
