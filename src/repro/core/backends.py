"""Execution backends.

:class:`SimBackend` — deterministic discrete-event simulator with a virtual
clock. Compute tasks take their declared ``sim.duration``; I/O tasks move
``sim.io_bytes`` MB through the congestion model of their device
(storage_model.py), with per-task rates recomputed at every arrival/departure
(piecewise-linear integration). Used by the paper-figure benchmarks and the
property tests — bit-for-bit reproducible.

:class:`RealBackend` — thread pools per worker (a compute platform sized to
``cpus`` and an I/O platform sized to ``io_executors``, paper Fig. 7), wall
clock, real user functions (real ``write``+``fsync`` for I/O tasks). Used by
the end-to-end training driver for async checkpointing.
"""
from __future__ import annotations

import heapq
import itertools
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

from .scheduler import SchedulerError
from .storage_model import per_task_rate
from .task import Future, TaskInstance, TaskState, TaskType

_EPS = 1e-9


class Backend:
    """Interface the runtime drives."""

    #: trace recorder (obs/), picked up from the runtime at bind; None
    #: keeps every event site a single comparison away from doing nothing
    recorder = None

    def bind(self, runtime) -> None:
        self.runtime = runtime
        self.recorder = getattr(runtime, "recorder", None)

    def launch(self, task: TaskInstance, worker) -> None:
        raise NotImplementedError

    def drain(self, predicate: Callable[[], bool]) -> None:
        raise NotImplementedError

    def on_submitted(self) -> None:
        pass

    def now(self) -> float:
        raise NotImplementedError

    def shutdown(self) -> None:
        pass


# --------------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------------
class SimBackend(Backend):
    """Discrete-event simulator with an O(log n) event queue.

    Events live in a single lazy-deletion ``heapq``: each running task has at
    most one *current* entry (a per-tid version counter supersedes older
    ones). An entry's time is a lower-bound estimate of the task's true
    finish time — exact while the task's device keeps its I/O population, and
    an under-estimate after more streams join (rates only drop, so the true
    time moves later). When a stream *leaves* a device the per-task rate
    rises and old estimates would be late, so entries for that device are
    eagerly re-pushed — devices expose monotonically increasing epochs
    (resources.py): ``rate_epoch`` for any population change and
    ``release_epoch`` for rate-RAISING changes only; the refresh keys on the
    latter, because lower-bound estimates survive allocations unharmed.
    ``_next_event_time`` then pops candidates, recomputes
    their exact finish time at the current clock (the same arithmetic the
    per-event linear scan used, so results are bit-identical), and returns
    the minimum.
    """

    #: estimates within this window of the best candidate are recomputed
    #: exactly (covers float drift between push-time and pop-time arithmetic)
    _GUARD = 1e-9

    def __init__(self, sanitize: bool = False):
        self.clock = 0.0
        self._compute: dict[int, tuple[TaskInstance, float]] = {}  # tid -> (task, end)
        self._io: dict[int, list] = {}  # tid -> [task, remaining_mb, min_end]
        # co-tenant traffic (interference.py); None keeps every code path —
        # and all arithmetic — identical to the interference-free simulator
        self.interference = None
        # failure domains (failures.py); None (or an empty schedule) keeps
        # the simulator byte-identical to a failure-free run
        self.failures = None
        # IOSan (repro.analysis.sanitizer): event-boundary invariant checks.
        # All checks are pure reads, so sanitize=True leaves the launch log
        # bit-identical; None costs one comparison per loop iteration.
        self.sanitizer = None
        if sanitize:
            from ..analysis.sanitizer import IOSanitizer  # lazy: no cycle
            self.sanitizer = IOSanitizer()
        self.io_busy_time = 0.0         # union over devices of I/O activity
        self.compute_busy_time = 0.0
        self.overlap_time = 0.0         # time with BOTH compute and I/O active
        self.total_io_mb = 0.0
        self.peak_io_mbs = 0.0          # max sustained aggregate I/O rate
        # --- event queue state ---
        self._heap: list[tuple[float, int, int, int]] = []  # (est, seq, tid, ver)
        self._entry_ver: dict[int, int] = {}                # tid -> live version
        self._push_seq = itertools.count()
        self._launch_seq = itertools.count()                # seed-order pop ties
        self._dev_tasks: dict[int, tuple] = {}   # id(dev) -> (dev, set[tid])
        self._dev_epoch_seen: dict[int, int] = {}  # id(dev) -> release_epoch
        # sharded control plane (core.shardplane): one event heap per shard,
        # batch-scanned per event step so each shard's queue stays short.
        # bind() splits the heaps when the runtime's scheduler is sharded;
        # unsharded, _heaps[0] IS _heap (the same list object) and every
        # push/scan/pop runs the exact arithmetic above — launch logs stay
        # bit-identical.
        self._heaps: list[list] = [self._heap]
        self._tid_shard: Optional[dict[int, int]] = None  # tid -> shard

    def now(self) -> float:
        return self.clock

    def bind(self, runtime) -> None:
        super().bind(runtime)
        n = getattr(runtime.scheduler, "n_shards", 1)
        if n > 1:
            self._heaps = [[] for _ in range(n)]
            self._tid_shard = {}

    def attach_interference(self, engine) -> None:
        """Bind an InterferenceEngine: burst boundaries become simulation
        events, co-tenant streams join each device's congestion model."""
        self.interference = engine if engine is not None and engine.active \
            else None
        if self.interference is not None:
            # bursts starting at the current clock (t=0 co-tenants) must
            # hold their budgets before the first schedule pass runs
            self.interference.apply_due(self.clock)

    def attach_failures(self, engine) -> None:
        """Bind a FailureEngine: scheduled health transitions become
        simulation events, peer to the interference engine's bursts."""
        self.failures = engine if engine is not None and engine.active \
            else None
        if self.failures is not None:
            # t=0 events (a tier down from the start) take effect before
            # the first schedule pass; nothing is running or resident yet,
            # so the transitions need no reroute/re-drain handling
            self.failures.apply_due(self.clock)

    # ---------------------------------------------------------- event queue
    def _push_entry(self, tid: int, est: float) -> None:
        ver = self._entry_ver.get(tid, 0) + 1
        self._entry_ver[tid] = ver
        heap = self._heap if self._tid_shard is None \
            else self._heaps[self._tid_shard[tid]]
        heapq.heappush(heap, (est, next(self._push_seq), tid, ver))

    def _true_finish(self, rec: list) -> float:
        task, rem, min_end = rec
        dev = task.device or task.worker.storage
        # co-tenant streams share the device fairly (0 without interference:
        # the arithmetic — and thus the golden launch log — is unchanged)
        rate = per_task_rate(dev, dev.active_io + dev.background_streams)
        eta = self.clock + rem / rate if rate > 0 else float("inf")
        return max(eta, min_end)

    def _refresh_stale_devices(self) -> None:
        """Re-push estimates for every task on a device whose per-task rate
        *rose* since the last check (lazy deletion leaves the superseded
        entries to be skipped on pop).

        Only releases raise rates — per-task rate is non-increasing in the
        stream count — and only a rate rise can turn an existing lower-bound
        estimate stale-late, so allocations (launch bursts) cost nothing
        here: their entries are merely early and get tightened lazily."""
        for dev_id, (dev, tids) in self._dev_tasks.items():
            if not tids:
                continue
            if self._dev_epoch_seen.get(dev_id) == dev.release_epoch:
                continue
            self._dev_epoch_seen[dev_id] = dev.release_epoch
            # one rate per device (all its tasks share the fair-share rate);
            # same arithmetic as _true_finish, hoisted out of the tid loop.
            # _push_entry is inlined below — this loop re-keys every task
            # of every stale device and is the single largest source of
            # heap pushes at the 1M-task bench scale
            rate = per_task_rate(dev, dev.active_io + dev.background_streams)
            clock = self.clock
            io = self._io
            ver_map = self._entry_ver
            push_seq = self._push_seq
            tid_shard = self._tid_shard
            heaps = self._heaps
            heappush = heapq.heappush
            inf = float("inf")
            for tid in tids:
                if rate > 0:
                    rec = io[tid]
                    est = clock + rec[1] / rate
                    min_end = rec[2]
                    if est < min_end:
                        est = min_end
                else:
                    est = inf
                ver = ver_map.get(tid, 0) + 1
                ver_map[tid] = ver
                heap = heaps[0] if tid_shard is None \
                    else heaps[tid_shard[tid]]
                heappush(heap, (est, next(push_seq), tid, ver))

    def launch(self, task: TaskInstance, worker) -> None:
        task.start_time = self.clock
        task._sim_seq = next(self._launch_seq)
        if self._tid_shard is not None:
            self._tid_shard[task.tid] = task.shard
        if self.sanitizer is not None:
            self.sanitizer.record(
                "launch", t=self.clock, tid=task.tid,
                sig=task.defn.signature, worker=worker.name,
                device=task.device.name if task.device is not None else None)
        if self.recorder is not None:
            self.recorder.on_launch(task, worker)
        # read_penalty: the data-lifecycle catalog's simulated cost of
        # pulling tracked inputs from their fastest resident tier (0.0
        # unless the lifecycle subsystem is active — grant-time snapshot)
        dur = task.sim.duration + task.read_penalty
        if task.defn.task_type == TaskType.COMPUTE:
            end = self.clock + max(dur, _EPS)
            self._compute[task.tid] = (task, end)
            self._push_entry(task.tid, end)
        else:
            rem = max(task.sim.io_bytes, 0.0)
            min_end = self.clock + max(dur, _EPS)
            rec = [task, rem, min_end]
            self._io[task.tid] = rec
            # the device the scheduler granted (a tier of the worker); falls
            # back to the worker's primary device for bare/legacy launches
            dev = task.device or worker.storage
            entry = self._dev_tasks.get(id(dev))
            if entry is None:
                entry = self._dev_tasks[id(dev)] = (dev, set())
            entry[1].add(task.tid)
            self._push_entry(task.tid, self._true_finish(rec))

    def _next_event_time(self) -> float:
        best = float("inf")
        for heap in self._heaps:
            t = self._scan_heap(heap)
            if t < best:
                best = t
        return best

    def _scan_heap(self, heap: list) -> float:
        """Exact next event time within one shard's heap (the whole queue,
        unsharded). The global next event is the min across shards — each
        scan pops candidates within ``_GUARD`` of its own best, recomputes
        their true finish at the current clock, and re-pushes."""
        ver = self._entry_ver
        best = float("inf")
        repush = []
        # same once-per-device rate cache as _advance_to: the scan is pure
        # reads, so every candidate on one device sees one fair-share rate
        rates: dict[int, float] = {}
        clock = self.clock
        while heap:
            est, _, tid, v = heap[0]
            if est > best + self._GUARD:
                break
            heapq.heappop(heap)
            if ver.get(tid) != v:
                continue  # superseded or finished: lazy deletion
            if tid in self._compute:
                true = self._compute[tid][1]
            elif tid in self._io:
                task, rem, min_end = self._io[tid]
                dev = task.device or task.worker.storage
                key = id(dev)
                rate = rates.get(key)
                if rate is None:
                    rate = rates[key] = per_task_rate(
                        dev, dev.active_io + dev.background_streams)
                # inlined _true_finish with the cached rate
                eta = clock + rem / rate if rate > 0 else float("inf")
                true = eta if eta > min_end else min_end
            else:
                continue
            if true < best:
                best = true
            repush.append((true, tid))
        for true, tid in repush:
            self._push_entry(tid, true)
        return best

    def _advance_to(self, t: float) -> None:
        dt = t - self.clock
        if dt <= 0:
            self.clock = t
            return
        io_active = bool(self._io)
        comp_active = bool(self._compute)
        if io_active:
            self.io_busy_time += dt
        if comp_active:
            self.compute_busy_time += dt
        if io_active and comp_active:
            self.overlap_time += dt
        interval_mb = 0.0
        # per-device fair-share rate, computed once per event instead of
        # once per in-flight record: device stream counts are constant for
        # the whole interval, so the cached float is the exact value
        # per_task_rate would return for every record on that device
        rates: dict[int, float] = {}
        for rec in self._io.values():
            task, rem, _ = rec
            dev = task.device or task.worker.storage
            key = id(dev)
            rate = rates.get(key)
            if rate is None:
                rate = rates[key] = per_task_rate(
                    dev, dev.active_io + dev.background_streams)
            moved = min(rem, rate * dt)
            rec[1] = rem - moved
            dev.bytes_written += moved
            self.total_io_mb += moved
            interval_mb += moved
            if rec[1] <= 1e-6 < rem:
                # transfer finished off its own event (float ties): from here
                # the task's exact finish is its min_end — re-key its entry
                self._push_entry(task.tid, max(t, rec[2]))
        if dt > 1e-6 and interval_mb > 0:
            self.peak_io_mbs = max(self.peak_io_mbs, interval_mb / dt)
        self.clock = t

    def _finish_io(self, tid: int) -> TaskInstance:
        task, _, _ = self._io.pop(tid)
        self._entry_ver.pop(tid, None)
        if self._tid_shard is not None:
            self._tid_shard.pop(tid, None)
        dev = task.device or task.worker.storage
        self._dev_tasks[id(dev)][1].discard(tid)
        return task

    def _pop_due(self) -> list[TaskInstance]:
        due_c: list[TaskInstance] = []
        due_io: list[TaskInstance] = []
        repush: list[tuple[int, float]] = []
        horizon = self.clock + _EPS
        for heap in self._heaps:
            self._pop_due_heap(heap, horizon, due_c, due_io, repush)
        # re-push AFTER draining the horizon: a tightened estimate can land
        # back inside it (fast devices: rem in MB vs horizon in seconds) and
        # re-pushing inside the loop would pop it again forever
        for tid, est in repush:
            self._push_entry(tid, est)
        # the seed popped compute tasks then I/O tasks, each in launch order
        # (the per-shard batches merge into the same global order: _sim_seq
        # is assigned from one counter at launch)
        due_c.sort(key=lambda t: t._sim_seq)
        due_io.sort(key=lambda t: t._sim_seq)
        return due_c + due_io

    def _pop_due_heap(self, heap: list, horizon: float,
                      due_c: list, due_io: list, repush: list) -> None:
        """Drain one shard's heap up to ``horizon`` into the shared due
        batches (the whole event queue, unsharded)."""
        ver = self._entry_ver
        while heap and heap[0][0] <= horizon:
            _, _, tid, v = heapq.heappop(heap)
            if ver.get(tid) != v:
                continue
            if tid in self._compute:
                task, end = self._compute[tid]
                if end <= horizon:
                    del self._compute[tid]
                    del ver[tid]
                    if self._tid_shard is not None:
                        self._tid_shard.pop(tid, None)
                    due_c.append(task)
                else:  # defensive: estimate undershot the fixed end
                    repush.append((tid, end))
            elif tid in self._io:
                rec = self._io[tid]
                if rec[1] <= 1e-6 and rec[2] <= horizon:
                    due_io.append(self._finish_io(tid))
                else:  # estimate was early (device gained streams): tighten
                    repush.append((tid, self._true_finish(rec)))

    # ------------------------------------------------------ failure domains
    def _fail_attempt(self, task: TaskInstance, error: BaseException) -> bool:
        """One attempt of ``task`` failed (injected fault or its device went
        offline). While attempts remain (``maxRetries``, same arithmetic as
        RealBackend._run: ``max_retries + 1`` attempts, ``task.retries``
        counting failed ones) the task re-enters the ready queue for a
        fresh grant — on a surviving eligible device — and True is
        returned; otherwise the task is FAILED and False is returned (the
        caller resolves futures and hands it to the runtime)."""
        task.retries += 1
        if task.retries <= task.defn.max_retries:
            if self.sanitizer is not None:
                self.sanitizer.record(
                    "retry", t=self.clock, tid=task.tid,
                    sig=task.defn.signature, attempt=task.retries)
            if self.recorder is not None:
                self.recorder.on_retry(task)
            self.runtime._requeue_retry(task)
            return True
        task.state = TaskState.FAILED
        if task.error is None:
            task.error = error
        return False

    def _on_failure_transitions(self, transitions) -> None:
        """Health transitions just fired: fail in-flight I/O on newly
        offline devices into the retry path, then let the runtime drop
        lost residencies and synthesize re-drains/lineage recovery."""
        rt = self.runtime
        san = self.sanitizer
        offline = []
        for dev, prev, new in transitions:
            if san is not None:
                san.record("health", t=self.clock, device=dev.name,
                           prev=prev, state=new)
            if new == "offline" and prev != "offline":
                offline.append(dev)
        for dev in offline:
            entry = self._dev_tasks.get(id(dev))
            if entry is None or not entry[1]:
                continue
            # deterministic order: launch order, like _pop_due
            tids = sorted(entry[1], key=lambda tid: self._io[tid][0]._sim_seq)
            for tid in tids:
                task = self._finish_io(tid)
                task.end_time = self.clock
                err = RuntimeError(
                    f"device {dev.name} went offline under "
                    f"{task.defn.name}#{task.tid}")
                if self._fail_attempt(task, err):
                    continue
                if self.recorder is not None:
                    self.recorder.on_complete(task, failed=True)
                for f in task.futures:
                    f.set_value(None)
                rt._handle_completion(task)
        if offline:
            rt._on_health_change(offline)
        rt.scheduler._dirty = True
        self._refresh_stale_devices()

    #: in the nothing-running branch, at most this many consecutive burst
    #: boundaries are stepped through looking for one that unblocks a grant
    #: before the scheduler is declared stuck (bounds the wait on infinite
    #: burst trains when the blockage is unrelated to interference)
    _BG_STUCK_LIMIT = 512

    def _bg_step(self, eng) -> bool:
        """Advance the clock to the next co-tenant burst boundary and apply
        it (nothing of ours is running). Returns True when a boundary was
        applied — a burst end releases bandwidth/capacity that may unblock
        a ready task; a burst start can push a tier over its watermark and
        let the lifecycle tick make eviction progress."""
        t = eng.next_time()
        if t == float("inf"):
            return False
        if t > self.clock:
            self._advance_to(t)
        eng.apply_due(self.clock)
        if self.recorder is not None:
            self.recorder.on_stall(self.clock, "bg_step")
        self._refresh_stale_devices()
        self.runtime.scheduler._dirty = True
        self.runtime._lifecycle_tick()
        return True

    def _fail_step(self, feng) -> bool:
        """Advance to the next scheduled health transition and apply it
        (nothing of ours is running): a recovery can make a pinned tier's
        devices eligible again and unblock the queued class."""
        t = feng.next_time()
        if t == float("inf"):
            return False
        if t > self.clock:
            self._advance_to(t)
        transitions = feng.apply_due(self.clock)
        if transitions:
            self._on_failure_transitions(transitions)
        if self.recorder is not None:
            self.recorder.on_stall(self.clock, "fail_step")
        self._refresh_stale_devices()
        self.runtime.scheduler._dirty = True
        self.runtime._lifecycle_tick()
        return True

    def drain(self, predicate: Callable[[], bool]) -> None:
        rt = self.runtime
        eng = self.interference
        feng = self.failures
        bg_retries = 0
        san = self.sanitizer
        while True:
            if rt.scheduler.schedule_pass():
                bg_retries = 0
            # no refresh needed here: launches only allocate (rates drop),
            # which leaves existing estimates as valid lower bounds
            if san is not None:
                san.check(self)  # event boundary: after grants, before step
            if predicate():
                return
            if not self._compute and not self._io:
                # nothing running: either stalled learning epochs or done
                if rt.scheduler.n_ready:
                    # a capacity-blocked task may just need an eviction —
                    # give the lifecycle a chance before declaring stuck
                    if rt._lifecycle_tick():
                        continue
                    # gentle unstick first (close partial learning epochs
                    # and retry — the interference-free behaviour); only if
                    # that still leaves nothing placeable may a co-tenant
                    # burst be holding the budget/capacity: step to the
                    # next burst boundary and try again
                    try:
                        rt.scheduler.assert_not_stuck()
                    except SchedulerError:
                        if bg_retries < self._BG_STUCK_LIMIT and (
                                (eng is not None and self._bg_step(eng))
                                or (feng is not None
                                    and self._fail_step(feng))):
                            bg_retries += 1
                            continue
                        raise
                    continue
                if predicate():
                    return
                raise SchedulerError(
                    f"simulation drained but predicate unmet "
                    f"(unfinished={rt.graph.unfinished})")
            bg_retries = 0
            t = self._next_event_time()
            if eng is not None:
                t = min(t, eng.next_time())
            if feng is not None:
                t = min(t, feng.next_time())
            if t == float("inf"):
                raise SchedulerError("no next event with tasks running")
            self._advance_to(t)
            for task in self._pop_due():
                task.end_time = self.clock
                fail_spec = task.sim.fail
                # sim_fail=True fails every attempt; sim_fail=N only the
                # first N (task.retries counts failed attempts so far)
                inject = fail_spec is True or \
                    (fail_spec and task.retries < int(fail_spec))
                if san is not None:
                    san.record("complete", t=self.clock, tid=task.tid,
                               sig=task.defn.signature,
                               failed=bool(inject))
                if inject:
                    # fault injection (sim_fail= at call time): the task
                    # consumed its resources and time, then this attempt
                    # FAILs — retried under maxRetries exactly like
                    # RealBackend (a re-placement is a fresh grant); once
                    # attempts are exhausted the runtime cancels its
                    # data-descendants. Non-raising: post-mortem inspection
                    # happens via graph states.
                    if self._fail_attempt(task, RuntimeError(
                            f"injected failure: "
                            f"{task.defn.name}#{task.tid}")):
                        continue
                if self.recorder is not None:
                    self.recorder.on_complete(task, failed=bool(inject))
                for f in task.futures:
                    f.set_value(None)
                rt._handle_completion(task)
            if eng is not None and eng.apply_due(self.clock):
                # burst boundaries at this instant: budgets/rates changed —
                # retry placement, and let a capacity burst that crossed a
                # watermark trigger eviction planning
                rt.scheduler._dirty = True
                rt._lifecycle_tick()
            if feng is not None:
                # health transitions at this instant: completions at t won
                # the tie (a task that finishes as its device dies counts
                # as finished), then in-flight work on dead devices fails
                # into the retry path and the catalog starts recovery
                transitions = feng.apply_due(self.clock)
                if transitions:
                    self._on_failure_transitions(transitions)
                    rt._lifecycle_tick()
            self._refresh_stale_devices()  # releases raised device rates
            if san is not None:
                san.check(self)  # event boundary: completions + bursts done


# --------------------------------------------------------------------------
# Real (threaded) backend
# --------------------------------------------------------------------------
class RealBackend(Backend):
    """Threaded backend. ``tier_dirs`` maps tier labels to directories
    (e.g. ``{"ssd": "/nvme/scratch", "fs": "/gpfs/ckpt"}``) so runtime-
    generated drain/prefetch tasks can move files between tiers; see
    ``IORuntime.drain``/``IORuntime.prefetch``."""

    def __init__(self, poll_interval: float = 0.02,
                 tier_dirs: Optional[dict] = None):
        self._t0 = time.monotonic()
        self._pools: dict[tuple[str, str], ThreadPoolExecutor] = {}
        self._cv = threading.Condition()  # rebound to runtime.lock in bind()
        self._poll = poll_interval
        self._failed: list[TaskInstance] = []
        self.tier_dirs = dict(tier_dirs) if tier_dirs else {}
        # measured per-device throughput (obs/telemetry.py): fed on every
        # I/O launch/complete; always collecting (cheap — one dict/deque
        # update per op), emitted as trace events only when the run is
        # traced. The simulator has no hub, which is what gates the
        # stats()["telemetry"] key to real runs.
        from ..obs.telemetry import TelemetryHub  # lazy: obs pulls nothing
        #                                           from core, but keep the
        #                                           import edge one-way
        self.telemetry = TelemetryHub()

    def tier_path(self, tier: str, name: str) -> Optional[str]:
        """Absolute path of ``name`` inside ``tier``'s directory, or None
        when the tier has no directory mapping."""
        base = self.tier_dirs.get(tier)
        if base is None:
            return None
        return os.path.join(str(base), name)

    def bind(self, runtime) -> None:
        super().bind(runtime)
        # validate tier_dirs keys against the cluster's actual tier labels
        # up front: an unknown key used to be silently ignored and surfaced
        # much later as a confusing per-task "no tier_dirs directory" error.
        # Only enforced when the cluster models a hierarchy — on a single-
        # tier cluster the labels are plain directory names for tier-
        # agnostic path= movement, not modelled tiers.
        tiers = runtime.cluster.tier_names()
        unknown = sorted(k for k in self.tier_dirs
                         if not runtime.cluster.has_tier(k))
        if unknown and len(tiers) > 1:
            raise ValueError(
                f"RealBackend tier_dirs key(s) {unknown} name no storage "
                f"tier in the cluster (tiers: "
                f"{runtime.cluster.tier_names()}) — a path= drain/prefetch "
                f"targeting them could never resolve its endpoint")
        self._cv = threading.Condition(runtime.lock)
        self.telemetry.bind(self.recorder)

    def now(self) -> float:
        return time.monotonic() - self._t0

    def _pool(self, worker, platform: str) -> ThreadPoolExecutor:
        key = (worker.name, platform)
        if key not in self._pools:
            size = worker.cpus if platform == "compute" else worker.io_executors
            self._pools[key] = ThreadPoolExecutor(
                max_workers=max(1, size),
                thread_name_prefix=f"{worker.name}-{platform}")
        return self._pools[key]

    @staticmethod
    def _resolve(arg, _depth=0):
        if isinstance(arg, Future):
            return arg.value()
        if _depth < 4:
            if isinstance(arg, list):
                return [RealBackend._resolve(v, _depth + 1) for v in arg]
            if isinstance(arg, tuple):
                return tuple(RealBackend._resolve(v, _depth + 1) for v in arg)
            if isinstance(arg, dict):
                return {k: RealBackend._resolve(v, _depth + 1)
                        for k, v in arg.items()}
        return arg

    def launch(self, task: TaskInstance, worker) -> None:
        platform = "compute" if task.defn.task_type == TaskType.COMPUTE else "io"
        task.start_time = self.now()
        if task.defn.task_type == TaskType.IO and task.device is not None:
            # launch-side concurrency snapshot: the fit harness groups
            # samples by the depth the op ran under (launch is always under
            # the runtime lock — submit/schedule_pass hold it)
            task._telemetry_k = self.telemetry.on_launch(
                task.start_time, task.device)
        if self.recorder is not None:
            self.recorder.on_launch(task, worker)
        self._pool(worker, platform).submit(self._run, task)

    def _run(self, task: TaskInstance) -> None:
        args = tuple(self._resolve(a) for a in task.args)
        kwargs = {k: self._resolve(v) for k, v in task.kwargs.items()}
        err: Optional[BaseException] = None
        result = None
        attempts = task.defn.max_retries + 1
        for attempt in range(attempts):
            attempt_t0 = time.monotonic()
            try:
                result = task.defn.fn(*args, **kwargs)
                # measured wall time of the successful attempt alone: the
                # signal the drift monitor compares against the learned
                # curve (task.duration would also count pool queueing,
                # argument resolution and earlier attempts' backoff)
                task.measured_duration = time.monotonic() - attempt_t0
                err = None
                break
            except BaseException as e:  # noqa: BLE001 — report at barrier
                err = e
                task.retries = attempt + 1
                if attempt + 1 < attempts:
                    time.sleep(min(0.05 * (2 ** attempt), 1.0))
        task.end_time = self.now()
        if err is not None:
            task.error = err
            task.state = TaskState.FAILED
        if task.defn.returns > 1 and isinstance(result, tuple):
            for f, v in zip(task.futures, result):
                f.set_value(v)
        else:
            task.futures[0].set_value(result)
        with self._cv:
            if task.defn.task_type == TaskType.IO and task.device is not None:
                # measured sample under the runtime lock (same critical
                # section as the complete event, so trace order matches)
                self.telemetry.on_complete(
                    task.end_time, task.device, task.sim.io_bytes,
                    task.measured_duration, failed=task.error is not None,
                    launch_inflight=task._telemetry_k)
            if self.recorder is not None:
                # RealBackend retries in-place inside this worker thread, so
                # a failed attempt never re-enters the ready queue — the
                # whole retry loop lands in this one complete event
                self.recorder.on_complete(task, failed=task.error is not None)
            self.runtime._handle_completion(task)
            if task.error is not None:
                self._failed.append(task)
            self._cv.notify_all()

    def on_submitted(self) -> None:
        with self._cv:
            self.runtime.scheduler.schedule_pass()

    def drain(self, predicate: Callable[[], bool]) -> None:
        rt = self.runtime
        with self._cv:
            while True:
                rt.scheduler.schedule_pass()
                if self._failed:
                    t = self._failed[0]
                    raise RuntimeError(
                        f"task {t.defn.name}#{t.tid} failed after "
                        f"{t.retries} attempt(s)") from t.error
                if predicate():
                    return
                if not rt.scheduler.running and rt.scheduler.n_ready:
                    if rt._lifecycle_tick():
                        continue
                    rt.scheduler.assert_not_stuck()
                    continue
                self._cv.wait(timeout=self._poll)

    def shutdown(self) -> None:
        for p in self._pools.values():
            p.shutdown(wait=True)
        self._pools.clear()
