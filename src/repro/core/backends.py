"""Execution backends.

:class:`SimBackend` — deterministic discrete-event simulator with a virtual
clock. Compute tasks take their declared ``sim.duration``; I/O tasks move
``sim.io_bytes`` MB through the congestion model of their device
(storage_model.py), with per-task rates recomputed at every arrival/departure
(piecewise-linear integration). Used by the paper-figure benchmarks and the
property tests — bit-for-bit reproducible.

:class:`RealBackend` — thread pools per worker (a compute platform sized to
``cpus`` and an I/O platform sized to ``io_executors``, paper Fig. 7), wall
clock, real user functions (real ``write``+``fsync`` for I/O tasks). Used by
the end-to-end training driver for async checkpointing.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

from .scheduler import Scheduler, SchedulerError
from .storage_model import per_task_rate
from .task import DataHandle, Future, TaskInstance, TaskState, TaskType

_EPS = 1e-9


class Backend:
    """Interface the runtime drives."""

    def bind(self, runtime) -> None:
        self.runtime = runtime

    def launch(self, task: TaskInstance, worker) -> None:
        raise NotImplementedError

    def drain(self, predicate: Callable[[], bool]) -> None:
        raise NotImplementedError

    def on_submitted(self) -> None:
        pass

    def now(self) -> float:
        raise NotImplementedError

    def shutdown(self) -> None:
        pass


# --------------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------------
class SimBackend(Backend):
    def __init__(self):
        self.clock = 0.0
        self._compute: dict[int, tuple[TaskInstance, float]] = {}  # tid -> (task, end)
        self._io: dict[int, list] = {}  # tid -> [task, remaining_mb, min_end]
        self.io_busy_time = 0.0         # union over devices of I/O activity
        self.compute_busy_time = 0.0
        self.overlap_time = 0.0         # time with BOTH compute and I/O active
        self.total_io_mb = 0.0
        self.peak_io_mbs = 0.0          # max sustained aggregate I/O rate

    def now(self) -> float:
        return self.clock

    def launch(self, task: TaskInstance, worker) -> None:
        task.start_time = self.clock
        if task.defn.task_type == TaskType.COMPUTE:
            self._compute[task.tid] = (task, self.clock + max(task.sim.duration, _EPS))
        else:
            rem = max(task.sim.io_bytes, 0.0)
            min_end = self.clock + max(task.sim.duration, _EPS)
            self._io[task.tid] = [task, rem, min_end]

    def _next_event_time(self) -> float:
        t = float("inf")
        for _, end in self._compute.values():
            t = min(t, end)
        # group io tasks per device for rate computation
        for task, rem, min_end in self._io.values():
            dev = task.worker.storage
            rate = per_task_rate(dev, dev.active_io)
            eta = self.clock + rem / rate if rate > 0 else float("inf")
            t = min(t, max(eta, min_end))
        return t

    def _advance_to(self, t: float) -> None:
        dt = t - self.clock
        if dt <= 0:
            self.clock = t
            return
        io_active = bool(self._io)
        comp_active = bool(self._compute)
        if io_active:
            self.io_busy_time += dt
        if comp_active:
            self.compute_busy_time += dt
        if io_active and comp_active:
            self.overlap_time += dt
        interval_mb = 0.0
        for rec in self._io.values():
            task, rem, _ = rec
            dev = task.worker.storage
            rate = per_task_rate(dev, dev.active_io)
            moved = min(rem, rate * dt)
            rec[1] = rem - moved
            dev.bytes_written += moved
            self.total_io_mb += moved
            interval_mb += moved
        if dt > 1e-6 and interval_mb > 0:
            self.peak_io_mbs = max(self.peak_io_mbs, interval_mb / dt)
        self.clock = t

    def _pop_due(self) -> list[TaskInstance]:
        due = []
        for tid in list(self._compute):
            task, end = self._compute[tid]
            if end <= self.clock + _EPS:
                del self._compute[tid]
                due.append(task)
        for tid in list(self._io):
            task, rem, min_end = self._io[tid]
            if rem <= 1e-6 and min_end <= self.clock + _EPS:
                del self._io[tid]
                due.append(task)
        return due

    def drain(self, predicate: Callable[[], bool]) -> None:
        rt = self.runtime
        while True:
            rt.scheduler.schedule_pass()
            if predicate():
                return
            if not self._compute and not self._io:
                # nothing running: either stalled learning epochs or done
                if rt.scheduler.ready:
                    rt.scheduler.assert_not_stuck()
                    continue
                if predicate():
                    return
                raise SchedulerError(
                    f"simulation drained but predicate unmet "
                    f"(unfinished={rt.graph.unfinished})")
            t = self._next_event_time()
            if t == float("inf"):
                raise SchedulerError("no next event with tasks running")
            self._advance_to(t)
            for task in self._pop_due():
                task.end_time = self.clock
                for f in task.futures:
                    f.set_value(None)
                rt._handle_completion(task)


# --------------------------------------------------------------------------
# Real (threaded) backend
# --------------------------------------------------------------------------
class RealBackend(Backend):
    def __init__(self, poll_interval: float = 0.02):
        self._t0 = time.monotonic()
        self._pools: dict[tuple[str, str], ThreadPoolExecutor] = {}
        self._cv = threading.Condition()  # rebound to runtime.lock in bind()
        self._poll = poll_interval
        self._failed: list[TaskInstance] = []

    def bind(self, runtime) -> None:
        super().bind(runtime)
        self._cv = threading.Condition(runtime.lock)

    def now(self) -> float:
        return time.monotonic() - self._t0

    def _pool(self, worker, platform: str) -> ThreadPoolExecutor:
        key = (worker.name, platform)
        if key not in self._pools:
            size = worker.cpus if platform == "compute" else worker.io_executors
            self._pools[key] = ThreadPoolExecutor(
                max_workers=max(1, size),
                thread_name_prefix=f"{worker.name}-{platform}")
        return self._pools[key]

    @staticmethod
    def _resolve(arg, _depth=0):
        if isinstance(arg, Future):
            return arg.value()
        if _depth < 4:
            if isinstance(arg, list):
                return [RealBackend._resolve(v, _depth + 1) for v in arg]
            if isinstance(arg, tuple):
                return tuple(RealBackend._resolve(v, _depth + 1) for v in arg)
            if isinstance(arg, dict):
                return {k: RealBackend._resolve(v, _depth + 1)
                        for k, v in arg.items()}
        return arg

    def launch(self, task: TaskInstance, worker) -> None:
        platform = "compute" if task.defn.task_type == TaskType.COMPUTE else "io"
        task.start_time = self.now()
        self._pool(worker, platform).submit(self._run, task)

    def _run(self, task: TaskInstance) -> None:
        args = tuple(self._resolve(a) for a in task.args)
        kwargs = {k: self._resolve(v) for k, v in task.kwargs.items()}
        err: Optional[BaseException] = None
        result = None
        attempts = task.defn.max_retries + 1
        for attempt in range(attempts):
            try:
                result = task.defn.fn(*args, **kwargs)
                err = None
                break
            except BaseException as e:  # noqa: BLE001 — report at barrier
                err = e
                task.retries = attempt + 1
                if attempt + 1 < attempts:
                    time.sleep(min(0.05 * (2 ** attempt), 1.0))
        task.end_time = self.now()
        if err is not None:
            task.error = err
            task.state = TaskState.FAILED
        if task.defn.returns > 1 and isinstance(result, tuple):
            for f, v in zip(task.futures, result):
                f.set_value(v)
        else:
            task.futures[0].set_value(result)
        with self._cv:
            self.runtime._handle_completion(task)
            if task.error is not None:
                self._failed.append(task)
            self._cv.notify_all()

    def on_submitted(self) -> None:
        with self._cv:
            self.runtime.scheduler.schedule_pass()

    def drain(self, predicate: Callable[[], bool]) -> None:
        rt = self.runtime
        with self._cv:
            while True:
                rt.scheduler.schedule_pass()
                if self._failed:
                    t = self._failed[0]
                    raise RuntimeError(
                        f"task {t.defn.name}#{t.tid} failed after "
                        f"{t.retries} attempt(s)") from t.error
                if predicate():
                    return
                if not rt.scheduler.running and rt.scheduler.ready:
                    rt.scheduler.assert_not_stuck()
                    continue
                self._cv.wait(timeout=self._poll)

    def shutdown(self) -> None:
        for p in self._pools.values():
            p.shutdown(wait=True)
        self._pools.clear()
