"""Storage-bandwidth constraint specifications (paper §3.2, §4.2.2-4.2.3).

A constraint is either:
  * static:   ``storageBW = 20``          (MB/s, fixed for the whole run)
  * bounded:  ``storageBW = "auto(2,256,2)"``  -> AutoSpec(min,max,delta)
  * unbounded:``storageBW = "auto"``           -> AutoSpec(unbounded)
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional, Union


@dataclass(frozen=True)
class StaticSpec:
    value: float

    def __post_init__(self):
        if self.value <= 0:
            raise ValueError(f"storageBW must be positive, got {self.value}")


@dataclass(frozen=True)
class AutoSpec:
    bounded: bool
    min: Optional[float] = None
    max: Optional[float] = None
    delta: Optional[float] = None

    def __post_init__(self):
        if self.bounded:
            if not (self.min and self.max and self.delta):
                raise ValueError("bounded auto constraint needs min, max, delta")
            if self.min <= 0 or self.max < self.min:
                raise ValueError(f"invalid bounds auto({self.min},{self.max},{self.delta})")
            if self.delta <= 1:
                raise ValueError("delta must be > 1 (multiplicative step)")


ConstraintSpec = Union[StaticSpec, AutoSpec]

_AUTO_RE = re.compile(
    r"^auto\(\s*([0-9.]+)\s*,\s*([0-9.]+)\s*,\s*([0-9.]+)\s*\)$")


def parse_storage_bw(value) -> ConstraintSpec:
    """Parse the ``storageBW`` argument of ``@constraint`` (paper Listings 3-5)."""
    if isinstance(value, (StaticSpec, AutoSpec)):
        return value
    if isinstance(value, (int, float)):
        return StaticSpec(float(value))
    if isinstance(value, str):
        s = value.strip()
        if s == "auto":
            return AutoSpec(bounded=False)
        m = _AUTO_RE.match(s)
        if m:
            lo, hi, delta = (float(g) for g in m.groups())
            return AutoSpec(bounded=True, min=lo, max=hi, delta=delta)
        try:
            return StaticSpec(float(s))
        except ValueError:
            pass
    raise ValueError(f"cannot parse storageBW constraint: {value!r}")


def is_auto(spec: Optional[ConstraintSpec]) -> bool:
    return isinstance(spec, AutoSpec)
