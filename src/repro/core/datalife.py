"""Data lifecycle subsystem: object residency, tier capacity, eviction,
auto-prefetch.

The paper's I/O-aware scheduler (§4.2) constrains storage *bandwidth*; this
module adds the second finite resource of a tiered hierarchy — *capacity*.
Fast tiers (node-local SSD, burst buffer) are small: a 240 GB SSD cannot
"fit" an unbounded stream of checkpoint shards no matter how well bandwidth
is budgeted. The subsystem closes the loop the related work sketches:

* **CkIO (arXiv:2411.18593)** — read staging: input files are prefetched
  from the parallel FS into fast storage ahead of the compute wave, so reads
  hit the fast tier. Here the scheduler *auto-issues* ``rt.prefetch`` tasks
  for any task whose tracked inputs are resident only on a slower tier than
  its target placement — the CkIO read pipeline without user annotations.
* **Aupy et al. (arXiv:1702.06900)** — periodic I/O under burst-buffer
  capacity pressure: when a fast tier crosses its high watermark the catalog
  synthesizes *eviction* tasks (drain-then-delete of cold objects, LRU by
  last reader, pinned objects exempt) that write cold data back to the
  durable tier in the shadow of compute, keeping the fast tier absorbing new
  bursts.

Concept map
-----------
``DataObject``
    Every I/O task's output (``io_mb`` footprint) becomes a tracked object
    with *per-tier residency*: which tiers hold a copy, on which concrete
    device (per-worker SSDs are distinct devices of one tier). External
    datasets (already on the parallel FS at t0, the CkIO input case) enter
    via :meth:`DataCatalog.add_external`.
``TierCapacity``
    Per-tier capacity/watermark spec. ``StorageDevice.capacity_gb`` carries
    the budget; occupancy is accounted like the bandwidth epochs —
    *reserve at grant, commit at finish* — so concurrent writers can never
    overcommit a tier (resources.py).
``EvictionPolicy`` / ``LRUEviction``
    Chooses victims among resident objects that are not pinned, have no
    scheduled reader, and are not already being evicted. Objects without a
    durable copy are drained first (``rt.drain`` machinery, runtime.py) and
    deleted only after the drain lands — *every evicted object keeps a
    durable copy*.
``DataCatalog``
    The bookkeeping hub: registers outputs, tracks readers (LRU clock),
    plans evictions from watermark pressure *and* demand (a capacity-blocked
    grant reported by the scheduler), computes read penalties (the simulated
    cost of pulling inputs from their fastest resident tier), and brokers
    staging futures so one prefetch serves many readers.

The subsystem is **inert by default**: with no finite ``capacity_gb``
anywhere (and no explicit ``LifecycleConfig(enabled=True)``) the catalog
stays disabled and the scheduler/simulator behave bit-identically to the
capacity-less implementation — the golden-parity suite pins this.

Pipelined prefetch: a consumer submitted *before* its producer finishes
cannot know where the output will land, so it gets a **conditional**
staging — a mover chained onto the producer's completion whose decision is
made at registration time (``pipeline_prefetch``): if the output landed on
a slower tier than the consumer's target, the mover becomes a real staging;
otherwise it is neutralized into a zero-cost pass-through. Ephemeral
objects (``rt.discard``): temp data provably never read again is deleted at
eviction without the durable drain, freeing FS bandwidth.

Limitations: under ``RealBackend`` eviction drains move catalog state, not
files, since ``DataObject`` carries no path — file movement stays with
``rt.drain(path=)`` and the checkpoint manager.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from .resources import Cluster, StorageDevice
from .storage_model import read_floor_time
from .task import TaskInstance, TaskState, TaskType


def _validate_watermark(name: str, value: float) -> None:
    if not (0.0 < value <= 1.0):
        raise ValueError(
            f"{name} must be a fraction in (0, 1], got {value}")


@dataclass(frozen=True)
class TierCapacity:
    """Capacity/watermark spec for one tier.

    ``capacity_gb`` (if given) is applied to every device of the tier when
    the catalog binds to a cluster; ``high_watermark`` is the occupancy
    fraction that triggers eviction, which then drains down to
    ``low_watermark``.
    """

    tier: str
    capacity_gb: Optional[float] = None
    high_watermark: float = 0.85
    low_watermark: float = 0.60

    def __post_init__(self):
        if self.capacity_gb is not None and self.capacity_gb <= 0:
            raise ValueError(
                f"tier {self.tier!r}: capacity_gb must be positive, got "
                f"{self.capacity_gb}")
        _validate_watermark(f"tier {self.tier!r}: high_watermark",
                            self.high_watermark)
        _validate_watermark(f"tier {self.tier!r}: low_watermark",
                            self.low_watermark)
        if self.low_watermark > self.high_watermark:
            raise ValueError(
                f"tier {self.tier!r}: low_watermark ({self.low_watermark}) "
                f"must not exceed high_watermark ({self.high_watermark})")


class DataObject:
    """A tracked datum resident on one or more storage tiers."""

    _ids = itertools.count()

    def __init__(self, name: str, size_mb: float, producer_tid: int = -1,
                 pinned: bool = False, created: float = 0.0):
        self.oid = next(DataObject._ids)
        self.name = name
        self.size_mb = float(size_mb)
        self.producer_tid = producer_tid
        self.pinned = pinned
        self.created = created
        self.last_use = created        # LRU clock: bumped by reader activity
        self.ephemeral = False         # rt.discard: never read again, so
        #                                eviction may delete without a drain
        self.residency: dict[str, StorageDevice] = {}  # tier -> device copy
        self.readers: set[int] = set()  # tids of scheduled/running readers
        self.reader_log: list[list] = []  # [tid, submit_t, end_t|None]
        self._open_reads: dict[int, list] = {}  # tid -> its open log record
        self.staging: dict[str, object] = {}  # tier -> in-flight prefetch fut
        self.evicting: bool = False
        self.recovering: bool = False  # failure recovery in flight (redrain
        #                                or lineage re-run): exempt from
        #                                eviction until the copy lands

    def begin_read(self, tid: int, t: float) -> None:
        self.readers.add(tid)
        if tid not in self._open_reads:  # O(1); a tid reads an object once
            rec = [tid, t, None]
            self.reader_log.append(rec)
            self._open_reads[tid] = rec
        self.last_use = t

    def end_read(self, tid: int, t: float) -> None:
        self.readers.discard(tid)
        rec = self._open_reads.pop(tid, None)
        if rec is not None:
            rec[2] = t
        self.last_use = t

    def fastest_tier(self, rank: Callable[[str], int]) -> Optional[str]:
        if not self.residency:
            return None
        return min(self.residency, key=rank)

    def __repr__(self) -> str:
        return (f"<DataObject {self.name}#{self.oid} {self.size_mb:.0f}MB "
                f"on {sorted(self.residency)}>")


class EvictionPolicy:
    """Victim selection among evictable resident objects of one device."""

    def select(self, candidates: list[DataObject], need_mb: float
               ) -> list[DataObject]:
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    """Coldest-first by last reader time (ties: oldest object first)."""

    def select(self, candidates: list[DataObject], need_mb: float
               ) -> list[DataObject]:
        chosen, freed = [], 0.0
        for obj in sorted(candidates, key=lambda o: (o.last_use, o.oid)):
            if freed >= need_mb:
                break
            chosen.append(obj)
            freed += obj.size_mb
        return chosen


@dataclass
class LifecycleConfig:
    """Runtime-level configuration of the data lifecycle subsystem.

    ``enabled=None`` auto-detects: the subsystem activates iff any device in
    the cluster has a finite ``capacity_gb`` (or a ``tiers`` entry supplies
    one). ``durable_tier`` names the backing store evictions drain to;
    default is the slowest tier of the hierarchy. Objects resident on the
    durable tier are never evicted from it.
    """

    enabled: Optional[bool] = None
    auto_prefetch: bool = True
    #: extend auto_prefetch to fully-async DAGs: a consumer submitted before
    #: its producer finishes gets a conditional staging chained onto the
    #: producer's completion (decided when the output's tier is known)
    #: instead of silently skipping staging. Only meaningful with
    #: auto_prefetch on.
    pipeline_prefetch: bool = True
    auto_evict: bool = True
    high_watermark: float = 0.85
    low_watermark: float = 0.60
    durable_tier: Optional[str] = None
    policy: EvictionPolicy = field(default_factory=LRUEviction)
    tiers: dict = field(default_factory=dict)  # tier -> TierCapacity

    def __post_init__(self):
        _validate_watermark("high_watermark", self.high_watermark)
        _validate_watermark("low_watermark", self.low_watermark)
        if self.low_watermark > self.high_watermark:
            raise ValueError(
                f"low_watermark ({self.low_watermark}) must not exceed "
                f"high_watermark ({self.high_watermark})")
        for tier, tc in self.tiers.items():
            if not isinstance(tc, TierCapacity):
                raise TypeError(
                    f"tiers[{tier!r}] must be a TierCapacity, got "
                    f"{type(tc).__name__}")


@dataclass
class EvictionAction:
    """One planned eviction: free ``obj``'s copy on ``device``; if the
    object has no durable copy yet, drain it to ``drain_to`` first."""

    obj: DataObject
    device: StorageDevice
    drain_to: Optional[str]  # None: durable copy exists, drop immediately


class DataCatalog:
    """Residency + capacity bookkeeping for every tracked data object.

    Owned by the runtime; the scheduler holds a reference for grant-time
    hooks (read penalties, demand reporting). All methods are called under
    the runtime lock.
    """

    def __init__(self, cluster: Cluster, config: Optional[LifecycleConfig],
                 now: Callable[[], float], strict: bool = True):
        self.cluster = cluster
        self.config = config or LifecycleConfig()
        self.now = now
        # strict=False (capture mode, repro.analysis): configuration errors
        # are recorded here instead of raising, so the static analyzer can
        # report them as diagnostics (IO204) over a plan that a live
        # runtime would refuse to construct
        self.config_errors: list[str] = []
        # trace recorder (obs/): wired by the runtime when tracing is on;
        # None costs one comparison per lifecycle event
        self.recorder = None
        # sharded control plane (core.shardplane): the ShardedScheduler
        # wires its bus here so every residency change is ALSO posted as an
        # ordered broadcast message. The mutation itself stays synchronous
        # (applied before the message is posted, never partially): eviction
        # planning and read-penalty snapshots run between bus drains and
        # must see current occupancy — the message stream is the ordered
        # cross-shard record, not the mechanism of the update.
        self.shardbus = None
        self._tier_order = cluster.tier_names()
        self._rank = {t: i for i, t in enumerate(self._tier_order)}
        # apply TierCapacity budgets before auto-detection
        for tc in self.config.tiers.values():
            if tc.capacity_gb is None:
                continue
            for dev in cluster.devices:
                if dev.tier == tc.tier:
                    dev.capacity_gb = tc.capacity_gb
        if self.config.enabled is None:
            self.enabled = any(d.capacity_gb is not None
                               for d in cluster.devices)
        else:
            self.enabled = bool(self.config.enabled)
        self.durable_tier = self.config.durable_tier or (
            self._tier_order[-1] if self._tier_order else None)
        if self.enabled and self.config.auto_evict:
            # eviction drains land on the durable tier and objects there are
            # never evicted, so a finite durable tier would silently wedge
            # once cumulative output exceeds it (capacity-blocked drains,
            # nothing evictable) — fail loudly up front instead
            finite = [d.name for d in cluster.devices
                      if d.tier == self.durable_tier
                      and d.capacity_gb is not None]
            if finite:
                msg = (
                    f"durable tier {self.durable_tier!r} must be unlimited "
                    f"when auto_evict is on (eviction drains terminate "
                    f"there and are never themselves evicted), but "
                    f"{finite} carry capacity_gb — drop the budget, pick "
                    f"another durable_tier, or set "
                    f"LifecycleConfig(auto_evict=False)")
                if strict:
                    raise ValueError(msg)
                self.config_errors.append(msg)
        # capacities are fixed once the runtime is constructed: precompute
        # the finite devices so the per-submission/per-completion lifecycle
        # tick doesn't rescan workers x tiers (0-3 entries in practice)
        self._finite_devs = [d for d in cluster.devices
                             if d.capacity_mb is not None]
        self.graph = None  # TaskGraph, wired by the runtime: lets output
        #                    registration pick up readers that were submitted
        #                    before the producer finished (pipelined DAGs)
        self.objects: dict[int, DataObject] = {}
        # id(Future) -> (future, object): the future itself is retained so a
        # garbage-collected future's reused id can never resolve to a stale
        # object (external/resolved futures are not held by the graph)
        self._by_fut: dict[int, tuple] = {}
        self._pending_pins: set[int] = set()         # pinned-before-produced
        self._pending_discards: set[int] = set()     # discarded-before-produced
        self._resident: dict[int, set] = {}          # id(device) -> objects
        self._evicting_mb: dict[int, float] = {}     # id(device) -> in-flight
        # pipelined prefetch: consumers submitted before their producer
        # finished register a *deferred* staging decision here, resolved at
        # the producer's registration — id(producer_fut) -> (fut, {tier:
        # mover_fut}); the future is retained so a reused id can't alias
        self._deferred_stage: dict[int, tuple] = {}
        self.events: list[dict] = []                 # eviction audit log
        self.lost_objects: list[DataObject] = []     # unrecoverable after a
        #                                              device failure (no
        #                                              copy, no lineage)
        self.n_prefetches = 0
        self.n_evictions = 0
        self.n_discards = 0
        self.n_deferred_stages = 0
        self.bytes_evicted_mb = 0.0
        self.bytes_prefetched_mb = 0.0

    # ------------------------------------------------------------- helpers
    def tier_rank(self, tier: str) -> int:
        return self._rank.get(tier, len(self._rank))

    def _watermarks(self, dev: StorageDevice) -> tuple[float, float]:
        tc = self.config.tiers.get(dev.tier)
        if tc is not None:
            return tc.high_watermark, tc.low_watermark
        return self.config.high_watermark, self.config.low_watermark

    #: public accessor (the scheduler's tier-choice objective prices the
    #: eviction drain a watermark crossing would force)
    watermarks = _watermarks

    def lookup_future(self, fut) -> Optional[DataObject]:
        entry = self._by_fut.get(id(fut))
        return entry[1] if entry is not None else None

    def map_future(self, fut, obj: DataObject) -> None:
        self._by_fut[id(fut)] = (fut, obj)

    def input_objects(self, task: TaskInstance) -> list[DataObject]:
        """Distinct tracked objects among a task's argument futures."""
        from .graph import iter_futures  # local: avoid import cycle
        out, seen = [], set()
        for arg in list(task.args) + list(task.kwargs.values()):
            for f in iter_futures(arg):
                obj = self.lookup_future(f)
                if obj is not None and obj.oid not in seen:
                    seen.add(obj.oid)
                    out.append(obj)
        return out

    def _shard_of(self, obj: DataObject) -> int:
        """Source shard of a residency message: the producing task's owner
        (external objects and pre-shard producers fall back to shard 0)."""
        if self.graph is not None:
            t = self.graph.tasks.get(obj.producer_tid)
            if t is not None:
                return t.shard
        return 0

    def _add_residency(self, obj: DataObject, dev: StorageDevice) -> None:
        obj.residency[dev.tier] = dev
        self._resident.setdefault(id(dev), set()).add(obj)
        if self.shardbus is not None:
            self.shardbus.post("RESIDENCY_ADD", self._shard_of(obj), None,
                               (obj.name, dev.tier))

    def _drop_residency(self, obj: DataObject, dev: StorageDevice) -> None:
        if obj.residency.get(dev.tier) is dev:
            del obj.residency[dev.tier]
        self._resident.get(id(dev), set()).discard(obj)
        if self.shardbus is not None:
            self.shardbus.post("RESIDENCY_DROP", self._shard_of(obj), None,
                               (obj.name, dev.tier))

    # ----------------------------------------------------------- ingestion
    def add_external(self, name: str, size_mb: float, tier: str,
                     pinned: bool = False, charge: bool = True
                     ) -> DataObject:
        """Register a dataset that already exists on ``tier`` at time zero
        (the CkIO input case: files on the parallel FS before the run).
        Commits capacity on the tier's representative device.
        ``charge=False`` (capture mode) registers residency without
        touching device accounting, keeping plan capture side-effect-free
        on a shared cluster object."""
        if size_mb <= 0:
            raise ValueError(f"external object {name!r}: size_mb must be "
                             f"positive, got {size_mb}")
        dev = self.cluster.tier_spec(tier)
        if dev is None:
            raise ValueError(
                f"external object {name!r}: tier {tier!r} not present "
                f"(available: {self._tier_order})")
        if dev.health == "offline":
            # prefer a surviving device of the tier over the representative
            dev = next((d for d in self.cluster.devices if d.tier == tier
                        and d.health != "offline"), dev)
        obj = DataObject(name, size_mb, pinned=pinned, created=self.now())
        if charge:
            if not dev.can_reserve_capacity(size_mb):
                raise ValueError(
                    f"external object {name!r} ({size_mb} MB) does not fit "
                    f"on {dev.name} ({dev.free_capacity_mb():.0f} MB free)")
            dev.reserve_capacity(size_mb)
            dev.commit_capacity(size_mb)
        self._add_residency(obj, dev)
        self.objects[obj.oid] = obj
        return obj

    def register_output(self, task: TaskInstance) -> Optional[DataObject]:
        """A successful I/O task's written bytes become a resident object on
        the device the write was granted on."""
        if task.device is None or task.sim.io_bytes <= 0:
            return None
        t = self.now()
        obj = DataObject(f"{task.defn.signature}#{task.tid}",
                         task.sim.io_bytes, producer_tid=task.tid,
                         created=t)
        self._add_residency(obj, task.device)
        self.objects[obj.oid] = obj
        for f in task.futures:
            self.map_future(f, obj)
            if id(f) in self._pending_pins:
                self._pending_pins.discard(id(f))
                obj.pinned = True
            if id(f) in self._pending_discards:
                self._pending_discards.discard(id(f))
                obj.ephemeral = True
        # readers submitted BEFORE the producer finished (pipelined DAGs)
        # could not be tracked at their submission — the object didn't exist
        # yet. Pick them up from the dependency graph now, so eviction can
        # never select an object a scheduled consumer is about to read.
        if self.graph is not None:
            from .graph import iter_futures  # local: avoid import cycle
            fut_ids = {id(f) for f in task.futures}
            for ctid in task.children:
                child = self.graph.tasks.get(ctid)
                if child is None or child.state in (TaskState.DONE,
                                                    TaskState.FAILED):
                    continue
                # only true data readers: anti-dependents (write-after-read
                # successors) are children too but never touch the object
                reads = any(id(f) in fut_ids for arg in
                            list(child.args) + list(child.kwargs.values())
                            for f in iter_futures(arg))
                if reads:
                    obj.begin_read(ctid, t)
        self._resolve_deferred(task, obj, t)
        return obj

    def pin(self, fut_or_obj) -> Optional[DataObject]:
        """Exempt from eviction. Pinning a future whose producer has not
        finished yet is allowed — the pin applies when the object
        registers."""
        obj = fut_or_obj if isinstance(fut_or_obj, DataObject) \
            else self.lookup_future(fut_or_obj)
        if obj is None:
            self._pending_pins.add(id(fut_or_obj))
            return None
        obj.pinned = True
        if self.recorder is not None:
            self.recorder.on_pin(self.now(), obj, True)
        return obj

    def unpin(self, fut_or_obj) -> Optional[DataObject]:
        obj = fut_or_obj if isinstance(fut_or_obj, DataObject) \
            else self.lookup_future(fut_or_obj)
        if obj is None:
            self._pending_pins.discard(id(fut_or_obj))
            return None
        obj.pinned = False
        if self.recorder is not None:
            self.recorder.on_pin(self.now(), obj, False)
        return obj

    def discard(self, fut_or_obj) -> Optional[DataObject]:
        """Ephemeral liveness signal (``rt.discard``): the caller promises
        the datum will never be read again. Eviction may then *delete* the
        object without the durable drain — temp data stops consuming FS
        bandwidth on its way out. Outstanding scheduled readers are still
        honoured (the evictable filter skips objects with readers).
        Discarding before the producer finished defers the mark to
        registration, like pin."""
        obj = fut_or_obj if isinstance(fut_or_obj, DataObject) \
            else self.lookup_future(fut_or_obj)
        if obj is None:
            self._pending_discards.add(id(fut_or_obj))
            return None
        obj.ephemeral = True
        return obj

    # -------------------------------------------------------- reader hooks
    def on_submit(self, task: TaskInstance) -> None:
        """Track the task as a scheduled reader of its tracked inputs: the
        LRU clock advances and eviction must not select these objects while
        the reader is outstanding."""
        if not self.enabled:
            return
        t = self.now()
        for obj in self.input_objects(task):
            obj.begin_read(task.tid, t)

    def on_grant(self, task: TaskInstance) -> None:
        """Grant-time hook from the scheduler: charge the simulated cost of
        pulling inputs from their fastest resident tier (movers carry their
        own read floor from ``IORuntime._move`` and are skipped)."""
        if not self.enabled:
            return
        if getattr(task, "_datalife", None) is not None or \
                task.defn.signature in ("tier_drain", "tier_prefetch"):
            return
        penalty = 0.0
        for obj in self.input_objects(task):
            tier = obj.fastest_tier(self.tier_rank)
            if tier is None:
                continue
            src = self.cluster.tier_spec(tier)
            if src is not None:
                penalty += read_floor_time(src, obj.size_mb)
        task.read_penalty = penalty

    def on_task_done(self, task: TaskInstance, failed: bool) -> None:
        """Completion hook (runtime, under lock, after the scheduler
        committed/cancelled the capacity reservation): close reader
        bookkeeping, resolve mover tags, register new outputs."""
        if not self.enabled:
            return
        t = self.now()
        in_objs = self.input_objects(task)
        for obj in in_objs:
            obj.end_read(task.tid, t)
        if failed:
            # a failed/cancelled producer never registers: its deferred
            # staging decisions die with it (the movers are its data-
            # descendants and were cancelled by the same fan-out)
            for f in task.futures:
                self._deferred_stage.pop(id(f), None)
        tag = getattr(task, "_datalife", None)
        if tag is not None:
            kind, obj = tag[0], tag[1]
            if kind == "stage":
                self._finish_stage(task, obj, tag[2], failed)
            elif kind == "evict":
                self._finish_evict(task, obj, tag[2], failed)
            elif kind in ("redrain", "recover"):
                self._finish_recovery(task, obj, failed)
            return
        if not failed and task.is_io and task.sim.io_bytes > 0 \
                and task.device is not None:
            if task.defn.signature in ("tier_drain", "tier_prefetch"):
                # a user-issued move of tracked data: the payload gains a
                # copy on the destination device, no new object is minted —
                # but only when the mover's accounted footprint matches the
                # object (a drain submitted before its producer registered
                # carries the caller's io_mb guess; recording the object's
                # true size against a commit of the guessed size would
                # desync used_mb from the resident sum and underflow later)
                if len(in_objs) == 1 and \
                        in_objs[0].size_mb == task.sim.io_bytes and \
                        in_objs[0].residency.get(task.device.tier) \
                        is not task.device:
                    obj = in_objs[0]
                    self._add_residency(obj, task.device)
                    for f in task.futures:  # mover future aliases the datum
                        self.map_future(f, obj)
                    return
            self.register_output(task)

    # ----------------------------------------------------------- prefetch
    def staging_future(self, obj: DataObject, tier: str):
        """The in-flight prefetch future for ``obj``→``tier``, if any —
        a second reader of the same cold object rides the same staging."""
        return obj.staging.get(tier)

    def begin_stage(self, obj: DataObject, tier: str, fut) -> None:
        obj.staging[tier] = fut
        self.map_future(fut, obj)
        fut.task._datalife = ("stage", obj, tier)
        self.n_prefetches += 1
        self.bytes_prefetched_mb += obj.size_mb
        if self.recorder is not None:
            self.recorder.on_stage(self.now(), obj, tier)

    def _finish_stage(self, task: TaskInstance, obj: DataObject, tier: str,
                      failed: bool) -> None:
        obj.staging.pop(tier, None)
        if not failed and task.device is not None:
            if obj.residency.get(task.device.tier) is task.device:
                # a lineage recovery (or competing mover) landed this copy
                # while the stage was in flight — e.g. retried across a
                # device outage; the scheduler's commit for this mover
                # would double-count the single resident copy
                task.device.free_capacity(task.sim.io_bytes)
            else:
                self._add_residency(obj, task.device)

    # ------------------------------------- prefetch under producer pipelining
    def wants_deferred_stage(self, fut, target_tier: str) -> bool:
        """Should a consumer of the not-yet-finished producer behind ``fut``
        get a *conditional* staging chained onto the producer's completion?
        Only for pending I/O producers with a real output footprint that
        could ever fit the target tier — whether staging is actually useful
        is unknowable until the producer's output lands somewhere, which is
        exactly why the decision is deferred."""
        if target_tier not in self._rank:
            return False
        t = getattr(fut, "task", None)
        if t is None or t.state in (TaskState.DONE, TaskState.FAILED):
            return False  # resolved or doomed: nothing to defer
        if t.defn.task_type != TaskType.IO or t.sim.io_bytes <= 0:
            return False
        if t.defn.signature in ("tier_drain", "tier_prefetch"):
            return False  # movers move data; they are never staged
        return any(d.tier == target_tier and
                   (d.capacity_mb is None or t.sim.io_bytes <= d.capacity_mb)
                   for d in self.cluster.devices)

    def deferred_stage_future(self, fut, tier: str):
        """The already-minted conditional mover for ``fut``→``tier``, if
        any — every pipelined reader of the same pending output rides the
        same mover."""
        entry = self._deferred_stage.get(id(fut))
        return entry[1].get(tier) if entry is not None else None

    def begin_deferred_stage(self, fut, tier: str, mover_fut) -> None:
        entry = self._deferred_stage.get(id(fut))
        if entry is None:
            entry = self._deferred_stage[id(fut)] = (fut, {})
        entry[1][tier] = mover_fut
        self.n_deferred_stages += 1

    def _resolve_deferred(self, task: TaskInstance, obj: DataObject,
                          t: float) -> None:
        """The producer registered: decide each deferred staging now. A
        useful mover becomes a real staging (source tier known at last);
        a useless one — the output already landed on a tier at least as
        fast as the target — is neutralized into a zero-cost pass-through
        so its consumers release immediately."""
        for f in task.futures:
            entry = self._deferred_stage.pop(id(f), None)
            if entry is None:
                continue
            for tier, mover_fut in entry[1].items():
                mover = mover_fut.task
                self.map_future(mover_fut, obj)
                # consumers were submitted before the object existed: they
                # depend on the mover — pick them up as readers so eviction
                # can never select the object out from under them
                if self.graph is not None:
                    for ctid in mover.children:
                        child = self.graph.tasks.get(ctid)
                        if child is not None and child.state not in (
                                TaskState.DONE, TaskState.FAILED):
                            obj.begin_read(ctid, t)
                if self.wants_stage(obj, tier):
                    src = obj.fastest_tier(self.tier_rank)
                    src_dev = self.cluster.tier_spec(src) if src else None
                    mover.sim.io_bytes = obj.size_mb
                    mover.sim.duration = read_floor_time(
                        src_dev, obj.size_mb) if src_dev is not None else 0.0
                    self.begin_stage(obj, tier, mover_fut)
                else:
                    mover.sim.io_bytes = 0.0
                    mover.sim.duration = 0.0

    def wants_stage(self, obj: DataObject, target_tier: str) -> bool:
        """Is a prefetch of ``obj`` up to ``target_tier`` useful? Only when
        the object is resident somewhere, every copy is on a strictly slower
        tier, the target exists in the cluster, and at least one of the
        target's devices could ever hold it (an object bigger than the fast
        tier's total capacity must keep being read from where it lives —
        staging it would be rejected at submission)."""
        if target_tier not in self._rank:
            return False
        best = obj.fastest_tier(self.tier_rank)
        if best is None:
            return False
        if self.tier_rank(best) <= self.tier_rank(target_tier):
            return False
        return any(d.tier == target_tier and
                   (d.capacity_mb is None or obj.size_mb <= d.capacity_mb)
                   for d in self.cluster.devices)

    # ------------------------------------------------------------ eviction
    def _evictable(self, dev: StorageDevice) -> list[DataObject]:
        return [o for o in self._resident.get(id(dev), ())
                if not o.pinned and not o.readers and not o.evicting
                and not o.staging and not o.recovering]

    def plan_evictions(self, demand_mb: Optional[dict] = None
                       ) -> list[EvictionAction]:
        """Eviction planning pass over every finite device.

        Two triggers: occupancy above the tier's high watermark (drain back
        down to the low watermark), and *demand* — the scheduler reports a
        capacity-blocked grant (``{id(device): mb}``) and eviction frees at
        least that much even below the watermark. In-flight eviction volume
        is subtracted so ticks don't over-evict.
        """
        if not self.enabled or not self.config.auto_evict \
                or not self._finite_devs:
            return []
        demand_mb = demand_mb or {}
        actions: list[EvictionAction] = []
        for dev in self._finite_devs:
            cap = dev.capacity_mb
            if dev.tier == self.durable_tier:
                continue  # the backing store is never evicted
            hi, lo = self._watermarks(dev)
            in_flight = self._evicting_mb.get(id(dev), 0.0)
            occ = dev.occupancy_mb - in_flight
            need = 0.0
            if occ > hi * cap:
                need = occ - lo * cap
            want = demand_mb.get(id(dev), 0.0)
            if want > 0:
                free_after = cap - occ
                if free_after < want:
                    need = max(need, want - free_after)
            if need <= 0:
                continue
            chosen = self.config.policy.select(self._evictable(dev), need)
            t_sel = self.now()
            for obj in chosen:
                obj.evicting = True
                obj._selected_at = t_sel  # audited: no reader was scheduled
                self._evicting_mb[id(dev)] = \
                    self._evicting_mb.get(id(dev), 0.0) + obj.size_mb
                durable = self.durable_tier in obj.residency
                # ephemeral objects (rt.discard) skip the durable drain:
                # nobody will ever read them, so deletion is free — no FS
                # bandwidth spent writing back data on its way out
                actions.append(EvictionAction(
                    obj=obj, device=dev,
                    drain_to=None if durable or obj.ephemeral
                    else self.durable_tier))
        return actions

    def drop_now(self, obj: DataObject, dev: StorageDevice) -> None:
        """Immediate delete of a copy that has a durable sibling — or of an
        ephemeral object (rt.discard), which needs none."""
        assert obj.ephemeral or self.durable_tier in obj.residency, obj
        if obj.ephemeral:
            self.n_discards += 1
        self._record_eviction(
            obj, dev, mode="discard" if obj.ephemeral else "drop")
        dev.free_capacity(obj.size_mb)
        self._drop_residency(obj, dev)
        self._evicting_mb[id(dev)] = max(
            0.0, self._evicting_mb.get(id(dev), 0.0) - obj.size_mb)
        obj.evicting = False

    def _finish_evict(self, task: TaskInstance, obj: DataObject,
                      dev: StorageDevice, failed: bool) -> None:
        """Drain-then-delete completion: the durable copy landed (or the
        drain failed, in which case the fast copy survives untouched)."""
        self._evicting_mb[id(dev)] = max(
            0.0, self._evicting_mb.get(id(dev), 0.0) - obj.size_mb)
        obj.evicting = False
        if failed:
            return
        if task.device is not None:
            self._add_residency(obj, task.device)
        self._record_eviction(obj, dev, mode="drain")
        if obj.residency.get(dev.tier) is dev:
            # the copy can already be gone: the device went offline mid-
            # drain and on_device_offline dropped it (freeing the capacity)
            dev.free_capacity(obj.size_mb)
            self._drop_residency(obj, dev)

    def _finish_recovery(self, task: TaskInstance, obj: DataObject,
                         failed: bool) -> None:
        """Emergency re-drain / lineage re-run completion: the restored
        copy becomes residency of the *original* object (no new object is
        minted) and the object leaves its recovering state. A failed
        attempt (retries exhausted) leaves whatever copies survive."""
        obj.recovering = False
        if failed or task.device is None:
            return
        if obj.residency.get(task.device.tier) is not task.device:
            self._add_residency(obj, task.device)
        else:
            # an in-flight stage/mover beat the recovery to this device:
            # one resident copy, so one committed footprint
            task.device.free_capacity(task.sim.io_bytes)
        for f in task.futures:
            self.map_future(f, obj)

    # ------------------------------------------------------ failure domains
    def on_device_offline(self, dev: StorageDevice
                          ) -> tuple[list[DataObject], list[DataObject]]:
        """A device died (failures.py): every copy it held is gone. Drop
        the residencies — freeing the modelled occupancy so a recovered
        device starts empty — and classify the damage:

        * **orphans**: objects whose ONLY copy lived on ``dev``; they need
          a lineage re-run (``IORuntime._recover_object``);
        * **at_risk**: objects that keep a surviving copy on another tier
          but lost their durable-tier copy; they need an emergency
          re-drain (``IORuntime._issue_redrain``).

        Returns ``(orphans, at_risk)``, each in object-creation order.
        """
        if not self.enabled:
            return [], []
        orphans: list[DataObject] = []
        at_risk: list[DataObject] = []
        for obj in sorted(self._resident.get(id(dev), set()),
                          key=lambda o: o.oid):
            dev.free_capacity(obj.size_mb)
            self._drop_residency(obj, dev)
            self.events.append({
                "time": self.now(), "oid": obj.oid, "name": obj.name,
                "size_mb": obj.size_mb, "tier": dev.tier,
                "device": dev.name, "mode": "lost",
                "readers": len(obj.readers),
                "durable": self.durable_tier in obj.residency,
                "pinned": obj.pinned, "ephemeral": obj.ephemeral,
            })
            if self.recorder is not None:
                self.recorder.on_evict(self.now(), obj, dev, "lost")
            if not obj.residency:
                orphans.append(obj)
            elif dev.tier == self.durable_tier and not obj.ephemeral:
                at_risk.append(obj)
        return orphans, at_risk

    def _record_eviction(self, obj: DataObject, dev: StorageDevice,
                         mode: str) -> None:
        self.n_evictions += 1
        self.bytes_evicted_mb += obj.size_mb
        self.events.append({
            "time": self.now(), "oid": obj.oid, "name": obj.name,
            "size_mb": obj.size_mb, "tier": dev.tier, "device": dev.name,
            "mode": mode, "readers": len(obj.readers),
            "selected_at": getattr(obj, "_selected_at", self.now()),
            "durable": self.durable_tier in obj.residency,
            "pinned": obj.pinned,
            "ephemeral": obj.ephemeral,
        })
        if self.recorder is not None:
            self.recorder.on_evict(self.now(), obj, dev, mode)

    # ------------------------------------------------------------- summary
    def summary(self) -> dict:
        return {
            "enabled": self.enabled,
            "n_objects": len(self.objects),
            "n_prefetches": self.n_prefetches,
            "n_deferred_stages": self.n_deferred_stages,
            "n_evictions": self.n_evictions,
            "n_discards": self.n_discards,
            "n_lost_objects": len(self.lost_objects),
            "bytes_prefetched_mb": self.bytes_prefetched_mb,
            "bytes_evicted_mb": self.bytes_evicted_mb,
            "occupancy": {
                d.name: {
                    "tier": d.tier,
                    "capacity_mb": d.capacity_mb,
                    "used_mb": d.used_mb,
                    "reserved_mb": d.reserved_mb,
                    "peak_occupancy_mb": d.peak_occupancy_mb,
                }
                for d in self.cluster.devices if d.capacity_mb is not None
            },
        }
