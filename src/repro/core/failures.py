"""Failure domains: device/tier health states driven by scheduled events.

Generalizes per-task ``sim_fail=`` into whole storage devices (and whole
tiers) transitioning ``healthy -> degraded(bw_factor) -> offline`` at
simulated times. A :class:`FailureSchedule` is an ordered list of
:class:`FailureEvent`; :class:`FailureEngine` resolves each event's target
against the cluster and feeds the transitions to ``SimBackend`` as
first-class simulation events, peer to the interference engine's burst
heap (interference.py — the architectural template for this module).

Semantics on transition (see docs/failures.md):

* ``degraded(f)`` — the device keeps serving but its effective bandwidth
  drops to ``f * bandwidth``: the congestion model scales aggregate
  throughput, new grants must fit under the reduced budget, and
  co-tenant claims are clamped against it.
* ``offline`` — the scheduler stops granting to the device
  (``eligible_devices`` is health-aware), in-flight I/O on it fails into
  the ordinary retry path (a re-placement is a fresh grant on a surviving
  device), the catalog drops lost residencies and re-drains / re-runs
  lineage for objects whose only durable copy died with the device, and
  the checkpoint manager reroutes draining shards to the shared FS.
* back to ``healthy`` — the device rejoins the eligible set; nothing is
  replayed (recovered hardware comes back empty, residency is not
  resurrected).

An engine built from an empty schedule is inert: every code path — and
all simulator arithmetic — is identical to a run with no engine at all
(launch logs stay bit-identical; pinned by tests/test_failures.py).
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass
from typing import Iterable, Optional

HEALTH_STATES = ("healthy", "degraded", "offline")


@dataclass(frozen=True)
class FailureEvent:
    """One scheduled health transition.

    ``target`` names a tier label or a device name (resolved against the
    cluster at engine construction, like interference targets).
    ``bw_factor`` only matters for ``degraded``: the fraction of nameplate
    bandwidth the device retains."""

    t: float
    target: str
    state: str
    bw_factor: float = 1.0

    def __post_init__(self):
        if self.t < 0:
            raise ValueError(f"FailureEvent.t must be >= 0, got {self.t}")
        if self.state not in HEALTH_STATES:
            raise ValueError(
                f"FailureEvent.state must be one of {HEALTH_STATES}, "
                f"got {self.state!r}")
        if self.state == "degraded" and not (0.0 < self.bw_factor <= 1.0):
            raise ValueError(
                f"degraded bw_factor must be in (0, 1], got {self.bw_factor}")


class FailureSchedule:
    """An ordered, reproducible list of :class:`FailureEvent`.

    Stable-sorted by time: two events at the same instant apply in the
    order given (so ``[... offline, ... healthy]`` at equal t ends
    healthy)."""

    def __init__(self, events: Iterable[FailureEvent] = ()):
        evs = []
        for ev in events:
            if not isinstance(ev, FailureEvent):
                ev = FailureEvent(*ev)
            evs.append(ev)
        self.events: tuple[FailureEvent, ...] = tuple(
            sorted(evs, key=lambda e: e.t))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @classmethod
    def seeded(cls, seed: int, targets, horizon: float,
               n_events: int = 3, offline_prob: float = 0.5,
               recover: bool = True, min_factor: float = 0.25,
               max_factor: float = 0.9) -> "FailureSchedule":
        """Draw a reproducible schedule: ``n_events`` fault injections over
        ``[0, horizon)`` against the given tier/device targets, each going
        offline with ``offline_prob`` (else degraded with a bandwidth
        factor in ``[min_factor, max_factor]``), optionally recovering to
        healthy before the horizon."""
        rng = random.Random(seed)
        targets = list(targets)
        if not targets:
            raise ValueError("FailureSchedule.seeded needs >= 1 target")
        events: list[FailureEvent] = []
        for _ in range(n_events):
            target = rng.choice(targets)
            t = rng.uniform(0.0, horizon)
            if rng.random() < offline_prob:
                events.append(FailureEvent(t, target, "offline"))
            else:
                f = rng.uniform(min_factor, max_factor)
                events.append(FailureEvent(t, target, "degraded", f))
            if recover:
                t_back = rng.uniform(t, horizon)
                events.append(FailureEvent(t_back, target, "healthy"))
        return cls(events)


class _Binding:
    """One (device, event) pair on the engine's heap."""

    __slots__ = ("device", "event")

    def __init__(self, device, event: FailureEvent):
        self.device = device
        self.event = event


class FailureEngine:
    """Applies a :class:`FailureSchedule` to a cluster's devices as the
    simulation clock advances. Mirrors ``InterferenceEngine``'s contract:
    ``next_time()`` feeds the event loop's horizon, ``apply_due(now)``
    fires everything due and returns the transitions that happened."""

    def __init__(self, schedule, cluster):
        if not isinstance(schedule, FailureSchedule):
            schedule = FailureSchedule(schedule)
        self.schedule = schedule
        self.cluster = cluster
        self._seq = itertools.count()
        self._heap: list[tuple[float, int, _Binding]] = []
        self.log: list[tuple[float, str, str, str]] = []  # (t, dev, prev, new)
        # trace recorder (obs/): wired by the runtime when tracing is on;
        # None costs one comparison per fired transition
        self.recorder = None
        self.n_transitions = 0
        self._final: dict[int, FailureEvent] = {}  # id(dev) -> last event
        for ev in schedule.events:
            devs = [d for d in cluster.devices
                    if d.tier == ev.target or d.name == ev.target]
            if not devs:
                tiers = cluster.tier_names()
                names = sorted(d.name for d in cluster.devices)
                raise ValueError(
                    f"FailureEvent target {ev.target!r} matches no tier "
                    f"(available: {tiers}) and no device (available: "
                    f"{names})")
            for d in devs:
                heapq.heappush(self._heap,
                               (ev.t, next(self._seq), _Binding(d, ev)))
                self._final[id(d)] = ev

    @property
    def active(self) -> bool:
        """True when the schedule carries any event at all. An inactive
        engine is dropped by ``SimBackend.attach_failures`` so the
        simulator arithmetic stays byte-identical to a failure-free run."""
        return bool(self.schedule.events)

    def next_time(self) -> float:
        return self._heap[0][0] if self._heap else float("inf")

    def apply_due(self, now: float, eps: float = 1e-9) -> list:
        """Fire every event with ``t <= now + eps``; returns the list of
        ``(device, prev_state, new_state)`` transitions applied (possibly
        empty). Same-instant events apply in schedule order."""
        transitions = []
        while self._heap and self._heap[0][0] <= now + eps:
            _, _, b = heapq.heappop(self._heap)
            dev, ev = b.device, b.event
            prev = dev.health
            dev.set_health(ev.state, ev.bw_factor)
            self.n_transitions += 1
            self.log.append((ev.t, dev.name, prev, ev.state))
            if self.recorder is not None:
                self.recorder.on_health(ev.t, dev, prev, ev.state)
            transitions.append((dev, prev, ev.state))
        return transitions

    def final_state(self, dev) -> Optional[str]:
        """The health state the schedule leaves ``dev`` in once every event
        has fired — None when the schedule never touches it. Used by the
        static analyzer (IO501) to flag durable tiers the schedule kills
        without recovery."""
        ev = self._final.get(id(dev))
        return ev.state if ev is not None else None

    def summary(self) -> dict:
        return {
            "events": len(self.schedule),
            "transitions": self.n_transitions,
            "pending": len(self._heap),
            "log": list(self.log),
        }
