"""Data-dependency detection (paper §4.1.2).

Futures passed as arguments create read-after-write dependencies on the
producing task. DataHandles passed to parameters declared INOUT/OUT get
COMPSs-style version bumps: a writer depends on the previous writer *and*
on every reader of the current version (serialising in-place updates).
"""
from __future__ import annotations

import inspect

from .task import (DataHandle, Direction, Future, TaskInstance, TaskState)


def _param_names(defn) -> list[str]:
    cache = getattr(defn, "_param_names", None)
    if cache is None:
        try:
            cache = list(inspect.signature(defn.fn).parameters)
        except (TypeError, ValueError):
            cache = []
        defn._param_names = cache
    return cache


def iter_futures(obj, _depth=0):
    """Futures in an argument, recursing through lists/tuples/dicts (a task
    may take e.g. a list of futures — the checkpoint commit barrier does)."""
    if isinstance(obj, Future):
        yield obj
    elif _depth < 4:
        if isinstance(obj, (list, tuple)):
            for v in obj:
                yield from iter_futures(v, _depth + 1)
        elif isinstance(obj, dict):
            for v in obj.values():
                yield from iter_futures(v, _depth + 1)


def bind_args(task: "TaskInstance") -> list:
    """(param_name, argument) pairs in binding order (positional then
    keyword) — the order dependency detection and DataHandle version
    bumps observe."""
    names = _param_names(task.defn)
    return list(zip(names, task.args)) + list(task.kwargs.items())


def compute_deps(task: "TaskInstance", pairs=None) -> dict:
    """Predecessor detection WITHOUT mutating any DataHandle bookkeeping:
    maps each predecessor TaskInstance to True for a *data* edge
    (read-after-write / write-after-write) or False for an *anti* edge
    (write-after-read ordering only). Data wins when both apply.

    :meth:`TaskGraph.add` applies the handle side effects afterwards via
    :func:`apply_handle_effects`; the static-analysis capture recorder
    (repro.analysis.capture) calls this directly to record the full
    happens-before relation — including edges to already-DONE producers,
    which ``add`` elides as satisfied.

    ``pairs`` optionally carries a precomputed :func:`bind_args` result so
    a caller running both passes binds the arguments once.
    """
    deps: dict = {}  # predecessor TaskInstance -> is_data
    for pname, arg in (bind_args(task) if pairs is None else pairs):
        if isinstance(arg, DataHandle):
            direction = task.defn.param_dirs.get(pname, Direction.IN)
            if direction == Direction.IN:
                if arg.last_writer is not None:
                    deps[arg.last_writer] = True
            else:  # INOUT / OUT: write-after-write + write-after-read
                if direction == Direction.INOUT and \
                        arg.last_writer is not None:
                    deps[arg.last_writer] = True
                for r in arg.readers_since_write:
                    if r is not task:
                        deps.setdefault(r, False)  # anti edge
        else:
            for fut in iter_futures(arg):
                deps[fut.task] = True
    deps.pop(task, None)  # a handle passed twice can't self-depend
    return deps


def apply_handle_effects(task: "TaskInstance", pairs=None) -> None:
    """Second pass of dependency detection: record this task against every
    DataHandle argument (reader lists, version bumps, last-writer) in the
    same binding order the one-pass implementation used. ``pairs`` as in
    :func:`compute_deps`."""
    for pname, arg in (bind_args(task) if pairs is None else pairs):
        if not isinstance(arg, DataHandle):
            continue
        direction = task.defn.param_dirs.get(pname, Direction.IN)
        if direction == Direction.IN:
            arg.readers_since_write.append(task)
        else:
            arg.version += 1
            arg.last_writer = task
            arg.readers_since_write = []


class TaskGraph:
    def __init__(self):
        self.tasks: dict[int, TaskInstance] = {}
        self.unfinished: int = 0
        self._missing_deps: dict[int, int] = {}  # tid -> #unfinished deps
        # sharded control plane (core.shardplane): when the runtime routes
        # tasks to shards it flips track_shards so every edge is classified
        # at add() time — cross-shard edges are the dependency messages the
        # ShardBus will carry (already-DONE producers included: the edge
        # crossed the boundary even if it never blocked anything)
        self.track_shards = False
        self.cross_shard_edges = 0
        self.local_edges = 0

    def add(self, task: TaskInstance) -> bool:
        """Register a task; returns True if it is immediately ready.

        Edges are tagged by kind: *data* edges (futures, read-after-write,
        write-after-write on INOUT) require the producer to SUCCEED; *anti*
        edges (write-after-read serialisation) only require the predecessor
        to be out of the way, so a FAILED/cancelled predecessor satisfies
        them instead of propagating the failure.
        """
        pairs = bind_args(task)  # bound once, shared by both passes
        deps = compute_deps(task, pairs)  # dep -> is_data (data wins)
        apply_handle_effects(task, pairs)
        if self.track_shards:
            shard = task.shard
            for d in deps:
                if d.shard == shard:
                    self.local_edges += 1
                else:
                    self.cross_shard_edges += 1

        task.deps = set()
        task.anti_deps = set()
        dead = None
        for d, is_data in deps.items():
            if d.state == TaskState.DONE:
                continue  # satisfied
            if d.state == TaskState.FAILED:
                if is_data:
                    dead = dead or d  # producer already crashed: doomed
                continue  # a failed anti-predecessor is out of the way
            task.deps.add(d.tid)
            if not is_data:
                task.anti_deps.add(d.tid)
            d.children.append(task.tid)
        self.tasks[task.tid] = task
        if dead is not None:
            task.state = TaskState.FAILED
            task.error = RuntimeError(
                f"cancelled: ancestor {dead.defn.name}#{dead.tid} failed")
            for f in task.futures:
                f.set_value(None)  # cancelled: resolve so waiters can't hang
            return False
        self.unfinished += 1
        if not task.deps:
            task.state = TaskState.READY
            return True
        self._missing_deps[task.tid] = len(task.deps)
        return False

    def complete(self, task: TaskInstance) -> list[TaskInstance]:
        """Mark done; return children that became ready.

        Children are stored as tids and appended at submission time, so the
        returned batch is deterministically in submission (tid) order — the
        scheduler relies on this for reproducible launch logs.
        """
        task.state = TaskState.DONE
        self.unfinished -= 1
        newly_ready = []
        missing = self._missing_deps
        for ctid in task.children:
            child = self.tasks[ctid]
            if child.state != TaskState.PENDING:
                continue
            missing[ctid] -= 1
            if missing[ctid] == 0:
                del missing[ctid]
                child.state = TaskState.READY
                newly_ready.append(child)
        return newly_ready

    def fail(self, task: TaskInstance
             ) -> tuple[list[TaskInstance], list[TaskInstance]]:
        """Remove a FAILED task from the graph and cancel its descendants.

        A PENDING task downstream of a failure can never have its missing
        *data* dependency satisfied; without transitive cancellation those
        tasks would keep ``unfinished`` positive forever and hang any drain
        loop waiting on it. *Anti* edges (write-after-read) are instead
        treated as satisfied — the failed predecessor will never touch the
        handle — so their successors may become READY. Returns
        ``(cancelled, newly_ready)``, each in submission order.
        """
        self.unfinished -= 1
        cancelled: list[TaskInstance] = []
        newly_ready: list[TaskInstance] = []
        missing = self._missing_deps
        stack = [task]
        while stack:
            failed = stack.pop()
            for ctid in failed.children:
                child = self.tasks.get(ctid)
                if child is None or child.state != TaskState.PENDING:
                    continue  # descendants of an unfinished failure that are
                #               not DONE/FAILED are necessarily PENDING
                if failed.tid in child.anti_deps:
                    # ordering-only edge: satisfied by the cancellation
                    missing[ctid] -= 1
                    if missing[ctid] == 0:
                        del missing[ctid]
                        child.state = TaskState.READY
                        newly_ready.append(child)
                    continue
                child.state = TaskState.FAILED
                if child.error is None:
                    child.error = RuntimeError(
                        f"cancelled: ancestor "
                        f"{failed.defn.name}#{failed.tid} failed")
                for f in child.futures:
                    f.set_value(None)  # resolve: wait_on a cancelled task's
                #                        future must return, not hang a drain
                missing.pop(ctid, None)
                self.unfinished -= 1
                cancelled.append(child)
                stack.append(child)
        cancelled.sort(key=lambda t: t.tid)
        newly_ready.sort(key=lambda t: t.tid)
        return cancelled, newly_ready
