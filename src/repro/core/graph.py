"""Data-dependency detection (paper §4.1.2).

Futures passed as arguments create read-after-write dependencies on the
producing task. DataHandles passed to parameters declared INOUT/OUT get
COMPSs-style version bumps: a writer depends on the previous writer *and*
on every reader of the current version (serialising in-place updates).
"""
from __future__ import annotations

import inspect
from typing import Iterable

from .task import (DataHandle, Direction, Future, TaskInstance, TaskState)


def _param_names(defn) -> list[str]:
    cache = getattr(defn, "_param_names", None)
    if cache is None:
        try:
            cache = list(inspect.signature(defn.fn).parameters)
        except (TypeError, ValueError):
            cache = []
        defn._param_names = cache
    return cache


def iter_futures(obj, _depth=0):
    """Futures in an argument, recursing through lists/tuples/dicts (a task
    may take e.g. a list of futures — the checkpoint commit barrier does)."""
    if isinstance(obj, Future):
        yield obj
    elif _depth < 4:
        if isinstance(obj, (list, tuple)):
            for v in obj:
                yield from iter_futures(v, _depth + 1)
        elif isinstance(obj, dict):
            for v in obj.values():
                yield from iter_futures(v, _depth + 1)


class TaskGraph:
    def __init__(self):
        self.tasks: dict[int, TaskInstance] = {}
        self.unfinished: int = 0
        self._missing_deps: dict[int, int] = {}  # tid -> #unfinished deps

    def add(self, task: TaskInstance) -> bool:
        """Register a task; returns True if it is immediately ready."""
        names = _param_names(task.defn)
        bound = list(zip(names, task.args)) + list(task.kwargs.items())

        deps: set[TaskInstance] = set()
        for pname, arg in bound:
            if not isinstance(arg, DataHandle):
                for fut in iter_futures(arg):
                    if fut.task.state not in (TaskState.DONE,):
                        deps.add(fut.task)
            if isinstance(arg, DataHandle):
                direction = task.defn.param_dirs.get(pname, Direction.IN)
                if direction == Direction.IN:
                    if arg.last_writer is not None and \
                            arg.last_writer.state != TaskState.DONE:
                        deps.add(arg.last_writer)
                    arg.readers_since_write.append(task)
                else:  # INOUT / OUT: write-after-write + write-after-read
                    if direction == Direction.INOUT and arg.last_writer is not None \
                            and arg.last_writer.state != TaskState.DONE:
                        deps.add(arg.last_writer)
                    for r in arg.readers_since_write:
                        if r.state != TaskState.DONE and r is not task:
                            deps.add(r)
                    arg.version += 1
                    arg.last_writer = task
                    arg.readers_since_write = []

        task.deps = {d.tid for d in deps}
        for d in deps:
            d.children.append(task)
        self.tasks[task.tid] = task
        self._missing_deps[task.tid] = len(task.deps)
        self.unfinished += 1
        if not task.deps:
            task.state = TaskState.READY
            return True
        return False

    def complete(self, task: TaskInstance) -> list[TaskInstance]:
        """Mark done; return children that became ready."""
        task.state = TaskState.DONE
        self.unfinished -= 1
        newly_ready = []
        for child in task.children:
            if child.state != TaskState.PENDING:
                continue
            self._missing_deps[child.tid] -= 1
            if self._missing_deps[child.tid] == 0:
                child.state = TaskState.READY
                newly_ready.append(child)
        return newly_ready
