"""Co-tenant interference subsystem: background traffic on shared tiers.

The runtime's shared devices (burst buffer, parallel FS) are cluster-global
budgets, but until now only *this* runtime's tasks drew from them — the
autotuner therefore learned constraints that hold only when the runtime is
the cluster's sole tenant. This module injects **background load** from
co-tenant applications into :class:`~repro.core.resources.StorageDevice`\\ s
so that calibration, steady-state scheduling and the eviction machinery all
see the storage the cluster actually provides, not its nameplate.

Two interference channels, both first-class consumers of the device budgets
(resources.py):

* **Bandwidth interference** — a burst joins the congestion model with its
  own fair-share streams (our tasks' per-task rate drops to
  ``A(k + bg) / (k + bg)``) and takes bandwidth out of the allocatable
  budget, so the scheduler cannot grant constraints the co-tenant is
  already using. Claims are clamped to the free budget: a co-tenant can
  *contend*, never *over-commit*.
* **Capacity interference** — a co-tenant fills tier capacity (its own
  checkpoints landing on the shared burst buffer). The filled space counts
  toward occupancy, so it can push a tier over its eviction watermarks
  (datalife.py synthesizes drains of *our* cold objects) and capacity-block
  our grants. Also clamped: the device never overfills.

Traffic models (pluggable, all deterministic)
---------------------------------------------
:class:`ConstantTraffic`
    A steady co-tenant: fixed streams/bandwidth/capacity from ``start`` on.
:class:`BurstyTraffic`
    Seeded stochastic on–off bursts (exponential on/off durations via
    ``random.Random(seed)``): the classic checkpointing co-tenant. The same
    seed always produces the same burst train — runs are bit-reproducible.
:class:`TraceTraffic`
    Replay of an explicit schedule; :meth:`TraceTraffic.from_jsonl` loads
    the simple JSONL schema (one event per line)::

        {"t": 10.0, "dur": 5.0, "streams": 32, "bw": 400.0, "capacity_mb": 0}

The :class:`InterferenceEngine` binds models to devices (by tier label or
device name), turns their interval streams into a deterministic event heap,
and is driven by ``SimBackend``'s event loop: burst starts/ends are
simulation events exactly like task finishes, so rates integrate piecewise
between them. With no engine attached (or no bindings) the simulator's
arithmetic is bit-identical to the interference-free implementation — the
golden-parity suite pins this.

Interference is a *simulation* concept: ``RealBackend`` refuses an engine
(real co-tenants are injected by the cluster, not by us).
"""
from __future__ import annotations

import heapq
import itertools
import json
import random
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Tuple

from .resources import Cluster, StorageDevice

_INF = float("inf")


@dataclass(frozen=True)
class Burst:
    """One background interval: at ``start`` (seconds), for ``duration``
    seconds, the co-tenant holds ``streams`` congestion-model streams,
    ``bw`` MB/s of allocatable bandwidth and ``capacity_mb`` MB of tier
    capacity (each clamped at claim time)."""

    start: float
    duration: float
    streams: int = 1
    bw: float = 0.0
    capacity_mb: float = 0.0

    def __post_init__(self):
        if self.start < 0:
            raise ValueError(f"burst start must be >= 0, got {self.start}")
        if self.duration <= 0:
            raise ValueError(
                f"burst duration must be positive, got {self.duration}")
        if self.streams < 0 or self.bw < 0 or self.capacity_mb < 0:
            raise ValueError(
                f"burst streams/bw/capacity_mb must be non-negative "
                f"(got {self.streams}/{self.bw}/{self.capacity_mb})")


class TrafficModel:
    """A deterministic stream of :class:`Burst` intervals."""

    def bursts(self) -> Iterator[Burst]:
        raise NotImplementedError


class ConstantTraffic(TrafficModel):
    """A co-tenant that is always there (one burst from ``start`` to
    ``until``, default forever)."""

    def __init__(self, streams: int = 1, bw: float = 0.0,
                 capacity_mb: float = 0.0, start: float = 0.0,
                 until: float = _INF):
        if until <= start:
            raise ValueError(f"until ({until}) must exceed start ({start})")
        self._burst = Burst(start=start, duration=until - start,
                            streams=streams, bw=bw, capacity_mb=capacity_mb)

    def bursts(self) -> Iterator[Burst]:
        yield self._burst


class BurstyTraffic(TrafficModel):
    """Seeded stochastic on–off traffic.

    Off/on durations are exponential with means ``off_mean``/``on_mean``
    (the memoryless arrival process of an independent co-tenant); the
    generator is ``random.Random(seed)``, so the burst train is a pure
    function of the constructor arguments. ``until`` bounds the train (a
    burst straddling ``until`` is truncated to it).

    ``seed=None`` is allowed but draws the train from OS entropy — runs
    stop being reproducible, and the static analyzer flags every such
    model bound to a device (diagnostic ``IO401``, ``seeded`` False).
    """

    def __init__(self, seed: Optional[int], on_mean: float, off_mean: float,
                 streams: int = 1, bw: float = 0.0,
                 capacity_mb: float = 0.0, until: float = _INF):
        if on_mean <= 0 or off_mean <= 0:
            raise ValueError(
                f"on_mean/off_mean must be positive "
                f"(got {on_mean}/{off_mean})")
        self.seed = None if seed is None else int(seed)
        self.seeded = self.seed is not None
        self.on_mean = float(on_mean)
        self.off_mean = float(off_mean)
        self.streams = int(streams)
        self.bw = float(bw)
        self.capacity_mb = float(capacity_mb)
        self.until = float(until)

    def bursts(self) -> Iterator[Burst]:
        rng = random.Random(self.seed)
        t = 0.0
        while True:
            t += rng.expovariate(1.0 / self.off_mean)
            if t >= self.until:
                return
            dur = rng.expovariate(1.0 / self.on_mean)
            dur = min(dur, self.until - t)
            if dur > 0:
                yield Burst(start=t, duration=dur, streams=self.streams,
                            bw=self.bw, capacity_mb=self.capacity_mb)
            t += dur


class TraceTraffic(TrafficModel):
    """Replay an explicit burst schedule (e.g. recorded from a real
    co-tenant). Bursts may be given in any order; replay is by start time."""

    def __init__(self, bursts: Iterable[Burst]):
        self._bursts = sorted(bursts, key=lambda b: (b.start, b.duration))

    def bursts(self) -> Iterator[Burst]:
        return iter(self._bursts)

    @staticmethod
    def from_jsonl(path_or_lines) -> "TraceTraffic":
        """Load the JSONL schedule schema: one object per line with keys
        ``t`` (start, required), ``dur`` (required) and optional
        ``streams``/``bw``/``capacity_mb``. Accepts a file path or any
        iterable of lines (so tests can pass strings directly)."""
        if isinstance(path_or_lines, str):
            with open(path_or_lines) as f:
                lines = f.readlines()
        else:
            lines = list(path_or_lines)
        out = []
        for i, line in enumerate(lines):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"trace line {i + 1}: invalid JSON ({e})") from e
            if not isinstance(rec, dict):
                raise ValueError(
                    f"trace line {i + 1}: expected a JSON object, got "
                    f"{type(rec).__name__} ({line[:60]!r})")
            if "t" not in rec or "dur" not in rec:
                raise ValueError(
                    f"trace line {i + 1}: needs 't' and 'dur' keys, got "
                    f"{sorted(rec)}")
            try:
                out.append(Burst(
                    start=float(rec["t"]),
                    duration=float(rec["dur"]),
                    streams=int(rec.get("streams", 1)),
                    bw=float(rec.get("bw", 0.0)),
                    capacity_mb=float(rec.get("capacity_mb", 0.0))))
            except (TypeError, ValueError) as e:
                # malformed values (negative duration, non-numeric fields)
                # surface with the line number instead of a bare Burst/
                # float error from deep inside model construction
                raise ValueError(
                    f"trace line {i + 1}: invalid record ({e})") from e
        return TraceTraffic(out)


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------
class _Binding:
    """One (device, model) pair with its lazily-pulled burst iterator."""

    __slots__ = ("device", "model", "it", "next_burst")

    def __init__(self, device: StorageDevice, model: TrafficModel):
        self.device = device
        self.model = model
        self.it = model.bursts()
        self.next_burst: Optional[Burst] = next(self.it, None)

    def pull(self) -> Optional[Burst]:
        b, self.next_burst = self.next_burst, next(self.it, None)
        return b


class InterferenceEngine:
    """Deterministic event source of co-tenant traffic for the simulator.

    Construct with ``targets``: an iterable of ``(target, model)`` where
    ``target`` is a tier label (every device of the tier gets the model) or
    a device name. The engine exposes :meth:`next_time` /
    :meth:`apply_due`; ``SimBackend`` treats burst boundaries as simulation
    events. Each applied start records what was *actually* claimed (clamped
    to the device's free budgets) so the matching end returns exactly that.
    """

    def __init__(self, targets: Iterable[Tuple[str, TrafficModel]],
                 cluster: Cluster):
        self.cluster = cluster
        self._bindings: list[_Binding] = []
        for target, model in targets:
            if not isinstance(model, TrafficModel):
                raise TypeError(
                    f"interference target {target!r}: model must be a "
                    f"TrafficModel, got {type(model).__name__}")
            devs = [d for d in cluster.devices
                    if d.tier == target or d.name == target]
            if not devs:
                raise ValueError(
                    f"interference target {target!r} matches no tier or "
                    f"device (tiers: {cluster.tier_names()}, devices: "
                    f"{[d.name for d in cluster.devices]})")
            for d in devs:
                self._bindings.append(_Binding(d, model))
        # event heap: (time, kind, seq, payload) — kind 0 = burst end,
        # 1 = burst start, so an end at time t applies before a start at t
        # (back-to-back bursts hand the budget over cleanly)
        self._heap: list = []
        self._seq = itertools.count()
        for i, b in enumerate(self._bindings):
            burst = b.pull()
            if burst is not None:
                heapq.heappush(self._heap,
                               (burst.start, 1, next(self._seq), (i, burst)))
        # trace recorder (obs/): wired by the runtime when tracing is on;
        # None costs one comparison per burst boundary
        self.recorder = None
        # stats
        self.n_bursts = 0
        self.bg_busy_time: dict[str, float] = {}     # device -> burst seconds
        #                                              (finite bursts only)
        self.bg_unbounded: dict[str, int] = {}       # device -> #never-ending
        #                                              bursts (until=inf)
        self.bg_capacity_peak: dict[str, float] = {} # device -> max bg MB held
        self.bg_bw_peak: dict[str, float] = {}       # device -> max bg MB/s

    @property
    def active(self) -> bool:
        return bool(self._bindings)

    def next_time(self) -> float:
        return self._heap[0][0] if self._heap else _INF

    def apply_due(self, now: float, eps: float = 1e-9) -> bool:
        """Apply every burst boundary at or before ``now``. Returns True if
        any boundary was applied (rates/budgets changed: the caller must
        refresh stale finish estimates and re-run a schedule pass)."""
        applied = False
        while self._heap and self._heap[0][0] <= now + eps:
            _, kind, _, payload = heapq.heappop(self._heap)
            if kind == 1:
                self._start_burst(*payload)
            else:
                self._end_burst(*payload)
            applied = True
        return applied

    def _start_burst(self, bi: int, burst: Burst) -> None:
        b = self._bindings[bi]
        dev = b.device
        taken_bw = dev.add_background(burst.streams, burst.bw)
        taken_mb = dev.add_background_capacity(burst.capacity_mb)
        if self.recorder is not None:
            # what was actually claimed (clamped), not the model's ask
            self.recorder.on_burst(burst.start, dev, "start",
                                   burst.streams, taken_bw, taken_mb)
        self.n_bursts += 1
        if burst.duration != _INF:
            self.bg_busy_time[dev.name] = \
                self.bg_busy_time.get(dev.name, 0.0) + burst.duration
        else:
            # a steady co-tenant (until=inf): count it rather than poison
            # the summary with an Infinity that strict JSON rejects
            self.bg_unbounded[dev.name] = \
                self.bg_unbounded.get(dev.name, 0) + 1
        self.bg_capacity_peak[dev.name] = max(
            self.bg_capacity_peak.get(dev.name, 0.0), dev.background_mb)
        self.bg_bw_peak[dev.name] = max(
            self.bg_bw_peak.get(dev.name, 0.0), dev.background_bw)
        end = burst.start + burst.duration
        heapq.heappush(self._heap, (end, 0, next(self._seq),
                                    (bi, burst, taken_bw, taken_mb)))
        # pull the binding's next burst into the heap
        nxt = b.pull()
        if nxt is not None:
            heapq.heappush(self._heap,
                           (nxt.start, 1, next(self._seq), (bi, nxt)))

    def _end_burst(self, bi: int, burst: Burst, taken_bw: float,
                   taken_mb: float) -> None:
        dev = self._bindings[bi].device
        dev.remove_background(burst.streams, taken_bw)
        dev.remove_background_capacity(taken_mb)
        if self.recorder is not None:
            self.recorder.on_burst(burst.start + burst.duration, dev, "end",
                                   burst.streams, taken_bw, taken_mb)

    def summary(self) -> dict:
        return {
            "n_bursts": self.n_bursts,
            "bg_busy_time": dict(self.bg_busy_time),
            "bg_unbounded_bursts": dict(self.bg_unbounded),
            "bg_capacity_peak_mb": dict(self.bg_capacity_peak),
            "bg_bw_peak_mbs": dict(self.bg_bw_peak),
            "devices": {
                b.device.name: b.device.tier for b in self._bindings},
        }
