"""Resource descriptions: workers, storage tiers, bandwidth accounting.

Mirrors the COMPSs resource-description file (paper §4.1.2) extended with a
maximum I/O bandwidth per storage device (paper §4.2.2), generalised to a
**multi-tier storage hierarchy**: each worker carries an *ordered* list of
tiers (fastest first), each tier its own :class:`StorageDevice` with an
independent bandwidth budget and congestion calibration. The paper's
single-device MareNostrum-4 setup is the one-tier special case
(:meth:`Cluster.make`); :meth:`Cluster.make_tiered` builds the
SSD → burst-buffer → shared-FS layering of modern HPC platforms.

Tier model
----------
* ``WorkerNode.tiers`` is ordered fastest-first; ``WorkerNode.storage`` is an
  alias for ``tiers[0]`` (the node-local device) so single-tier code — and
  the frozen seed scheduler in ``benchmarks/_seed_impl.py`` — is unchanged.
* A :class:`StorageDevice` may be *shared* between workers simply by placing
  the same instance in several tier lists (the burst buffer and the shared
  filesystem below); bandwidth is always accounted per *device*, so a shared
  tier is a single budget no matter how many workers reference it.
* ``StorageDevice.tier`` is the tier label tasks target via the ``tier=``
  hint on ``@constraint`` or the per-call ``storage_tier=`` override
  (see ``runtime.py``); the scheduler's default policy is label-free:
  prefer the fastest tier with budget, fall back down the hierarchy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StorageDevice:
    """A storage device with a bandwidth budget for constraint accounting.

    ``bandwidth`` is the budget the scheduler allocates constraint values
    from (MB/s). The congestion model parameters describe how the *achieved*
    aggregate throughput behaves as a function of the number of concurrent
    streams (see storage_model.py); they drive the simulator and default to
    the MareNostrum-4 node-local SSD calibration from the paper. Each tier of
    a hierarchy is its own device with its own calibration.
    """

    name: str
    bandwidth: float = 450.0        # MB/s, budget for storageBW accounting
    per_stream_cap: float = 8.0     # MB/s a single stream can achieve
    congestion_alpha: float = 0.004  # linear penalty per stream past the knee
    congestion_beta: float = 1e-5   # quadratic term: fsync seek-thrash at
    #                                 very high concurrency is superlinear
    congestion_knee: Optional[int] = None  # default: bandwidth/per_stream_cap
    tier: str = "ssd"               # tier label (targetable via tier= hints)
    capacity_gb: Optional[float] = None  # finite capacity budget; None =
    #                                      unlimited (the seed behaviour)

    def __post_init__(self):
        if self.capacity_gb is not None and self.capacity_gb <= 0:
            raise ValueError(
                f"device {self.name}: capacity_gb must be positive "
                f"(got {self.capacity_gb}); use capacity_gb=None for an "
                f"unlimited tier")
        if self.bandwidth <= 0:
            raise ValueError(
                f"device {self.name}: bandwidth must be positive "
                f"(got {self.bandwidth})")
        if self.congestion_knee is None:
            self.congestion_knee = max(1, int(self.bandwidth / self.per_stream_cap))
        # --- dynamic accounting state ---
        self.available_bw: float = self.bandwidth
        self.active_io: int = 0          # running I/O tasks on this device
        self.bytes_written: float = 0.0  # MB, for throughput reporting
        self.rate_epoch: int = 0         # bumped whenever active_io changes:
        #                                  the O(1) "did this device's rate
        #                                  change" check for rate caches
        self.release_epoch: int = 0      # bumped on releases only — the sole
        #                                  rate-RAISING change, i.e. the only
        #                                  one that can make cached finish-time
        #                                  lower bounds stale-late
        # --- capacity occupancy state (symmetric to the bandwidth budget:
        #     reserve-at-grant, commit-at-finish, free-at-eviction) ---
        self.used_mb: float = 0.0        # committed resident bytes (MB)
        self.reserved_mb: float = 0.0    # in-flight writer reservations (MB)
        self.peak_occupancy_mb: float = 0.0  # high-water mark of used+reserved
        # --- co-tenant (background) traffic state (interference.py) ---
        # Background streams share the congestion model fairly with our
        # tasks; background bandwidth/capacity claims are clamped to the
        # free budget so a co-tenant can never over-commit the device.
        self.background_streams: int = 0
        self.background_bw: float = 0.0  # MB/s currently held by co-tenants
        self.background_mb: float = 0.0  # capacity currently held (MB)
        # --- failure-domain health state (failures.py) ---
        # healthy -> degraded(bw_factor) -> offline, driven by a
        # FailureSchedule; healthy keeps every accounting path (and all
        # simulator arithmetic) identical to a pre-failure-domain device.
        self.health: str = "healthy"
        self.bw_factor: float = 1.0      # effective-bandwidth fraction
        #                                  while degraded (1.0 otherwise)
        # memoized per-task-rate curve (storage_model.per_task_rate):
        # k -> MB/s, valid while calibration and health are unchanged
        self._rate_cache: dict = {}

    def invalidate_rates(self) -> None:
        """Drop the memoized T(k) curve. Must be called after any mutation
        of the congestion calibration (bandwidth, per_stream_cap, alpha,
        beta, knee — see obs.telemetry.apply_tier_config) or of the health
        state; population changes (active_io, background_streams) need no
        invalidation because they are the ``k`` argument, not cached
        state."""
        self._rate_cache.clear()

    # -- failure-domain health (failures.py) ---------------------------------
    @property
    def effective_bandwidth(self) -> float:
        """The bandwidth the device can actually deliver in its current
        health state: 0 offline, ``bw_factor * bandwidth`` degraded."""
        if self.health == "offline":
            return 0.0
        return self.bandwidth * self.bw_factor

    def set_health(self, state: str, bw_factor: float = 1.0) -> None:
        """Transition the device's health. Any transition can change the
        achievable rate in either direction, so both epochs bump — the
        simulator re-checks every cached finish-time estimate."""
        if state not in ("healthy", "degraded", "offline"):
            raise ValueError(f"unknown health state {state!r}")
        if state == "degraded":
            if not (0.0 < bw_factor <= 1.0):
                raise ValueError(
                    f"degraded bw_factor must be in (0, 1], got {bw_factor}")
            self.bw_factor = bw_factor
        else:
            self.bw_factor = 1.0
        self.health = state
        self.invalidate_rates()
        self.rate_epoch += 1
        self.release_epoch += 1

    # -- budget accounting (scheduler-facing) --------------------------------
    def can_allocate(self, bw: float) -> bool:
        if self.health == "healthy":
            return bw <= self.available_bw + 1e-9
        if self.health == "offline":
            return False
        # degraded: the lost fraction of the nameplate budget is not
        # allocatable — grants must fit under what the device can deliver
        lost = self.bandwidth - self.effective_bandwidth
        return bw <= self.available_bw - lost + 1e-9

    def allocate(self, bw: float) -> None:
        if not self.can_allocate(bw):
            raise RuntimeError(
                f"over-allocating device {self.name}: want {bw}, have {self.available_bw}")
        self.available_bw -= bw
        self.active_io += 1
        self.rate_epoch += 1

    def release(self, bw: float) -> None:
        self.available_bw += bw
        self.active_io -= 1
        self.rate_epoch += 1
        self.release_epoch += 1
        if self.active_io < 0 or self.available_bw > self.bandwidth + 1e-6:
            raise RuntimeError(f"bandwidth accounting underflow on {self.name}")

    # -- co-tenant (background) traffic (interference.py) --------------------
    def add_background(self, streams: int, bw: float) -> float:
        """A co-tenant burst arrives: it joins the congestion model with
        ``streams`` fair-share streams and takes up to ``bw`` MB/s out of
        the allocatable budget — clamped to what is actually free, so the
        scheduler's own grants are never invalidated. Returns the bandwidth
        actually taken (pass it back to :meth:`remove_background`)."""
        headroom = self.available_bw
        if self.health == "offline":
            headroom = 0.0
        elif self.health == "degraded":
            headroom = max(
                0.0, headroom - (self.bandwidth - self.effective_bandwidth))
        taken = min(max(bw, 0.0), headroom)
        self.available_bw -= taken
        self.background_bw += taken
        self.background_streams += max(int(streams), 0)
        self.rate_epoch += 1
        return taken

    def remove_background(self, streams: int, bw_taken: float) -> None:
        """The burst ends: streams leave and the taken bandwidth returns.
        A departure raises per-task rates, so the release epoch bumps (the
        simulator refreshes its finish-time lower bounds on it)."""
        self.available_bw += bw_taken
        self.background_bw -= bw_taken
        self.background_streams -= max(int(streams), 0)
        self.rate_epoch += 1
        self.release_epoch += 1
        if self.background_streams < 0 or self.background_bw < -1e-6 \
                or self.available_bw > self.bandwidth + 1e-6:
            raise RuntimeError(
                f"background traffic accounting underflow on {self.name}")

    def add_background_capacity(self, mb: float) -> float:
        """A co-tenant fills capacity (e.g. its own checkpoints landing on
        the shared burst buffer). Clamped to the free space — the co-tenant
        cannot overfill the device, but by shrinking free capacity it can
        push occupancy over the eviction watermarks and capacity-block our
        grants. Returns the MB actually taken."""
        if self.capacity_gb is None or mb <= 0 or self.health == "offline":
            return 0.0
        taken = min(mb, self.free_capacity_mb())
        if taken <= 0:
            return 0.0
        self.background_mb += taken
        self.peak_occupancy_mb = max(self.peak_occupancy_mb, self.occupancy_mb)
        return taken

    def remove_background_capacity(self, mb_taken: float) -> None:
        if mb_taken <= 0:
            return
        self.background_mb -= mb_taken
        if self.background_mb < -1e-6:
            raise RuntimeError(
                f"background capacity underflow on {self.name}")

    # -- capacity occupancy (data lifecycle; see datalife.py) ----------------
    @property
    def capacity_mb(self) -> Optional[float]:
        return None if self.capacity_gb is None else self.capacity_gb * 1024.0

    @property
    def occupancy_mb(self) -> float:
        """Committed + in-flight-reserved + co-tenant occupancy (MB)."""
        return self.used_mb + self.reserved_mb + self.background_mb

    def free_capacity_mb(self) -> float:
        cap = self.capacity_mb
        if cap is None:
            return float("inf")
        return cap - self.occupancy_mb

    def can_reserve_capacity(self, mb: float) -> bool:
        return mb <= self.free_capacity_mb() + 1e-9

    def reserve_capacity(self, mb: float) -> None:
        """Reserve-at-grant: an I/O task granted on this device claims its
        output footprint up front so concurrent grants can't overcommit."""
        if mb <= 0 or self.capacity_gb is None:
            return
        if not self.can_reserve_capacity(mb):
            raise RuntimeError(
                f"over-filling device {self.name}: want {mb} MB, have "
                f"{self.free_capacity_mb():.1f} MB free of "
                f"{self.capacity_mb:.0f}")
        self.reserved_mb += mb
        self.peak_occupancy_mb = max(self.peak_occupancy_mb, self.occupancy_mb)

    def commit_capacity(self, mb: float) -> None:
        """Commit-at-finish: the reservation becomes resident data."""
        if mb <= 0 or self.capacity_gb is None:
            return
        self.reserved_mb -= mb
        self.used_mb += mb
        if self.reserved_mb < -1e-6:
            raise RuntimeError(f"capacity reservation underflow on {self.name}")

    def cancel_reservation(self, mb: float) -> None:
        """A granted writer failed: its reservation never becomes resident."""
        if mb <= 0 or self.capacity_gb is None:
            return
        self.reserved_mb -= mb
        if self.reserved_mb < -1e-6:
            raise RuntimeError(f"capacity reservation underflow on {self.name}")

    def free_capacity(self, mb: float) -> None:
        """Eviction/deletion: resident data leaves the device."""
        if mb <= 0 or self.capacity_gb is None:
            return
        self.used_mb -= mb
        if self.used_mb < -1e-6:
            raise RuntimeError(f"capacity occupancy underflow on {self.name}")

    def check_invariants(self) -> list:
        """Read-only audit of the accounting state, returning human-readable
        violation messages (empty when consistent). Driven by the inline
        sanitizer (repro.analysis.sanitizer) at every simulation event
        boundary; the runtime's own accounting methods raise eagerly on the
        underflows they can see locally — this catches cross-counter drift
        they can't."""
        eps = 1e-6
        out = []
        if self.available_bw < -eps:
            out.append(
                f"{self.name}: bandwidth over-committed "
                f"(available_bw={self.available_bw:.6f} MB/s)")
        if self.available_bw > self.bandwidth + eps:
            out.append(
                f"{self.name}: bandwidth over-released "
                f"(available_bw={self.available_bw:.6f} exceeds budget "
                f"{self.bandwidth:g} MB/s)")
        if self.active_io < 0:
            out.append(f"{self.name}: active_io negative ({self.active_io})")
        if self.background_streams < 0 or self.background_bw < -eps \
                or self.background_mb < -eps:
            out.append(
                f"{self.name}: background traffic accounting negative "
                f"(streams={self.background_streams}, "
                f"bw={self.background_bw:.6f}, mb={self.background_mb:.6f})")
        if self.used_mb < -eps or self.reserved_mb < -eps:
            out.append(
                f"{self.name}: capacity accounting negative "
                f"(used_mb={self.used_mb:.6f}, "
                f"reserved_mb={self.reserved_mb:.6f})")
        cap = self.capacity_mb
        if cap is not None and self.occupancy_mb > cap + eps:
            out.append(
                f"{self.name}: occupancy {self.occupancy_mb:.3f} MB exceeds "
                f"capacity {cap:.0f} MB (used={self.used_mb:.3f}, "
                f"reserved={self.reserved_mb:.3f}, "
                f"background={self.background_mb:.3f})")
        if self.health not in ("healthy", "degraded", "offline"):
            out.append(f"{self.name}: unknown health state {self.health!r}")
        if not (0.0 < self.bw_factor <= 1.0):
            out.append(
                f"{self.name}: bw_factor {self.bw_factor} outside (0, 1]")
        if self.health == "offline" and self.active_io > 0:
            out.append(
                f"{self.name}: offline device still has "
                f"{self.active_io} active I/O task(s) — in-flight work "
                f"must fail into the retry path on transition")
        return out

    def reset(self):
        self.available_bw = self.bandwidth
        self.active_io = 0
        self.bytes_written = 0.0
        self.invalidate_rates()
        self.rate_epoch += 1
        self.release_epoch += 1
        self.used_mb = 0.0
        self.reserved_mb = 0.0
        self.peak_occupancy_mb = 0.0
        self.background_streams = 0
        self.background_bw = 0.0
        self.background_mb = 0.0
        self.health = "healthy"
        self.bw_factor = 1.0


@dataclass
class WorkerNode:
    """A worker with a compute execution platform and an I/O execution
    platform (paper Fig. 7), fronting an ordered storage hierarchy.

    ``tiers`` lists the storage devices reachable from this node, fastest
    first; ``storage`` stays the legacy alias for ``tiers[0]``. Constructing
    with only ``storage=`` (or nothing) yields the paper's one-tier node.
    """

    name: str
    cpus: int = 48
    io_executors: int = 225
    storage: StorageDevice = None  # node-local device (alias for tiers[0])
    tiers: list = None             # ordered hierarchy, fastest first

    def __post_init__(self):
        if self.tiers is None:
            if self.storage is None:
                self.storage = StorageDevice(name=f"{self.name}-ssd")
            self.tiers = [self.storage]
        else:
            if not self.tiers:
                raise ValueError(f"worker {self.name}: tiers must be non-empty")
            if self.storage is not None and self.storage is not self.tiers[0]:
                raise ValueError(
                    f"worker {self.name}: storage= and tiers[0] disagree — "
                    f"pass one or the other")
            self.storage = self.tiers[0]
        self.free_cpus: int = self.cpus
        self.free_io_executors: int = self.io_executors
        self.learning_owner = None   # signature owning this node as an
        #                              active-learning node (paper §4.2.3B)

    def tier_device(self, tier: str) -> Optional[StorageDevice]:
        """The device backing tier label ``tier`` on this node, or None."""
        for d in self.tiers:
            if d.tier == tier:
                return d
        return None

    def reset(self):
        self.free_cpus = self.cpus
        self.free_io_executors = self.io_executors
        self.learning_owner = None
        for d in self.tiers:
            d.reset()


@dataclass
class Cluster:
    """The resource pool the scheduler draws from.

    ``shared_workdir`` mirrors the paper: when True, task outputs live on a
    shared FS so I/O tasks go to the first candidate node; when False the
    scheduler prefers data locality.
    """

    workers: list = field(default_factory=list)
    shared_workdir: bool = True

    @staticmethod
    def make(n_workers: int = 12, cpus: int = 48, io_executors: int = 225,
             device_bw: float = 450.0, per_stream_cap: float = 8.0,
             congestion_alpha: float = 0.004,
             shared_storage: bool = False) -> "Cluster":
        """Build the paper's 12-node MareNostrum-4-like cluster by default."""
        shared_dev = StorageDevice(
            name="shared-fs", bandwidth=device_bw,
            per_stream_cap=per_stream_cap,
            congestion_alpha=congestion_alpha,
            tier="fs") if shared_storage else None
        workers = []
        for i in range(n_workers):
            dev = shared_dev or StorageDevice(
                name=f"w{i}-ssd", bandwidth=device_bw,
                per_stream_cap=per_stream_cap,
                congestion_alpha=congestion_alpha)
            workers.append(WorkerNode(
                name=f"w{i}", cpus=cpus, io_executors=io_executors, storage=dev))
        return Cluster(workers=workers)

    @staticmethod
    def make_tiered(n_workers: int = 12, cpus: int = 48,
                    io_executors: int = 225,
                    ssd_bw: float = 450.0, ssd_stream_cap: float = 8.0,
                    bb_bw: float = 1600.0, bb_stream_cap: float = 40.0,
                    fs_bw: float = 300.0, fs_stream_cap: float = 4.0,
                    congestion_alpha: float = 0.004,
                    ssd_capacity_gb: Optional[float] = None,
                    bb_capacity_gb: Optional[float] = None,
                    fs_capacity_gb: Optional[float] = None) -> "Cluster":
        """Three-tier hierarchy: node-local SSD → shared burst buffer →
        shared parallel FS.

        The SSD tier is one device *per worker* (as in :meth:`make`); the
        burst buffer and the shared FS are each a single shared device
        referenced by every worker, so their budgets are cluster-global.
        Defaults sketch a DataWarp-like burst buffer (high aggregate
        bandwidth, generous per-stream rate) over a congested parallel FS
        (modest aggregate bandwidth shared by everyone).

        ``*_capacity_gb`` gives the tier a finite capacity budget (per
        device: each worker SSD individually, the shared bb/fs globally);
        None keeps the tier unlimited — the data lifecycle subsystem
        (datalife.py) activates whenever any tier is finite.
        """
        bb = StorageDevice(name="burst-buffer", bandwidth=bb_bw,
                           per_stream_cap=bb_stream_cap,
                           congestion_alpha=congestion_alpha, tier="bb",
                           capacity_gb=bb_capacity_gb)
        fs = StorageDevice(name="shared-fs", bandwidth=fs_bw,
                           per_stream_cap=fs_stream_cap,
                           congestion_alpha=congestion_alpha, tier="fs",
                           capacity_gb=fs_capacity_gb)
        workers = []
        for i in range(n_workers):
            ssd = StorageDevice(name=f"w{i}-ssd", bandwidth=ssd_bw,
                                per_stream_cap=ssd_stream_cap,
                                congestion_alpha=congestion_alpha, tier="ssd",
                                capacity_gb=ssd_capacity_gb)
            workers.append(WorkerNode(
                name=f"w{i}", cpus=cpus, io_executors=io_executors,
                tiers=[ssd, bb, fs]))
        return Cluster(workers=workers)

    @property
    def devices(self):
        seen, out = set(), []
        for w in self.workers:
            for d in w.tiers:
                if id(d) not in seen:
                    seen.add(id(d))
                    out.append(d)
        return out

    def tier_names(self) -> list:
        """Distinct tier labels present in the cluster, hierarchy order."""
        seen, out = set(), []
        for w in self.workers:
            for d in w.tiers:
                if d.tier not in seen:
                    seen.add(d.tier)
                    out.append(d.tier)
        return out

    def has_tier(self, tier: str) -> bool:
        return any(w.tier_device(tier) is not None for w in self.workers)

    def tier_spec(self, tier: str) -> Optional[StorageDevice]:
        """A representative device for ``tier`` (the first worker's), used
        for analytic estimates like cross-tier read floors."""
        for w in self.workers:
            d = w.tier_device(tier)
            if d is not None:
                return d
        return None

    def reset(self):
        for w in self.workers:
            w.reset()

    def total_cpus(self) -> int:
        return sum(w.cpus for w in self.workers)
