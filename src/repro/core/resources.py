"""Resource descriptions: workers, storage devices, bandwidth accounting.

Mirrors the COMPSs resource-description file (paper §4.1.2) extended with a
maximum I/O bandwidth per storage device (paper §4.2.2). Bandwidth is
accounted per *device*: node-local SSDs are one device per worker (the
paper's MareNostrum-4 setup); a shared filesystem / object store is a single
device referenced by every worker (the pod-scale checkpoint case).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StorageDevice:
    """A storage device with a bandwidth budget for constraint accounting.

    ``bandwidth`` is the budget the scheduler allocates constraint values
    from (MB/s). The congestion model parameters describe how the *achieved*
    aggregate throughput behaves as a function of the number of concurrent
    streams (see storage_model.py); they drive the simulator and default to
    the MareNostrum-4 node-local SSD calibration from the paper.
    """

    name: str
    bandwidth: float = 450.0        # MB/s, budget for storageBW accounting
    per_stream_cap: float = 8.0     # MB/s a single stream can achieve
    congestion_alpha: float = 0.004  # linear penalty per stream past the knee
    congestion_beta: float = 1e-5   # quadratic term: fsync seek-thrash at
    #                                 very high concurrency is superlinear
    congestion_knee: Optional[int] = None  # default: bandwidth/per_stream_cap

    def __post_init__(self):
        if self.congestion_knee is None:
            self.congestion_knee = max(1, int(self.bandwidth / self.per_stream_cap))
        # --- dynamic accounting state ---
        self.available_bw: float = self.bandwidth
        self.active_io: int = 0          # running I/O tasks on this device
        self.bytes_written: float = 0.0  # MB, for throughput reporting
        self.rate_epoch: int = 0         # bumped whenever active_io changes:
        #                                  the O(1) "did this device's rate
        #                                  change" check for rate caches
        self.release_epoch: int = 0      # bumped on releases only — the sole
        #                                  rate-RAISING change, i.e. the only
        #                                  one that can make cached finish-time
        #                                  lower bounds stale-late

    # -- budget accounting (scheduler-facing) --------------------------------
    def can_allocate(self, bw: float) -> bool:
        return bw <= self.available_bw + 1e-9

    def allocate(self, bw: float) -> None:
        if not self.can_allocate(bw):
            raise RuntimeError(
                f"over-allocating device {self.name}: want {bw}, have {self.available_bw}")
        self.available_bw -= bw
        self.active_io += 1
        self.rate_epoch += 1

    def release(self, bw: float) -> None:
        self.available_bw += bw
        self.active_io -= 1
        self.rate_epoch += 1
        self.release_epoch += 1
        if self.active_io < 0 or self.available_bw > self.bandwidth + 1e-6:
            raise RuntimeError(f"bandwidth accounting underflow on {self.name}")

    def reset(self):
        self.available_bw = self.bandwidth
        self.active_io = 0
        self.bytes_written = 0.0
        self.rate_epoch += 1
        self.release_epoch += 1


@dataclass
class WorkerNode:
    """A worker with a compute execution platform and an I/O execution
    platform (paper Fig. 7)."""

    name: str
    cpus: int = 48
    io_executors: int = 225
    storage: StorageDevice = None  # node-local device (or shared instance)

    def __post_init__(self):
        if self.storage is None:
            self.storage = StorageDevice(name=f"{self.name}-ssd")
        self.free_cpus: int = self.cpus
        self.free_io_executors: int = self.io_executors
        self.learning_owner = None   # signature owning this node as an
        #                              active-learning node (paper §4.2.3B)

    def reset(self):
        self.free_cpus = self.cpus
        self.free_io_executors = self.io_executors
        self.learning_owner = None
        self.storage.reset()


@dataclass
class Cluster:
    """The resource pool the scheduler draws from.

    ``shared_workdir`` mirrors the paper: when True, task outputs live on a
    shared FS so I/O tasks go to the first candidate node; when False the
    scheduler prefers data locality.
    """

    workers: list = field(default_factory=list)
    shared_workdir: bool = True

    @staticmethod
    def make(n_workers: int = 12, cpus: int = 48, io_executors: int = 225,
             device_bw: float = 450.0, per_stream_cap: float = 8.0,
             congestion_alpha: float = 0.004,
             shared_storage: bool = False) -> "Cluster":
        """Build the paper's 12-node MareNostrum-4-like cluster by default."""
        shared_dev = StorageDevice(
            name="shared-fs", bandwidth=device_bw,
            per_stream_cap=per_stream_cap,
            congestion_alpha=congestion_alpha) if shared_storage else None
        workers = []
        for i in range(n_workers):
            dev = shared_dev or StorageDevice(
                name=f"w{i}-ssd", bandwidth=device_bw,
                per_stream_cap=per_stream_cap,
                congestion_alpha=congestion_alpha)
            workers.append(WorkerNode(
                name=f"w{i}", cpus=cpus, io_executors=io_executors, storage=dev))
        return Cluster(workers=workers)

    @property
    def devices(self):
        seen, out = set(), []
        for w in self.workers:
            if id(w.storage) not in seen:
                seen.add(id(w.storage))
                out.append(w.storage)
        return out

    def reset(self):
        for w in self.workers:
            w.reset()

    def total_cpus(self) -> int:
        return sum(w.cpus for w in self.workers)
