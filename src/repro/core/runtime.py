"""IORuntime facade + PyCOMPSs-style decorators (paper Listings 1-5).

    from repro.core import task, io, constraint, IORuntime, INOUT

    @constraint(storageBW="auto")
    @io
    @task()
    def checkpoint(block, i):
        ...  # real write+fsync in RealBackend; modelled in SimBackend

    with IORuntime(cluster, backend=SimBackend()) as rt:
        for i in range(3):
            block = generate_block()          # returns a Future
            checkpoint(block, i, io_mb=290)   # overlaps with scale()
            results.append(scale(block))
        rt.barrier()

``io_mb=`` / ``duration=`` call-time kwargs feed the simulator's execution
model and are stripped before the user function sees its arguments.
"""
from __future__ import annotations

import threading
from typing import Optional

from .backends import Backend, RealBackend, SimBackend
from .constraints import parse_storage_bw
from .graph import TaskGraph, _param_names
from .resources import Cluster
from .scheduler import Scheduler
from .task import (Direction, Future, SimSpec, TaskDef, TaskInstance,
                   TaskState, TaskType)

_current: threading.local = threading.local()


def current_runtime() -> Optional["IORuntime"]:
    return getattr(_current, "rt", None)


#: call-time kwargs consumed by the runtime (see IORuntime docstring); a
#: wrapped function must not declare parameters with these names, because
#: the runtime strips them before the user function runs.
RESERVED_KWARGS = ("io_mb", "duration", "storage_bw")


class TaskFunction:
    """A decorated function: direct call without a runtime, task submission
    inside a runtime context."""

    def __init__(self, defn: TaskDef):
        self.defn = defn
        self.__name__ = defn.name
        clashes = [n for n in RESERVED_KWARGS if n in _param_names(defn)]
        if clashes:
            raise TypeError(
                f"task {defn.name!r} declares reserved parameter(s) "
                f"{clashes}: {', '.join(RESERVED_KWARGS)} are runtime "
                f"execution-model kwargs and are stripped before the task "
                f"body runs — rename the function parameter(s)")

    def __call__(self, *args, **kwargs):
        rt = current_runtime()
        # strip exactly the names validated at decoration time
        reserved = {k: kwargs.pop(k, None) for k in RESERVED_KWARGS}
        sim = SimSpec(duration=float(reserved["duration"] or 0.0),
                      io_bytes=float(reserved["io_mb"] or 0.0))
        bw_override = reserved["storage_bw"]
        if rt is None:
            return self.defn.fn(*args, **kwargs)
        return rt.submit(self.defn, args, kwargs, sim,
                         storage_bw=parse_storage_bw(bw_override)
                         if bw_override is not None else None)


def _as_taskfn(fn) -> TaskFunction:
    if isinstance(fn, TaskFunction):
        return fn
    return TaskFunction(TaskDef(fn=fn, name=fn.__name__))


def task(returns: int = 0, **param_dirs):
    """@task(returns=1, data=INOUT) — declare a function as a task."""
    dirs = {}
    for name, d in param_dirs.items():
        if not isinstance(d, Direction):
            raise TypeError(f"direction for {name!r} must be IN/INOUT/OUT")
        dirs[name] = d

    def wrap(fn):
        tf = _as_taskfn(fn)
        tf.defn.returns = returns
        tf.defn.param_dirs.update(dirs)
        return tf
    return wrap


def io(fn):
    """@io — mark the task as an I/O task (zero computing units; scheduled on
    the I/O execution platform, overlapping compute tasks)."""
    tf = _as_taskfn(fn)
    tf.defn.task_type = TaskType.IO
    tf.defn.computing_units = 0
    return tf


def constraint(computingUnits: int | None = None, storageBW=None,
               maxRetries: int | None = None):
    """@constraint(computingUnits=2) / @constraint(storageBW="auto(2,256,2)")."""
    def wrap(fn):
        tf = _as_taskfn(fn)
        if computingUnits is not None:
            tf.defn.computing_units = int(computingUnits)
        if storageBW is not None:
            tf.defn.storage_bw = parse_storage_bw(storageBW)
        if maxRetries is not None:
            tf.defn.max_retries = int(maxRetries)
        return tf
    return wrap


def wait_on(*futures):
    """compss_wait_on: block until futures resolve; return their values."""
    rt = current_runtime()
    if rt is None:
        raise RuntimeError("wait_on outside an IORuntime context")
    return rt.wait_on(*futures)


class IORuntime:
    """Master runtime: submission, dependency tracking, barriers, stats.

    Reserved call-time kwargs — ``io_mb=``, ``duration=`` and
    ``storage_bw=`` are consumed by the runtime itself (simulator execution
    model and per-call constraint override) and never reach the task body;
    decorating a function whose signature declares one of these names raises
    ``TypeError`` at decoration time.

    ``scheduler_cls`` exists for A/B comparisons (e.g. the frozen seed
    scheduler in ``benchmarks/_seed_impl.py``); it must match the
    ``Scheduler`` interface.
    """

    def __init__(self, cluster: Cluster, backend: Backend | str = "sim",
                 scheduler_cls=Scheduler):
        self.cluster = cluster
        if isinstance(backend, str):
            backend = SimBackend() if backend == "sim" else RealBackend()
        self.backend = backend
        self.lock = threading.RLock()
        self.graph = TaskGraph()
        self.scheduler = scheduler_cls(cluster, launch=self.backend.launch)
        self.backend.bind(self)
        self._entered = False

    # ---------------------------------------------------------------- context
    def __enter__(self):
        _current.rt = self
        self._entered = True
        return self

    def __exit__(self, exc_type, exc, tb):
        try:
            if exc_type is None:
                self.barrier(final=True)
        finally:
            _current.rt = None
            self.backend.shutdown()
        return False

    # ------------------------------------------------------------- submission
    def submit(self, defn: TaskDef, args, kwargs, sim: SimSpec,
               storage_bw=None):
        with self.lock:
            inst = TaskInstance(defn, args, kwargs, sim=sim,
                                storage_bw=storage_bw)
            inst.submit_time = self.backend.now()
            ready = self.graph.add(inst)
            if ready:
                self.scheduler.make_ready(inst)
            self.backend.on_submitted()
        if defn.returns > 1:
            return tuple(inst.futures)
        return inst.futures[0]

    # ------------------------------------------------------------- completion
    def _handle_completion(self, task: TaskInstance) -> None:
        # called by the backend (sim loop / worker thread under runtime lock)
        self.scheduler.on_complete(task)
        if task.state != TaskState.FAILED:
            newly_ready = self.graph.complete(task)
            if newly_ready:
                self.scheduler.make_ready_many(newly_ready)
        else:
            # failed task leaves the graph and takes its (necessarily still
            # PENDING) data-descendants with it, so drain loops can't hang on
            # them; write-after-read successors are merely unblocked
            _, newly_ready = self.graph.fail(task)
            if newly_ready:
                self.scheduler.make_ready_many(newly_ready)

    # ------------------------------------------------------------------ waits
    def barrier(self, final: bool = False) -> None:
        if final:
            with self.lock:
                self.scheduler.end_of_stream()
        self.backend.drain(lambda: self.graph.unfinished == 0)

    def wait_on(self, *futures):
        self.backend.drain(lambda: all(f.resolved() for f in futures))
        vals = [f.value() for f in futures]
        return vals[0] if len(vals) == 1 else vals

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        done = self.scheduler.completed
        io_tasks = [t for t in done if t.is_io]
        out = {
            "makespan": self.backend.now(),
            "n_tasks": len(done),
            "n_io_tasks": len(io_tasks),
            "avg_io_task_time": (sum(t.duration for t in io_tasks) / len(io_tasks))
            if io_tasks else 0.0,
            "tuners": {s: t.summary() for s, t in self.scheduler.tuners.items()},
        }
        be = self.backend
        if isinstance(be, SimBackend):
            out.update({
                "io_busy_time": be.io_busy_time,
                "compute_busy_time": be.compute_busy_time,
                "overlap_time": be.overlap_time,
                "total_io_mb": be.total_io_mb,
                "io_throughput_mbs": (be.total_io_mb / be.io_busy_time)
                if be.io_busy_time > 0 else 0.0,
                "peak_io_mbs": be.peak_io_mbs,
            })
        return out
