"""IORuntime facade + PyCOMPSs-style decorators (paper Listings 1-5).

    from repro.core import task, io, constraint, IORuntime, INOUT

    @constraint(storageBW="auto")
    @io
    @task()
    def checkpoint(block, i):
        ...  # real write+fsync in RealBackend; modelled in SimBackend

    with IORuntime(cluster, backend=SimBackend()) as rt:
        for i in range(3):
            block = generate_block()          # returns a Future
            checkpoint(block, i, io_mb=290)   # overlaps with scale()
            results.append(scale(block))
        rt.barrier()

``io_mb=`` / ``duration=`` call-time kwargs feed the simulator's execution
model and are stripped before the user function sees its arguments.

Storage tiers
-------------
On a tiered cluster (``Cluster.make_tiered``: node-local SSD → shared burst
buffer → shared FS) an I/O task is placed on the fastest tier with budget by
default. Two hints pin it instead:

* ``@constraint(tier="bb")`` — every invocation targets the named tier;
* ``storage_tier="fs"`` at call time — per-invocation override, analogous
  to ``storage_bw=``.

Data moves *between* tiers through runtime-generated I/O tasks:
``rt.drain(fut, to_tier="fs", from_tier="ssd", io_mb=64)`` schedules an
asynchronous write-back (fast → slow) and ``rt.prefetch(...)`` the reverse;
both return Futures and overlap with compute like any other I/O task. Under
``RealBackend(tier_dirs={...})`` a ``path=`` names the file to copy between
the tier directories; under ``SimBackend`` the transfer is modelled with the
source tier's read floor and the destination tier's congestion.

``sim_fail=True`` at call time injects a failure at the task's simulated
completion (SimBackend only): the task FAILs and its data-descendants are
cancelled — the property-test harness drives fault-tolerance invariants
through this.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Optional

from .backends import Backend, RealBackend, SimBackend
from .constraints import parse_storage_bw
from .graph import TaskGraph, _param_names
from .resources import Cluster
from .scheduler import Scheduler
from .storage_model import read_floor_time
from .task import (Direction, Future, SimSpec, TaskDef, TaskInstance,
                   TaskState, TaskType)

_current: threading.local = threading.local()


def current_runtime() -> Optional["IORuntime"]:
    return getattr(_current, "rt", None)


#: call-time kwargs consumed by the runtime (see IORuntime docstring); a
#: wrapped function must not declare parameters with these names, because
#: the runtime strips them before the user function runs.
RESERVED_KWARGS = ("io_mb", "duration", "storage_bw", "storage_tier",
                   "sim_fail")


class TaskFunction:
    """A decorated function: direct call without a runtime, task submission
    inside a runtime context."""

    def __init__(self, defn: TaskDef):
        self.defn = defn
        self.__name__ = defn.name
        clashes = [n for n in RESERVED_KWARGS if n in _param_names(defn)]
        if clashes:
            raise TypeError(
                f"task {defn.name!r} declares reserved parameter(s) "
                f"{clashes}: {', '.join(RESERVED_KWARGS)} are runtime "
                f"execution-model kwargs and are stripped before the task "
                f"body runs — rename the function parameter(s)")

    def __call__(self, *args, **kwargs):
        rt = current_runtime()
        # strip exactly the names validated at decoration time
        reserved = {k: kwargs.pop(k, None) for k in RESERVED_KWARGS}
        sim = SimSpec(duration=float(reserved["duration"] or 0.0),
                      io_bytes=float(reserved["io_mb"] or 0.0),
                      fail=bool(reserved["sim_fail"]))
        bw_override = reserved["storage_bw"]
        if rt is None:
            return self.defn.fn(*args, **kwargs)
        return rt.submit(self.defn, args, kwargs, sim,
                         storage_bw=parse_storage_bw(bw_override)
                         if bw_override is not None else None,
                         storage_tier=reserved["storage_tier"])


def _as_taskfn(fn) -> TaskFunction:
    if isinstance(fn, TaskFunction):
        return fn
    return TaskFunction(TaskDef(fn=fn, name=fn.__name__))


def task(returns: int = 0, **param_dirs):
    """@task(returns=1, data=INOUT) — declare a function as a task."""
    dirs = {}
    for name, d in param_dirs.items():
        if not isinstance(d, Direction):
            raise TypeError(f"direction for {name!r} must be IN/INOUT/OUT")
        dirs[name] = d

    def wrap(fn):
        tf = _as_taskfn(fn)
        tf.defn.returns = returns
        tf.defn.param_dirs.update(dirs)
        return tf
    return wrap


def io(fn):
    """@io — mark the task as an I/O task (zero computing units; scheduled on
    the I/O execution platform, overlapping compute tasks)."""
    tf = _as_taskfn(fn)
    tf.defn.task_type = TaskType.IO
    tf.defn.computing_units = 0
    return tf


def constraint(computingUnits: int | None = None, storageBW=None,
               maxRetries: int | None = None, tier: str | None = None):
    """@constraint(computingUnits=2) / @constraint(storageBW="auto(2,256,2)")
    / @constraint(tier="bb") — ``tier`` pins the task's I/O to the named
    storage tier (default: the fastest tier with budget, falling down the
    hierarchy)."""
    def wrap(fn):
        tf = _as_taskfn(fn)
        if computingUnits is not None:
            tf.defn.computing_units = int(computingUnits)
        if storageBW is not None:
            tf.defn.storage_bw = parse_storage_bw(storageBW)
        if maxRetries is not None:
            tf.defn.max_retries = int(maxRetries)
        if tier is not None:
            tf.defn.storage_tier = str(tier)
        return tf
    return wrap


def wait_on(*futures):
    """compss_wait_on: block until futures resolve; return their values."""
    rt = current_runtime()
    if rt is None:
        raise RuntimeError("wait_on outside an IORuntime context")
    return rt.wait_on(*futures)


# --------------------------------------------------------------------------
# Runtime-generated data movement between tiers (drain / prefetch)
# --------------------------------------------------------------------------
def copy_fsync(src_path, dst_path) -> str:
    """Durable copy: the write side is flushed and fsync'd before the call
    returns (the shared primitive under drain/prefetch movers and the
    checkpoint manager's shard drains)."""
    os.makedirs(os.path.dirname(dst_path) or ".", exist_ok=True)
    with open(src_path, "rb") as s, open(dst_path, "wb") as d:
        shutil.copyfileobj(s, d)
        d.flush()
        os.fsync(d.fileno())
    return str(dst_path)


def _make_mover(name: str) -> TaskFunction:
    """One I/O task signature per movement direction, so each gets its own
    placement class and (if auto-constrained) its own per-tier tuner."""
    def _move(data, src_path, dst_path):
        # RealBackend: copy+fsync between tier directories when both paths
        # resolved; SimBackend never executes this body — the transfer is
        # modelled (write side: destination device congestion; read side:
        # the source tier's read floor as the task's minimum duration).
        if src_path and dst_path:
            return copy_fsync(src_path, dst_path)
        return data
    _move.__name__ = name
    return io(task(returns=1)(_move))


_drain_task = _make_mover("tier_drain")
_prefetch_task = _make_mover("tier_prefetch")


class IORuntime:
    """Master runtime: submission, dependency tracking, barriers, stats.

    Reserved call-time kwargs — ``io_mb=``, ``duration=`` and
    ``storage_bw=`` are consumed by the runtime itself (simulator execution
    model and per-call constraint override) and never reach the task body;
    decorating a function whose signature declares one of these names raises
    ``TypeError`` at decoration time.

    ``scheduler_cls`` exists for A/B comparisons (e.g. the frozen seed
    scheduler in ``benchmarks/_seed_impl.py``); it must match the
    ``Scheduler`` interface.
    """

    def __init__(self, cluster: Cluster, backend: Backend | str = "sim",
                 scheduler_cls=Scheduler):
        self.cluster = cluster
        if isinstance(backend, str):
            backend = SimBackend() if backend == "sim" else RealBackend()
        self.backend = backend
        self.lock = threading.RLock()
        self.graph = TaskGraph()
        self.scheduler = scheduler_cls(cluster, launch=self.backend.launch)
        self.backend.bind(self)
        self._entered = False

    # ---------------------------------------------------------------- context
    def __enter__(self):
        _current.rt = self
        self._entered = True
        return self

    def __exit__(self, exc_type, exc, tb):
        try:
            if exc_type is None:
                self.barrier(final=True)
        finally:
            _current.rt = None
            self.backend.shutdown()
        return False

    # ------------------------------------------------------------- submission
    def submit(self, defn: TaskDef, args, kwargs, sim: SimSpec,
               storage_bw=None, storage_tier=None):
        with self.lock:
            inst = TaskInstance(defn, args, kwargs, sim=sim,
                                storage_bw=storage_bw,
                                storage_tier=storage_tier)
            # reject unsatisfiable constraint/tier classes HERE, before the
            # task enters the graph: the error surfaces at the call site and
            # no half-registered state (unfinished counts, dependents) is
            # left behind. (getattr: A/B scheduler_cls like the frozen seed
            # predates submission-time validation)
            validate = getattr(self.scheduler, "validate_submit", None)
            if validate is not None:
                validate(inst)
            inst.submit_time = self.backend.now()
            ready = self.graph.add(inst)
            if ready:
                self.scheduler.make_ready(inst)
            self.backend.on_submitted()
        if defn.returns > 1:
            return tuple(inst.futures)
        return inst.futures[0]

    # ------------------------------------------------------------- completion
    def _handle_completion(self, task: TaskInstance) -> None:
        # called by the backend (sim loop / worker thread under runtime lock)
        self.scheduler.on_complete(task)
        if task.state != TaskState.FAILED:
            newly_ready = self.graph.complete(task)
            if newly_ready:
                self.scheduler.make_ready_many(newly_ready)
        else:
            # failed task leaves the graph and takes its (necessarily still
            # PENDING) data-descendants with it, so drain loops can't hang on
            # them; write-after-read successors are merely unblocked
            _, newly_ready = self.graph.fail(task)
            if newly_ready:
                self.scheduler.make_ready_many(newly_ready)

    # ----------------------------------------------------- tier data movement
    def drain(self, data, to_tier: str, from_tier: Optional[str] = None,
              io_mb: float = 0.0, storage_bw=None,
              path: Optional[str] = None) -> Future:
        """Asynchronously write ``data`` back to a slower tier (e.g. burst
        buffer → shared FS). Returns a Future; the movement is an ordinary
        I/O task that overlaps with compute. ``data`` may be a Future (the
        drain then depends on its producer). ``path`` names a file to copy
        between ``RealBackend.tier_dirs`` directories; ``storage_bw``
        optionally throttles the writer (static MB/s or "auto")."""
        return self._move(_drain_task, data, to_tier, from_tier, io_mb,
                          storage_bw, path)

    def prefetch(self, data, to_tier: str, from_tier: Optional[str] = None,
                 io_mb: float = 0.0, storage_bw=None,
                 path: Optional[str] = None) -> Future:
        """Asynchronously stage ``data`` up to a faster tier (e.g. shared
        FS → node-local SSD) ahead of the tasks that will read it."""
        return self._move(_prefetch_task, data, to_tier, from_tier, io_mb,
                          storage_bw, path)

    def _move(self, mover: TaskFunction, data, to_tier, from_tier, io_mb,
              storage_bw, path) -> Future:
        # read-side floor: a single reader streams at most at the source
        # device's bandwidth (the write side is modelled/performed on the
        # destination tier the task is placed on)
        src = None
        if from_tier is not None:
            src = self.cluster.tier_spec(from_tier)
        elif self.cluster.workers:
            src = self.cluster.workers[0].storage  # default: fastest tier
        dur = read_floor_time(src, io_mb) if src is not None else 0.0
        src_path = dst_path = None
        if path is not None:
            tp = getattr(self.backend, "tier_path", None)
            if tp is not None:
                # a backend that moves real files must be able to resolve
                # both ends — a silent no-op copy would report a drain as
                # durable without having moved anything
                if from_tier is None:
                    raise ValueError(
                        "path= movement needs from_tier= to locate the "
                        "source file")
                src_path = tp(from_tier, path)
                dst_path = tp(to_tier, path)
                if src_path is None or dst_path is None:
                    missing = from_tier if src_path is None else to_tier
                    raise ValueError(
                        f"no tier_dirs directory mapped for tier "
                        f"{missing!r} (have: "
                        f"{sorted(self.backend.tier_dirs)})")
        # pin to the destination tier only when the cluster models it; on a
        # plain single-tier cluster the move still runs, tier-agnostically
        tier_hint = to_tier if self.cluster.has_tier(to_tier) else None
        return mover(data, src_path, dst_path, io_mb=io_mb, duration=dur,
                     storage_bw=storage_bw, storage_tier=tier_hint)

    # ------------------------------------------------------------------ waits
    def barrier(self, final: bool = False) -> None:
        if final:
            with self.lock:
                self.scheduler.end_of_stream()
        self.backend.drain(lambda: self.graph.unfinished == 0)

    def wait_on(self, *futures):
        self.backend.drain(lambda: all(f.resolved() for f in futures))
        vals = [f.value() for f in futures]
        return vals[0] if len(vals) == 1 else vals

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        done = self.scheduler.completed
        io_tasks = [t for t in done if t.is_io]
        out = {
            "makespan": self.backend.now(),
            "n_tasks": len(done),
            "n_io_tasks": len(io_tasks),
            "avg_io_task_time": (sum(t.duration for t in io_tasks) / len(io_tasks))
            if io_tasks else 0.0,
            "tuners": {s: t.summary() for s, t in self.scheduler.tuners.items()},
            # per-tier occupancy: one entry per distinct device in the
            # hierarchy (shared tiers appear once)
            "devices": {d.name: {"tier": d.tier,
                                 "bytes_written": d.bytes_written}
                        for d in self.cluster.devices},
        }
        be = self.backend
        if isinstance(be, SimBackend):
            out.update({
                "io_busy_time": be.io_busy_time,
                "compute_busy_time": be.compute_busy_time,
                "overlap_time": be.overlap_time,
                "total_io_mb": be.total_io_mb,
                "io_throughput_mbs": (be.total_io_mb / be.io_busy_time)
                if be.io_busy_time > 0 else 0.0,
                "peak_io_mbs": be.peak_io_mbs,
            })
        return out
