"""IORuntime facade + PyCOMPSs-style decorators (paper Listings 1-5).

    from repro.core import task, io, constraint, IORuntime, INOUT

    @constraint(storageBW="auto")
    @io
    @task()
    def checkpoint(block, i):
        ...  # real write+fsync in RealBackend; modelled in SimBackend

    with IORuntime(cluster, backend=SimBackend()) as rt:
        for i in range(3):
            block = generate_block()          # returns a Future
            checkpoint(block, i, io_mb=290)   # overlaps with scale()
            results.append(scale(block))
        rt.barrier()

``io_mb=`` / ``duration=`` call-time kwargs feed the simulator's execution
model and are stripped before the user function sees its arguments.

Storage tiers
-------------
On a tiered cluster (``Cluster.make_tiered``: node-local SSD → shared burst
buffer → shared FS) an I/O task is placed on the fastest tier with budget by
default. Two hints pin it instead:

* ``@constraint(tier="bb")`` — every invocation targets the named tier;
* ``storage_tier="fs"`` at call time — per-invocation override, analogous
  to ``storage_bw=``.

Data moves *between* tiers through runtime-generated I/O tasks:
``rt.drain(fut, to_tier="fs", from_tier="ssd", io_mb=64)`` schedules an
asynchronous write-back (fast → slow) and ``rt.prefetch(...)`` the reverse;
both return Futures and overlap with compute like any other I/O task. Under
``RealBackend(tier_dirs={...})`` a ``path=`` names the file to copy between
the tier directories; under ``SimBackend`` the transfer is modelled with the
source tier's read floor and the destination tier's congestion.

``sim_fail=True`` at call time injects a failure at the task's simulated
completion (SimBackend only): the task FAILs and its data-descendants are
cancelled — the property-test harness drives fault-tolerance invariants
through this.
"""
from __future__ import annotations

import os
import shutil
import threading
from contextlib import contextmanager
from typing import Optional

from .autotune import DriftConfig
from .backends import Backend, RealBackend, SimBackend
from ..obs import TraceConfig, TraceRecorder
from .constraints import parse_storage_bw
from .datalife import DataCatalog, LifecycleConfig
from .failures import FailureEngine
from .interference import InterferenceEngine
from .graph import TaskGraph, _param_names
from .resources import Cluster
from .scheduler import Scheduler, eligible_devices
from .storage_model import read_floor_time
from .task import (Direction, Future, SimSpec, TaskDef, TaskInstance,
                   TaskState, TaskType, resolved_future)

_current: threading.local = threading.local()


def current_runtime() -> Optional["IORuntime"]:
    return getattr(_current, "rt", None)


#: call-time kwargs consumed by the runtime (see IORuntime docstring); a
#: wrapped function must not declare parameters with these names, because
#: the runtime strips them before the user function runs.
RESERVED_KWARGS = ("io_mb", "duration", "storage_bw", "storage_tier",
                   "sim_fail", "shard_key")


class TaskFunction:
    """A decorated function: direct call without a runtime, task submission
    inside a runtime context."""

    def __init__(self, defn: TaskDef):
        self.defn = defn
        self.__name__ = defn.name
        clashes = [n for n in RESERVED_KWARGS if n in _param_names(defn)]
        if clashes:
            raise TypeError(
                f"task {defn.name!r} declares reserved parameter(s) "
                f"{clashes}: {', '.join(RESERVED_KWARGS)} are runtime "
                f"execution-model kwargs and are stripped before the task "
                f"body runs — rename the function parameter(s)")

    def __call__(self, *args, **kwargs):
        rt = current_runtime()
        # strip exactly the names validated at decoration time — as
        # individual pops, not a dict build: this is the hottest line of
        # the submit path at the 1M-task bench scale
        pop = kwargs.pop
        raw_io_mb = pop("io_mb", None)
        raw_duration = pop("duration", None)
        bw_override = pop("storage_bw", None)
        storage_tier = pop("storage_tier", None)
        fail_spec = pop("sim_fail", None)
        shard_key = pop("shard_key", None)
        io_mb = float(raw_io_mb) if raw_io_mb else 0.0
        duration = float(raw_duration) if raw_duration else 0.0
        if io_mb < 0:
            raise ValueError(
                f"task {self.defn.name!r}: io_mb must be non-negative "
                f"(got {io_mb}) — it is the task's I/O footprint in MB")
        if duration < 0:
            raise ValueError(
                f"task {self.defn.name!r}: duration must be non-negative "
                f"(got {duration})")
        # booleans stay booleans (True: every attempt fails); an int N is
        # preserved so only the first N attempts fail — with maxRetries >= N
        # the task eventually succeeds (SimSpec.fail)
        if fail_spec is None or isinstance(fail_spec, bool):
            fail_spec = bool(fail_spec)
        else:
            fail_spec = int(fail_spec)
        sim = SimSpec(duration=duration, io_bytes=io_mb, fail=fail_spec)
        if rt is None:
            return self.defn.fn(*args, **kwargs)
        return rt.submit(self.defn, args, kwargs, sim,
                         storage_bw=parse_storage_bw(bw_override)
                         if bw_override is not None else None,
                         storage_tier=storage_tier,
                         shard_key=shard_key)


def _as_taskfn(fn) -> TaskFunction:
    if isinstance(fn, TaskFunction):
        return fn
    return TaskFunction(TaskDef(fn=fn, name=fn.__name__))


def task(returns: int = 0, **param_dirs):
    """@task(returns=1, data=INOUT) — declare a function as a task."""
    dirs = {}
    for name, d in param_dirs.items():
        if not isinstance(d, Direction):
            raise TypeError(f"direction for {name!r} must be IN/INOUT/OUT")
        dirs[name] = d

    def wrap(fn):
        tf = _as_taskfn(fn)
        tf.defn.returns = returns
        tf.defn.param_dirs.update(dirs)
        return tf
    return wrap


def io(fn):
    """@io — mark the task as an I/O task (zero computing units; scheduled on
    the I/O execution platform, overlapping compute tasks)."""
    tf = _as_taskfn(fn)
    tf.defn.task_type = TaskType.IO
    tf.defn.computing_units = 0
    return tf


def constraint(computingUnits: int | None = None, storageBW=None,
               maxRetries: int | None = None, tier: str | None = None):
    """@constraint(computingUnits=2) / @constraint(storageBW="auto(2,256,2)")
    / @constraint(tier="bb") — ``tier`` pins the task's I/O to the named
    storage tier (default: the fastest tier with budget, falling down the
    hierarchy)."""
    def wrap(fn):
        tf = _as_taskfn(fn)
        if computingUnits is not None:
            tf.defn.computing_units = int(computingUnits)
        if storageBW is not None:
            tf.defn.storage_bw = parse_storage_bw(storageBW)
        if maxRetries is not None:
            tf.defn.max_retries = int(maxRetries)
        if tier is not None:
            tf.defn.storage_tier = str(tier)
        return tf
    return wrap


def wait_on(*futures):
    """compss_wait_on: block until futures resolve; return their values."""
    rt = current_runtime()
    if rt is None:
        raise RuntimeError("wait_on outside an IORuntime context")
    return rt.wait_on(*futures)


# --------------------------------------------------------------------------
# Runtime-generated data movement between tiers (drain / prefetch)
# --------------------------------------------------------------------------
def copy_fsync(src_path, dst_path) -> str:
    """Durable copy: the write side is flushed and fsync'd before the call
    returns (the shared primitive under drain/prefetch movers and the
    checkpoint manager's shard drains)."""
    os.makedirs(os.path.dirname(dst_path) or ".", exist_ok=True)
    with open(src_path, "rb") as s, open(dst_path, "wb") as d:
        shutil.copyfileobj(s, d)
        d.flush()
        os.fsync(d.fileno())
    return str(dst_path)


def _make_mover(name: str) -> TaskFunction:
    """One I/O task signature per movement direction, so each gets its own
    placement class and (if auto-constrained) its own per-tier tuner."""
    def _move(data, src_path, dst_path):
        # RealBackend: copy+fsync between tier directories when both paths
        # resolved; SimBackend never executes this body — the transfer is
        # modelled (write side: destination device congestion; read side:
        # the source tier's read floor as the task's minimum duration).
        if src_path and dst_path:
            return copy_fsync(src_path, dst_path)
        return data
    _move.__name__ = name
    # movers are the durability path (eviction drains, emergency re-drains):
    # a transient device failure must not strand an object undurable
    return constraint(maxRetries=2)(io(task(returns=1)(_move)))


_drain_task = _make_mover("tier_drain")
_prefetch_task = _make_mover("tier_prefetch")


def _make_recovery_task() -> TaskFunction:
    """Lineage re-run: when a device failure orphans an object (every copy
    lost), the runtime re-executes the producer's work under this synthetic
    signature with the producer's recorded execution model (duration,
    io_mb). SimBackend never runs the body; under RealBackend lineage
    recovery is bookkeeping-only (DataObject carries no path)."""
    def _recover(inputs):
        return inputs
    _recover.__name__ = "lineage_recover"
    return constraint(maxRetries=2)(io(task(returns=1)(_recover)))


_recover_task = _make_recovery_task()


class IORuntime:
    """Master runtime: submission, dependency tracking, barriers, stats.

    Reserved call-time kwargs — ``io_mb=``, ``duration=`` and
    ``storage_bw=`` are consumed by the runtime itself (simulator execution
    model and per-call constraint override) and never reach the task body;
    decorating a function whose signature declares one of these names raises
    ``TypeError`` at decoration time.

    ``scheduler_cls`` exists for A/B comparisons (e.g. the frozen seed
    scheduler in ``benchmarks/_seed_impl.py``); it must match the
    ``Scheduler`` interface.

    Data lifecycle (``lifecycle=``, see datalife.py): when any tier carries
    a finite ``capacity_gb`` (or ``LifecycleConfig(enabled=True)``), every
    I/O task's output becomes a tracked ``DataObject``, tier capacity is
    reserved at grant and committed at finish, watermark/demand pressure on
    a fast tier synthesizes eviction tasks (drain-then-delete of cold
    objects), and tasks whose tracked inputs live only on a slower tier get
    an automatic ``rt.prefetch`` staged in front of them (the CkIO read
    pipeline) — including consumers submitted before their producer
    finished, via a conditional mover decided at the producer's completion
    (``pipeline_prefetch``). ``rt.discard(fut)`` marks temp data ephemeral
    so eviction deletes it without the durable drain. With no finite
    capacity the subsystem is inert and the runtime behaves exactly as
    before.

    Co-tenant interference (``interference=``, see interference.py and
    docs/interference.md): background traffic models injected into shared-
    tier devices (SimBackend only). ``drift=DriftConfig(...)`` arms the
    autotuners with a stale-curve detector that re-enters calibration on
    the live device; ``tier_objective=True`` turns the fastest-with-budget
    walk for tier-agnostic auto tasks into a measured argmin over the
    learned per-tier T(n, c) curves, priced with forced-eviction drains.
    All three default off and leave behaviour bit-identical.
    """

    def __init__(self, cluster: Cluster, backend: Backend | str = "sim",
                 scheduler_cls=Scheduler,
                 lifecycle: Optional[LifecycleConfig] = None,
                 interference=None,
                 failures=None,
                 drift: Optional[DriftConfig] = None,
                 tier_objective: bool = False,
                 trace=False,
                 shards: int = 1):
        self.cluster = cluster
        self.n_shards = int(shards)
        # constructor config, replayed by rt.plan() to build the capture
        # sibling with the same lifecycle/interference/tuning setup
        self._plan_config = dict(scheduler_cls=scheduler_cls,
                                 lifecycle=lifecycle,
                                 interference=interference,
                                 failures=failures, drift=drift,
                                 tier_objective=tier_objective,
                                 shards=shards)
        if isinstance(backend, str):
            if backend == "capture":
                from ..analysis.capture import CaptureBackend  # lazy: cycle
                backend = CaptureBackend()
            elif backend == "sim":
                backend = SimBackend()
            else:
                backend = RealBackend()
        # forced capture (the repro.lint CLI): whatever backend the script
        # asked for is replaced by a recording one — no task body executes
        from ..analysis import capture as _capture
        forced = _capture.FORCE and not getattr(backend, "is_capture", False)
        if forced:
            backend = _capture.CaptureBackend()
        self.capture_mode = bool(getattr(backend, "is_capture", False))
        # forced backend substitution (the repro.compare CLI): the
        # sim-vs-real harness runs the same unmodified script once under
        # SimBackend and once under RealBackend(tier_dirs=). Capture wins —
        # a lint pass must never execute task bodies.
        from .. import obs as _obs
        self._backend_forced = False
        if _obs.FORCE_BACKEND is not None and not self.capture_mode:
            forced_be = _obs.FORCE_BACKEND(cluster, backend)
            if forced_be is not None and forced_be is not backend:
                backend = forced_be
                self._backend_forced = True
        self.backend = backend
        self.lock = threading.RLock()
        self.graph = TaskGraph()
        # sharded control plane (shardplane.py, docs/scale.md): shards > 1
        # partitions the workers into per-shard schedulers behind the
        # ShardedScheduler facade; shards == 1 keeps the plain Scheduler —
        # zero facade overhead, bit-identical to every prior release
        if self.n_shards > 1:
            from .shardplane import ShardedScheduler  # lazy: rarely taken
            self.scheduler = ShardedScheduler(
                cluster, launch=self.backend.launch,
                n_shards=self.n_shards, scheduler_cls=scheduler_cls)
            self.graph.track_shards = True
        else:
            self.scheduler = scheduler_cls(cluster,
                                           launch=self.backend.launch)
        if drift is not None or tier_objective:
            set_tuning = getattr(self.scheduler, "set_tuning", None)
            if set_tuning is not None:
                set_tuning(drift=drift, tier_objective=tier_objective)
        # observability (obs/, docs/observability.md): trace=True (or a
        # TraceConfig / prebuilt TraceRecorder) wires a recorder into every
        # event site; None leaves each site a single is-not-None check away
        # from doing nothing (bit-identical behaviour either way). The
        # repro.trace CLI forces tracing on via obs.FORCE — same hijack
        # pattern as forced capture above. Capture mode never traces:
        # nothing executes, so there is nothing to time. Constructed BEFORE
        # the engines attach so t=0 bursts/health transitions are recorded.
        obs_forced = _obs.FORCE and not self.capture_mode
        if obs_forced and not trace:
            trace = True
        self.recorder = None
        if trace and not self.capture_mode:
            if isinstance(trace, TraceRecorder):
                rec = trace
            else:
                cfg = trace if isinstance(trace, TraceConfig) else None
                rec = TraceRecorder(cfg)
            rec.bind(clock=self.backend.now, scheduler=self.scheduler)
            self.recorder = rec
            set_recorder = getattr(self.scheduler, "set_recorder", None)
            if set_recorder is not None:
                set_recorder(rec)
        # co-tenant interference (interference.py): an InterferenceEngine,
        # or an iterable of (tier-or-device, TrafficModel) pairs. Simulation
        # only — a real cluster injects its own co-tenants.
        self.interference = None
        if interference is not None:
            engine = interference if isinstance(interference,
                                                InterferenceEngine) \
                else InterferenceEngine(list(interference), cluster)
            if engine.active:
                if self.capture_mode:
                    # recorded for the analyzer (IO401 reads the bindings);
                    # never attached — capture injects no traffic
                    self.interference = engine
                elif not isinstance(backend, SimBackend):
                    if not self._backend_forced:
                        raise ValueError(
                            "interference injection models co-tenant "
                            "traffic in the simulator; it is not supported "
                            f"on {type(backend).__name__}")
                    # forced substitution (repro.compare): injected
                    # co-tenants only exist in the simulator — the measured
                    # leg sees the real machine's own traffic instead, so
                    # the engine is dropped rather than refusing the run
                else:
                    engine.recorder = self.recorder  # before t=0 bursts
                    backend.attach_interference(engine)
                    self.interference = engine
        # plan() replays the *resolved* engine (an iterable argument was
        # consumed above; None when inactive, which has nothing to analyze)
        self._plan_config["interference"] = self.interference
        # tier failure domains (failures.py): a FailureEngine, a
        # FailureSchedule, or an iterable of (t, target, state[, bw_factor])
        # events. Simulation only — a real cluster fails on its own.
        self.failures = None
        if failures is not None:
            feng = failures if isinstance(failures, FailureEngine) \
                else FailureEngine(failures, cluster)
            if feng.active:
                if self.capture_mode:
                    # recorded for the analyzer (IO501 reads the schedule);
                    # never attached — capture flips no device health
                    self.failures = feng
                elif not isinstance(backend, SimBackend):
                    if not self._backend_forced:
                        raise ValueError(
                            "failure injection drives device health in the "
                            "simulator; it is not supported on "
                            f"{type(backend).__name__}")
                    # forced substitution (repro.compare): dropped, like
                    # the interference engine above
                else:
                    feng.recorder = self.recorder  # before t=0 transitions
                    backend.attach_failures(feng)
                    self.failures = feng
        self._plan_config["failures"] = self.failures
        # capture mode constructs non-strict: lifecycle config errors are
        # recorded (diagnostic IO204) instead of raising, so a plan a live
        # runtime would refuse can still be analyzed
        self.catalog = DataCatalog(cluster, lifecycle, now=self.backend.now,
                                   strict=not self.capture_mode)
        self.catalog.graph = self.graph
        if self.catalog.enabled and not self.capture_mode:
            set_catalog = getattr(self.scheduler, "set_catalog", None)
            if set_catalog is not None:
                set_catalog(self.catalog)
        if self.recorder is not None:
            self.catalog.recorder = self.recorder
        self._in_tick = False
        self._recovering = {}  # oid -> in-flight lineage-recovery Future
        self.backend.bind(self)
        self._entered = False
        if forced:
            _capture.register(self)  # the CLI lints every hijacked runtime
        if obs_forced:
            _obs.register(self)  # the CLI summarizes every traced runtime

    # ---------------------------------------------------------------- context
    def __enter__(self):
        _current.rt = self
        self._entered = True
        return self

    def __exit__(self, exc_type, exc, tb):
        try:
            if exc_type is None:
                self.barrier(final=True)
        finally:
            _current.rt = None
            self.backend.shutdown()
        return False

    # ------------------------------------------------------------- submission
    def submit(self, defn: TaskDef, args, kwargs, sim: SimSpec,
               storage_bw=None, storage_tier=None, shard_key=None):
        with self.lock:
            if self.capture_mode:
                # record-only path: no staging, no constraint validation
                # (unsatisfiable classes become IO1xx diagnostics instead of
                # raises), no scheduler, no lifecycle bookkeeping. The
                # capture hook runs BEFORE graph.add so the full
                # happens-before relation — including edges to already-DONE
                # producers, which add elides — is kept for the analyzer.
                inst = TaskInstance(defn, args, kwargs, sim=sim,
                                    storage_bw=storage_bw,
                                    storage_tier=storage_tier)
                if shard_key is not None:
                    inst.shard_key = shard_key  # lint reads routing anchors
                inst.submit_time = 0.0
                self.backend.capture.on_submit(inst)
                ready = self.graph.add(inst)
                if ready and inst.state != TaskState.FAILED:
                    self.backend.mark_ready(inst)
                if defn.returns > 1:
                    return tuple(inst.futures)
                return inst.futures[0]
            args, kwargs = self._stage_inputs(defn, args, kwargs,
                                              storage_tier)
            inst = TaskInstance(defn, args, kwargs, sim=sim,
                                storage_bw=storage_bw,
                                storage_tier=storage_tier)
            if shard_key is not None:
                inst.shard_key = shard_key
            if self.n_shards > 1:
                # route once, at submission: the owning shard is fixed for
                # the task's lifetime (validate_submit below checks the
                # class against that shard's sub-cluster)
                inst.shard = self.scheduler.route(inst)
            # reject unsatisfiable constraint/tier classes HERE, before the
            # task enters the graph: the error surfaces at the call site and
            # no half-registered state (unfinished counts, dependents) is
            # left behind. (getattr: A/B scheduler_cls like the frozen seed
            # predates submission-time validation)
            validate = getattr(self.scheduler, "validate_submit", None)
            if validate is not None:
                validate(inst)
            inst.submit_time = self.backend.now()
            if self.recorder is not None:
                self.recorder.on_submit(inst)
            ready = self.graph.add(inst)
            if inst.state != TaskState.FAILED:
                # scheduled-reader tracking (LRU clock + eviction guard);
                # tasks cancelled at add never run, so they never register
                self.catalog.on_submit(inst)
            if ready:
                self.scheduler.make_ready(inst)
            self.backend.on_submitted()
            self._lifecycle_tick()
        if defn.returns > 1:
            return tuple(inst.futures)
        return inst.futures[0]

    def _stage_inputs(self, defn: TaskDef, args, kwargs, storage_tier):
        """CkIO-style auto-prefetch: any argument future whose tracked data
        object is resident only on tiers slower than this task's target
        placement is replaced by a staging ``rt.prefetch`` future (value
        passes through the mover unchanged), so the read comes from the
        fast tier and concurrent stagings pipeline ahead of the consumer
        wave. One staging serves every reader of the same object."""
        cat = self.catalog
        if not cat.enabled or not cat.config.auto_prefetch:
            return args, kwargs
        if defn.signature in ("tier_drain", "tier_prefetch",
                              "lineage_recover"):
            return args, kwargs  # movers/recovery move data; never staged
        order = cat.cluster.tier_names()
        target = storage_tier or defn.storage_tier or \
            (order[0] if order else None)
        if target is None:
            return args, kwargs

        def map_arg(a, depth=0):
            if isinstance(a, Future):
                obj = cat.lookup_future(a)
                if obj is not None and cat.wants_stage(obj, target):
                    pf = cat.staging_future(obj, target)
                    if pf is None:
                        src = obj.fastest_tier(cat.tier_rank)
                        pf = self.prefetch(a, to_tier=target, from_tier=src,
                                           io_mb=obj.size_mb)
                        cat.begin_stage(obj, target, pf)
                    return pf
                if obj is None and cat.config.pipeline_prefetch:
                    # producer pipelining: the input's producer has not
                    # finished, so where its output will live is unknown —
                    # chain a *conditional* staging onto the producer's
                    # completion (decided at registration; a useless mover
                    # is neutralized into a zero-cost pass-through)
                    pf = cat.deferred_stage_future(a, target)
                    if pf is None and cat.wants_deferred_stage(a, target):
                        pf = self.prefetch(a, to_tier=target,
                                           io_mb=a.task.sim.io_bytes)
                        cat.begin_deferred_stage(a, target, pf)
                    if pf is not None:
                        return pf
                return a
            if depth < 4:
                if isinstance(a, list):
                    return [map_arg(v, depth + 1) for v in a]
                if isinstance(a, tuple):
                    return tuple(map_arg(v, depth + 1) for v in a)
                if isinstance(a, dict):
                    return {k: map_arg(v, depth + 1) for k, v in a.items()}
            return a

        return (tuple(map_arg(a) for a in args),
                {k: map_arg(v) for k, v in kwargs.items()})

    # ------------------------------------------------------------- completion
    def _handle_completion(self, task: TaskInstance) -> None:
        # called by the backend (sim loop / worker thread under runtime lock)
        self.scheduler.on_complete(task)
        failed = task.state == TaskState.FAILED
        # lifecycle bookkeeping AFTER the scheduler committed/cancelled the
        # capacity reservation: residency registration, reader release,
        # stage/evict mover resolution
        self.catalog.on_task_done(task, failed=failed)
        tag = getattr(task, "_datalife", None)
        if tag is not None and tag[0] == "recover":
            obj = tag[1]
            self._recovering.pop(obj.oid, None)
            # a lineage re-run restores a copy, not necessarily durability:
            # chain the emergency re-drain if the durable tier still lacks one
            if not failed and not obj.ephemeral and \
                    self.catalog.durable_tier is not None and \
                    self.catalog.durable_tier not in obj.residency:
                self._issue_redrain(obj)
        if not failed:
            newly_ready = self.graph.complete(task)
            if newly_ready:
                self.scheduler.make_ready_many(newly_ready)
        else:
            # failed task leaves the graph and takes its (necessarily still
            # PENDING) data-descendants with it, so drain loops can't hang on
            # them; write-after-read successors are merely unblocked
            cancelled, newly_ready = self.graph.fail(task)
            for c in cancelled:
                self.catalog.on_task_done(c, failed=True)
            if newly_ready:
                self.scheduler.make_ready_many(newly_ready)
        self._lifecycle_tick()

    # -------------------------------------------------------- fault tolerance
    def _requeue_retry(self, task: TaskInstance) -> None:
        """Return a failed attempt to the ready queue (SimBackend retry
        path, mirroring RealBackend's in-worker loop): the scheduler
        releases the grant, placement state is wiped, and the task re-enters
        readiness as a *fresh* grant — attempt N+1 may land on a different
        device, constraint, or tier than attempt N. Called under the
        runtime lock."""
        self.scheduler.on_retry(task)
        task.worker = None
        task.device = None
        task.granted_bw = 0.0
        task.reserved_mb = 0.0
        task.read_penalty = 0.0
        task.epoch = None
        task.tuner_key = None
        task.error = None
        task.measured_duration = None
        task._telemetry_k = 0
        if task.tier is not None and \
                not eligible_devices(self.cluster, task.tier):
            # the pinned tier went entirely offline: fall back to
            # tier-agnostic placement so the retry can land on a survivor
            task.tier = None
        task.state = TaskState.READY
        self.scheduler.make_ready(task)

    def _on_health_change(self, offline) -> None:
        """Devices went offline (FailureEngine transition, SimBackend):
        drop the residencies that died with them and synthesize recovery
        work. Called under the runtime lock, after in-flight I/O on the
        dead devices has failed into the retry path."""
        cat = self.catalog
        if not cat.enabled:
            return
        for dev in offline:
            orphans, at_risk = cat.on_device_offline(dev)
            for obj in at_risk:
                self._issue_redrain(obj)
            for obj in orphans:
                self._recover_object(obj)

    def _issue_redrain(self, obj) -> None:
        """Emergency re-drain: the object's only durable copy died with its
        device but a surviving copy exists on a faster tier — write it back
        so the object is durable again. If the durable tier is entirely
        offline the drain queues until a recovery event (lint IO501 flags a
        schedule that kills it permanently)."""
        cat = self.catalog
        to_tier = cat.durable_tier
        if to_tier is None or to_tier in obj.residency or obj.recovering:
            return
        src = obj.fastest_tier(cat.tier_rank)
        if src is None:
            return
        obj.recovering = True
        fut = self.drain(None, to_tier=to_tier, from_tier=src,
                         io_mb=obj.size_mb)
        fut.task._datalife = ("redrain", obj)
        self.scheduler._dirty = True

    def _recover_object(self, obj):
        """Lineage re-run for an orphaned object (every copy lost): re-
        execute the producer's recorded work, recursively recovering any
        of its tracked inputs that are also gone. Ephemeral objects nobody
        will read again are dropped silently; objects with no recorded
        producer (externals) are unrecoverable and land in
        ``catalog.lost_objects``. Returns the in-flight recovery Future
        (deduplicated per object), or None."""
        cat = self.catalog
        fut = self._recovering.get(obj.oid)
        if fut is not None:
            return fut
        if obj.ephemeral and not obj.readers:
            return None  # rt.discard temp data: nothing worth re-running
        producer = self.graph.tasks.get(obj.producer_tid)
        if producer is None:
            # external dataset or untracked producer: lineage is gone
            cat.lost_objects.append(obj)
            return None
        deps = []
        for inp in cat.input_objects(producer):
            if inp.residency:
                continue  # a surviving copy feeds the re-run directly
            f = self._recover_object(inp)
            if f is not None:
                deps.append(f)
        tier = producer.tier
        if tier is not None and not eligible_devices(self.cluster, tier):
            tier = None  # the producer's tier died too: land anywhere alive
        obj.recovering = True
        sim = SimSpec(duration=producer.sim.duration, io_bytes=obj.size_mb)
        fut = self.submit(_recover_task.defn, (deps,), {}, sim,
                          storage_tier=tier)
        fut.task._datalife = ("recover", obj)
        self._recovering[obj.oid] = fut
        self.scheduler._dirty = True
        return fut

    # --------------------------------------------------------- data lifecycle
    def _lifecycle_tick(self) -> bool:
        """Run one eviction-planning pass: watermark pressure plus any
        capacity-blocked demand the scheduler reported. Objects with a
        durable copy are dropped immediately; the rest get drain-then-delete
        eviction tasks (``rt.drain`` to the durable tier). Returns True when
        any eviction was started — backends use this to retry placement
        before declaring the scheduler stuck."""
        cat = self.catalog
        if not cat.enabled or self._in_tick:
            return False
        self._in_tick = True
        try:
            demand = getattr(self.scheduler, "capacity_blocked", None)
            actions = cat.plan_evictions(demand)
            if demand:
                demand.clear()
            progress = False
            for act in actions:
                if act.drain_to is None:
                    cat.drop_now(act.obj, act.device)
                    progress = True
                else:
                    fut = self.drain(None, to_tier=act.drain_to,
                                     from_tier=act.device.tier,
                                     io_mb=act.obj.size_mb)
                    fut.task._datalife = ("evict", act.obj, act.device)
                    progress = True
            if progress:
                self.scheduler._dirty = True
            return progress
        finally:
            self._in_tick = False

    def external_data(self, name: str, size_mb: float, tier: str,
                      pinned: bool = False) -> Future:
        """Register a dataset that already lives on ``tier`` (e.g. input
        files on the parallel FS at t0 — the CkIO staging scenario) and
        return a resolved Future tracked by the catalog: tasks taking it as
        an argument get read penalties and auto-prefetch like any produced
        object."""
        if not self.catalog.enabled:
            raise RuntimeError(
                "external_data requires the data lifecycle subsystem: give "
                "a tier a finite capacity_gb or pass "
                "LifecycleConfig(enabled=True)")
        with self.lock:
            # capture: register without charging device capacity (the
            # analyzer reasons about footprints symbolically; a recording
            # run must leave shared device state untouched)
            obj = self.catalog.add_external(name, size_mb, tier,
                                            pinned=pinned,
                                            charge=not self.capture_mode)
            fut = resolved_future(value=name, name=f"external:{name}")
            self.catalog.map_future(fut, obj)
            if self.capture_mode:
                self.backend.capture.on_external(name, size_mb, tier, pinned)
        return fut

    def pin(self, fut) -> None:
        """Exempt the future's data object from eviction."""
        with self.lock:
            if self.capture_mode:
                self.backend.capture.on_pin(fut)
                return
            self.catalog.pin(fut)

    def unpin(self, fut) -> None:
        with self.lock:
            if self.capture_mode:
                self.backend.capture.on_unpin(fut)
                return
            self.catalog.unpin(fut)

    def discard(self, fut) -> None:
        """Ephemeral liveness signal: the future's tracked data object will
        never be read again, so eviction may delete it *without* the
        durable drain (no FS bandwidth spent writing temp data back on its
        way out). Scheduled readers already in the graph are still
        honoured. Discarding before the producer finishes defers the mark
        to registration."""
        if not self.catalog.enabled:
            raise RuntimeError(
                "discard requires the data lifecycle subsystem: give a tier "
                "a finite capacity_gb or pass LifecycleConfig(enabled=True)")
        with self.lock:
            if self.capture_mode:
                self.backend.capture.on_discard(fut)
                return
            self.catalog.discard(fut)

    # ----------------------------------------------------- tier data movement
    def drain(self, data, to_tier: str, from_tier: Optional[str] = None,
              io_mb: float = 0.0, storage_bw=None,
              path: Optional[str] = None) -> Future:
        """Asynchronously write ``data`` back to a slower tier (e.g. burst
        buffer → shared FS). Returns a Future; the movement is an ordinary
        I/O task that overlaps with compute. ``data`` may be a Future (the
        drain then depends on its producer). ``path`` names a file to copy
        between ``RealBackend.tier_dirs`` directories; ``storage_bw``
        optionally throttles the writer (static MB/s or "auto")."""
        return self._move(_drain_task, data, to_tier, from_tier, io_mb,
                          storage_bw, path)

    def prefetch(self, data, to_tier: str, from_tier: Optional[str] = None,
                 io_mb: float = 0.0, storage_bw=None,
                 path: Optional[str] = None) -> Future:
        """Asynchronously stage ``data`` up to a faster tier (e.g. shared
        FS → node-local SSD) ahead of the tasks that will read it."""
        return self._move(_prefetch_task, data, to_tier, from_tier, io_mb,
                          storage_bw, path)

    def _move(self, mover: TaskFunction, data, to_tier, from_tier, io_mb,
              storage_bw, path) -> Future:
        if io_mb is not None and float(io_mb) < 0:
            raise ValueError(
                f"{mover.defn.name}: io_mb must be non-negative "
                f"(got {io_mb}) — it is the movement's footprint in MB")
        # no-op short-circuits: a same-tier "move", or data the catalog
        # already knows to be resident at the destination, resolves
        # immediately instead of scheduling a zero-progress movement task.
        # A path= move is never short-circuited on residency alone: catalog
        # residency is modelled state, and skipping it would report a real
        # file as copied without copy_fsync ever running.
        if from_tier is not None and from_tier == to_tier:
            return data if isinstance(data, Future) else resolved_future(
                data, name=f"noop_{mover.defn.name}")
        if isinstance(data, Future) and self.catalog.enabled:
            obj = self.catalog.lookup_future(data)
            if obj is not None:
                if to_tier in obj.residency and path is None:
                    return data
                # the catalog knows the payload's true footprint: charge the
                # destination what residency registration will record, not
                # whatever io_mb the caller guessed (a mismatch would desync
                # used_mb from the resident-object sum and underflow on a
                # later eviction)
                io_mb = obj.size_mb
        # read-side floor: a single reader streams at most at the source
        # device's bandwidth (the write side is modelled/performed on the
        # destination tier the task is placed on)
        src = None
        if from_tier is not None:
            src = self.cluster.tier_spec(from_tier)
        elif self.cluster.workers:
            src = self.cluster.workers[0].storage  # default: fastest tier
        dur = read_floor_time(src, io_mb) if src is not None else 0.0
        src_path = dst_path = None
        if path is not None:
            tp = getattr(self.backend, "tier_path", None)
            if tp is not None:
                # a backend that moves real files must be able to resolve
                # both ends — a silent no-op copy would report a drain as
                # durable without having moved anything
                if from_tier is None:
                    raise ValueError(
                        "path= movement needs from_tier= to locate the "
                        "source file")
                src_path = tp(from_tier, path)
                dst_path = tp(to_tier, path)
                if src_path is None or dst_path is None:
                    missing = from_tier if src_path is None else to_tier
                    raise ValueError(
                        f"no tier_dirs directory mapped for tier "
                        f"{missing!r} (have: "
                        f"{sorted(self.backend.tier_dirs)})")
        # pin to the destination tier only when the cluster models it; on a
        # plain single-tier cluster the move still runs, tier-agnostically
        tier_hint = to_tier if self.cluster.has_tier(to_tier) else None
        # submit directly (not via TaskFunction.__call__) so runtime-
        # synthesized movers — eviction drains fired from a completion on a
        # backend worker thread — don't depend on the thread-local ambient
        # runtime being set
        sim = SimSpec(duration=dur, io_bytes=float(io_mb or 0.0))
        return self.submit(
            mover.defn, (data, src_path, dst_path), {}, sim,
            storage_bw=parse_storage_bw(storage_bw)
            if storage_bw is not None else None,
            storage_tier=tier_hint)

    # ------------------------------------------------------------------ waits
    def barrier(self, final: bool = False) -> None:
        if final:
            with self.lock:
                self.scheduler.end_of_stream()
        self.backend.drain(lambda: self.graph.unfinished == 0)

    def wait_on(self, *futures):
        self.backend.drain(lambda: all(f.resolved() for f in futures))
        vals = [f.value() for f in futures]
        return vals[0] if len(vals) == 1 else vals

    # --------------------------------------------------------------- analysis
    def lint(self) -> list:
        """Run the static I/O-plan analyzer (see docs/lint.md) over this
        runtime's recorded plan (capture mode) or live graph. Returns the
        ``Diagnostic`` list sorted by (code, tid); empty means clean."""
        from ..analysis.lint import lint_runtime  # lazy: import cycle
        return lint_runtime(self)

    @contextmanager
    def plan(self):
        """Capture-mode sibling: a second runtime over the same cluster and
        configuration whose backend records the task DAG without executing
        any task body (futures resolve to ``None``). While the block is
        active it is the ambient runtime, so the same driving code that
        feeds this runtime can be replayed against it::

            with rt.plan() as p:
                build_pipeline()          # decorators submit to p, not rt
            diags = p.lint()

        Device state and catalogs of the live runtime are untouched."""
        cfg = self._plan_config
        prt = IORuntime(self.cluster, backend="capture",
                        scheduler_cls=cfg["scheduler_cls"],
                        lifecycle=cfg["lifecycle"],
                        interference=cfg["interference"],
                        failures=cfg["failures"],
                        drift=cfg["drift"],
                        tier_objective=cfg["tier_objective"],
                        shards=cfg["shards"])
        prev = getattr(_current, "rt", None)
        _current.rt = prt
        try:
            yield prt
            prt.barrier(final=True)
        finally:
            _current.rt = prev

    def trace(self) -> Optional[TraceRecorder]:
        """The runtime's :class:`~repro.obs.TraceRecorder` when constructed
        with ``trace=True`` (None otherwise — callers guard)."""
        return self.recorder

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        done = self.scheduler.completed
        io_tasks = [t for t in done if t.is_io]
        out = {
            "makespan": self.backend.now(),
            "n_tasks": len(done),
            "n_io_tasks": len(io_tasks),
            "avg_io_task_time": (sum(t.duration for t in io_tasks) / len(io_tasks))
            if io_tasks else 0.0,
            "tuners": {s: t.summary() for s, t in self.scheduler.tuners.items()},
            # per-tier occupancy: one entry per distinct device in the
            # hierarchy (shared tiers appear once)
            "devices": {d.name: {"tier": d.tier,
                                 "bytes_written": d.bytes_written,
                                 "capacity_mb": d.capacity_mb,
                                 "used_mb": d.used_mb,
                                 "peak_occupancy_mb": d.peak_occupancy_mb}
                        for d in self.cluster.devices},
        }
        if getattr(self.scheduler, "n_shards", 1) > 1:
            # sharded control plane rollup: per-shard launch counts, bus
            # message counters, lease accounts. Present exactly when the
            # run was sharded — unsharded stats stay schema-identical.
            out["shards"] = self.scheduler.summary()
            out["shards"]["cross_shard_edges"] = self.graph.cross_shard_edges
            out["shards"]["local_edges"] = self.graph.local_edges
        if self.catalog.enabled:
            out["lifecycle"] = self.catalog.summary()
        if self.interference is not None:
            out["interference"] = self.interference.summary()
        if self.failures is not None:
            out["failures"] = self.failures.summary()
        be = self.backend
        if isinstance(be, SimBackend):
            out.update({
                "io_busy_time": be.io_busy_time,
                "compute_busy_time": be.compute_busy_time,
                "overlap_time": be.overlap_time,
                "total_io_mb": be.total_io_mb,
                "io_throughput_mbs": (be.total_io_mb / be.io_busy_time)
                if be.io_busy_time > 0 else 0.0,
                "peak_io_mbs": be.peak_io_mbs,
            })
        if self.recorder is not None:
            # attribution rollup; absent when tracing is off so untraced
            # stats stay schema-identical to pre-obs runs (golden parity)
            out["wait_states"] = self.recorder.wait_state_summary()
            hub = getattr(self.backend, "telemetry", None)
            if hub is not None:
                # measured-throughput rollup: present exactly when the run
                # was traced AND the backend measures (RealBackend carries
                # a TelemetryHub, the simulator does not) — sim stats stay
                # schema-identical with the telemetry wiring present
                out["telemetry"] = hub.summary()
        return out
