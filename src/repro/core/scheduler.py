"""The I/O-aware scheduler (paper §4.2).

Compute tasks are placed by computing-unit availability (the compute
execution platform). I/O tasks are placed by *I/O executor* availability and
*storage-bandwidth* budget (the I/O execution platform) — their computing
requirement is zero, so they overlap with compute tasks (paper §4.2.1).

Auto-constrained tasks are routed through a per-signature :class:`AutoTuner`.
While a tuner is learning, its tasks run only on a dedicated
*active-learning node* and no other I/O tasks are co-scheduled there
(paper §4.2.3B). Once learning finishes the node is released and the
objective function picks the constraint, re-evaluated on every arrival.

Tier-aware placement (multi-tier storage hierarchy): every worker carries an
ordered list of storage tiers (resources.py). An I/O task with no tier hint
is placed on the *fastest tier with budget*: the scheduler tries tier 0 on
every candidate worker, then tier 1, and so on down the hierarchy, so a
saturated node-local SSD spills to the burst buffer and then to the shared
FS instead of queueing. A tier hint (``@constraint(tier=...)`` or per-call
``storage_tier=``) pins the task to that tier's devices. Auto-constrained
tasks get one :class:`AutoTuner` per (signature, tier) — the optimal
constraint is a property of the device the tasks actually write to — keyed
``sig`` for the default tier and ``"sig@tier"`` for hinted ones.

Hot-path design (100k-task workloads): ready tasks are kept in per
*placement-class* FIFO deques — one class per (compute-units), (static-bw,
tier) or (auto signature, tier) — because two ready tasks of the same class
have identical placement requirements: if the head of a class cannot be
placed, no other member can either, so a pass attempts at most one task per
class instead of rescanning the whole ready list. A heap over class heads
keeps the global attempt order identical to the seed's submission-order
scan, and a dirty flag skips passes entirely unless a resource was freed, a
tuner epoch advanced, or a new task became ready. Unsatisfiable static
constraints (a storageBW no device can ever grant, or a tier hint naming a
tier no worker has) are rejected once per placement class at submission
time instead of being rescanned on every failed placement attempt.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, Iterable, Optional

from .autotune import AutoTuner, DriftConfig
from .constraints import AutoSpec, StaticSpec, is_auto
from .resources import Cluster, StorageDevice, WorkerNode
from .storage_model import cross_tier_time
from .task import Future, TaskInstance, TaskState, TaskType


class SchedulerError(RuntimeError):
    pass


def eligible_devices(cluster: Cluster, tier: Optional[str],
                     healthy_only: bool = True) -> list[StorageDevice]:
    """Distinct devices a task with tier hint ``tier`` may ever be granted
    on (every tier of every worker when unhinted; shared devices appear
    once). Shared between submission-time class validation below and the
    static plan analyzer (repro.analysis.lint), so a lint diagnostic and a
    runtime ``SchedulerError`` can never disagree about placeability.

    Health-aware (failures.py): offline devices are not eligible — the
    scheduler never grants to them, and lint agrees. Degraded devices stay
    eligible (degradation is transient; nameplate bandwidth still bounds
    feasibility). ``healthy_only=False`` restores the raw topology view."""
    seen: set[int] = set()
    out: list[StorageDevice] = []
    for w in cluster.workers:
        if tier is None:
            devs = w.tiers
        else:
            d = w.tier_device(tier)
            devs = [d] if d is not None else []
        for d in devs:
            if healthy_only and d.health == "offline":
                continue
            if id(d) not in seen:
                seen.add(id(d))
                out.append(d)
    return out


class Scheduler:
    def __init__(self, cluster: Cluster,
                 launch: Callable[[TaskInstance, WorkerNode], None]):
        self.cluster = cluster
        self._launch = launch
        # per placement-class FIFO deques of ready tasks (see module docstring)
        self._ready_q: dict[tuple, deque[TaskInstance]] = {}
        self._ready_count = 0
        self._sig_ready: dict[str, int] = {}   # signature -> #ready (O(1))
        self._ready_seq = itertools.count()    # global readiness order
        # refusal epoch: one tick per dirty wake-up (completion, retry,
        # readiness, health/burst pokes). Blocked-head diagnoses are
        # memoized per (class, head, epoch) so a traced run scans each
        # blocked class once per event, not once per round (the diagnosis
        # walks every worker; see _diagnose_block).
        self._refusal_epoch = 0
        self._diag_cache: dict[tuple, tuple] = {}
        self._dirty = True                     # wake-up flag: anything changed
        #                                        since the last zero-progress pass?
        self._validated: set[tuple] = set()    # class keys proven satisfiable
        self._tier_depth = max((len(w.tiers) for w in cluster.workers),
                               default=1)
        self._recompute_tier_caps()
        self.running: set[int] = set()
        # tuners/learning_nodes are keyed per (signature, tier): plain ``sig``
        # for the default tier (seed-compatible), ``"sig@tier"`` for hints
        self.tuners: dict[str, AutoTuner] = {}
        self.learning_nodes: dict[str, WorkerNode] = {}
        # the *device* a tuner calibrates must be quiet too: on shared tiers
        # (burst buffer / FS) node-level isolation alone would let other
        # workers' traffic pollute the epoch measurements (paper §4.2.3B)
        self.learning_devices: dict[str, object] = {}   # key -> StorageDevice
        self._learning_dev_ids: set[int] = set()
        self.completed: list[TaskInstance] = []
        self.launch_log: list[tuple[float, str, str]] = []  # (tid, sig, worker)
        # data lifecycle (datalife.py): None unless the runtime wires an
        # enabled catalog — the capacity-less hot path stays untouched
        self.catalog = None
        # observability (obs/): None unless the runtime wires a recorder —
        # a disabled run pays one is-not-None check per readiness/refusal
        self.recorder = None
        self.capacity_blocked: dict[int, float] = {}  # id(dev) -> wanted MB
        # sharded control plane (shardplane.py): this scheduler's identity
        # inside a ShardedScheduler, and the lease broker gating bandwidth
        # grants on shared devices. The unsharded defaults cost one is-None
        # check per I/O grant and nothing else.
        self.shard_id = 0
        self.shard_lease = None
        # tuning extensions (interference.py / autotune.DriftConfig): both
        # default off, leaving the paper's placement byte-identical
        self.drift_config: Optional[DriftConfig] = None
        self.tier_objective = False
        self._probe_counts: dict[str, int] = {}  # sig -> steady grants (the
        #                                          cross-tier probe clock)

    def set_tuning(self, drift: Optional[DriftConfig] = None,
                   tier_objective: bool = False) -> None:
        """Wire the interference-era tuning extensions (runtime calls this):
        ``drift`` makes every AutoTuner monitor observed-vs-predicted task
        times and re-enter calibration when the curve goes stale;
        ``tier_objective`` turns the fastest-with-budget walk for
        tier-agnostic auto tasks into a measured decision across the
        learned per-tier T(n, c) curves, priced with the eviction drain a
        nearly-full fast tier would force."""
        self.drift_config = drift
        self.tier_objective = bool(tier_objective)

    def set_recorder(self, recorder) -> None:
        """Wire the trace recorder (runtime calls this when tracing is on):
        readiness, grant refusals (diagnosed per placement class), and
        queue-depth samples flow into the event stream."""
        self.recorder = recorder

    def set_catalog(self, catalog) -> None:
        """Wire the data catalog (runtime calls this when the lifecycle
        subsystem is enabled): grants then check + reserve tier capacity,
        completions commit it, and capacity-blocked demand is reported for
        demand-driven eviction."""
        self.catalog = catalog
        # the catalog may have applied TierCapacity budgets to devices
        self._recompute_tier_caps()

    def _recompute_tier_caps(self) -> None:
        """Per-tier (and any-tier, key None) LARGEST device capacity, with
        None meaning "some device is unlimited" — precomputed so the
        per-submission feasibility check stays O(1) on the 100k-task hot
        path (capacities are fixed once the runtime is constructed)."""
        self._tier_max_cap: dict = {}
        for d in self.cluster.devices:
            for key in (d.tier, None):
                if key in self._tier_max_cap \
                        and self._tier_max_cap[key] is None:
                    continue
                if d.capacity_mb is None:
                    self._tier_max_cap[key] = None
                else:
                    self._tier_max_cap[key] = max(
                        self._tier_max_cap.get(key, 0.0), d.capacity_mb)

    # ------------------------------------------------------------------ utils
    @property
    def _dirty(self) -> bool:
        return self._dirty_flag

    @_dirty.setter
    def _dirty(self, value: bool) -> None:
        """Every wake-up (True write) advances the refusal epoch — the
        cache key for memoized blocked-head diagnoses. Writes come from
        this class and from the runtime/backends (``scheduler._dirty =
        True`` on health transitions and burst boundaries), so the setter
        is the one chokepoint that sees them all."""
        if value:
            self._refusal_epoch += 1
        self._dirty_flag = value

    @staticmethod
    def _tuner_key(sig: str, tier: Optional[str]) -> str:
        return sig if tier is None else f"{sig}@{tier}"

    @staticmethod
    def _tier_on(w: WorkerNode, tier: Optional[str]):
        """The device ``tier`` resolves to on worker ``w``: the fastest
        (primary) device when no hint is given, else the named tier or None
        when the worker doesn't reach it."""
        return w.storage if tier is None else w.tier_device(tier)

    def tuner_for(self, task: TaskInstance,
                  node: Optional[WorkerNode] = None) -> AutoTuner:
        return self._make_tuner(
            self._tuner_key(task.defn.signature, task.tier),
            task.storage_bw, node, task.tier)

    def _make_tuner(self, key: str, spec, node: Optional[WorkerNode],
                    tier: Optional[str]) -> AutoTuner:
        if key not in self.tuners:
            assert isinstance(spec, AutoSpec)
            # the device model the tuner reasons about: the tier device of
            # the active-learning node its epochs actually run on (falls back
            # to the first worker when called before a node is acquired).
            w = node if node is not None else self.cluster.workers[0]
            dev = self._tier_on(w, tier) or w.storage
            self.tuners[key] = AutoTuner(
                key, spec, device_bw=dev.bandwidth,
                io_executors=w.io_executors, drift=self.drift_config)
        return self.tuners[key]

    def _acquire_learning_node(self, key: str,
                               tier: Optional[str] = None
                               ) -> Optional[WorkerNode]:
        node = self.learning_nodes.get(key)
        if node is not None:
            return node
        for w in self.cluster.workers:
            if w.learning_owner is not None:
                continue
            dev = self._tier_on(w, tier)
            if dev is None or id(dev) in self._learning_dev_ids \
                    or dev.health == "offline":
                continue  # tier absent, under calibration, or failed
            w.learning_owner = key
            self.learning_nodes[key] = w
            self.learning_devices[key] = dev
            self._learning_dev_ids.add(id(dev))
            return w
        return None  # all nodes busy learning other signatures: wait

    def _release_learning_node(self, key: str) -> None:
        node = self.learning_nodes.pop(key, None)
        if node is not None:
            node.learning_owner = None
            dev = self.learning_devices.pop(key, None)
            if dev is not None:
                self._learning_dev_ids.discard(id(dev))
            self._dirty = True

    def n_ready_of(self, sig: str) -> int:
        return self._sig_ready.get(sig, 0)

    @property
    def n_ready(self) -> int:
        return self._ready_count

    @property
    def ready(self) -> list[TaskInstance]:
        """Materialised ready list in readiness order (debug/reporting only —
        the hot path never builds this)."""
        tasks = [t for q in self._ready_q.values() for t in q]
        tasks.sort(key=lambda t: t._ready_seq)
        return tasks

    @staticmethod
    def _class_key(task: TaskInstance) -> tuple:
        """Placement class: tasks with the same key have identical placement
        requirements at any scheduler state."""
        d = task.defn
        if d.task_type == TaskType.COMPUTE:
            return ("C", d.computing_units)
        spec = task.storage_bw
        if is_auto(spec):
            return ("A", d.signature, task.tier)
        bw = spec.value if isinstance(spec, StaticSpec) else 0.0
        return ("S", bw, task.tier)

    def validate_submit(self, task: TaskInstance) -> None:
        """Called by the runtime *before* the task enters the graph, so an
        unsatisfiable class raises at the submission call site with no
        half-registered state left behind (and never from a completion
        fan-out on a backend worker thread)."""
        self._validate_class(self._class_key(task))
        # per-task (not per-class) feasibility: an output footprint larger
        # than every eligible device's TOTAL capacity can never be granted,
        # not even after evicting everything — without this check the task
        # would block its placement class forever and the run would die with
        # a generic "scheduler stuck" at the barrier. Only meaningful while
        # capacity is enforced (catalog wired; see _capacity_ok).
        mb = task.sim.io_bytes
        if self.catalog is None or task.defn.task_type == TaskType.COMPUTE \
                or mb <= 0:
            return
        tier = task.tier
        # (an unknown tier already raised in _validate_class above)
        cap = self._tier_max_cap.get(tier if tier is not None else None)
        if cap is not None and mb > cap:
            raise SchedulerError(
                f"io_mb={mb} exceeds every eligible device's total "
                f"capacity"
                + (f" on tier {tier!r}" if tier is not None else "")
                + f" (max {cap:.0f} MB)")

    def _validate_class(self, key: tuple) -> None:
        """Once-per-class satisfiability check (at submission time): a static
        storageBW no eligible device can ever grant, or a tier hint naming a
        tier no worker reaches, would otherwise fail every placement attempt
        forever — the seed rescanned all workers on *each* attempt instead.
        Only satisfiable keys are cached: a rejected class is re-diagnosed
        (same precise error) if the caller retries it."""
        if key in self._validated:
            return
        if key[0] == "C":
            self._validated.add(key)
            return
        tier = key[2]
        if tier is not None and not any(
                w.tier_device(tier) is not None for w in self.cluster.workers):
            raise SchedulerError(
                f"storage tier {tier!r} is not present on any worker "
                f"(available: {self.cluster.tier_names()})")
        if key[0] == "S" and key[1] > 0:
            bw = key[1]
            devs = eligible_devices(self.cluster, tier)
            # an all-offline tier leaves devs empty: the class queues until
            # the tier recovers instead of being rejected as unsatisfiable
            if devs and all(d.bandwidth < bw for d in devs):
                raise SchedulerError(
                    f"storageBW={bw} exceeds every device's bandwidth"
                    + (f" on tier {tier!r}" if tier is not None else ""))
        self._validated.add(key)

    def _sig_key(self, task: TaskInstance) -> str:
        """Backlog-count key: auto I/O tasks count per (signature, tier) —
        the backlog feeds that tier's tuner objective — others per
        signature."""
        if task.defn.task_type != TaskType.COMPUTE and \
                is_auto(task.storage_bw):
            return self._tuner_key(task.defn.signature, task.tier)
        return task.defn.signature

    # -------------------------------------------------------------- submission
    def make_ready(self, task: TaskInstance) -> None:
        task._ready_seq = next(self._ready_seq)
        key = self._class_key(task)
        q = self._ready_q.get(key)
        if q is None:
            q = self._ready_q[key] = deque()
        q.append(task)
        self._ready_count += 1
        sig = self._sig_key(task)
        self._sig_ready[sig] = self._sig_ready.get(sig, 0) + 1
        self._dirty = True
        if self.recorder is not None:
            self.recorder.on_ready(task, key)

    def make_ready_many(self, tasks: Iterable[TaskInstance]) -> None:
        """Batched completion fan-out: newly-ready children arrive together
        (in submission order) so the pass that follows sees them all."""
        for t in tasks:
            self.make_ready(t)

    # -------------------------------------------------------------- scheduling
    def schedule_pass(self) -> int:
        """Try to place every ready task; returns number launched.

        Event-driven: returns immediately unless something changed since the
        last zero-progress pass (resource freed, tuner epoch advanced, new
        ready task). Within a pass, class heads are attempted in global
        readiness order; a failed head blocks its whole class for the rest of
        the round (identical requirements => identical outcome), and rounds
        repeat until one launches nothing — matching the seed's
        ``while progress`` full-rescan semantics at O(log n) per attempt.
        """
        if not self._dirty or self._ready_count == 0:
            return 0
        launched = 0
        while True:
            n = self._round()
            launched += n
            if n == 0:
                break
        self._dirty = False
        return launched

    def _round(self) -> int:
        heads = [(q[0]._ready_seq, key)
                 for key, q in self._ready_q.items() if q]
        heapq.heapify(heads)
        launched = 0
        while heads:
            _, key = heapq.heappop(heads)
            if self._attempt_head(key):
                launched += 1
                q = self._ready_q.get(key)
                if q:
                    heapq.heappush(heads, (q[0]._ready_seq, key))
        return launched

    def _attempt_head(self, key: tuple) -> bool:
        """One placement attempt on the head of class ``key`` (no re-queue):
        True launched and dequeued it; False leaves the class blocked for
        the rest of the round. The sharded control plane
        (shardplane.ShardedScheduler) calls this directly so its global
        round can interleave class heads across shards in readiness order."""
        q = self._ready_q[key]
        task = q[0]
        if self._try_place(task):
            q.popleft()
            self._ready_count -= 1
            sig = self._sig_key(task)
            self._sig_ready[sig] -= 1
            if not self._sig_ready[sig]:
                del self._sig_ready[sig]
            if not q:
                # drop drained classes so rounds stay O(live classes)
                # (per-call storage_bw overrides can mint many keys)
                del self._ready_q[key]
            return True
        if self.recorder is not None:
            # class blocked until the next round — diagnose why (pure
            # reads) so ready->launch time is attributable per class.
            # Memoized per (class, head, refusal epoch): re-diagnosing
            # the same head within one dirty wake-up would re-walk every
            # worker per round for an answer that only event-level state
            # changes can alter (within a pass resources only shrink).
            cached = self._diag_cache.get(key)
            if cached is not None and cached[0] == self._refusal_epoch \
                    and cached[1] == task.tid:
                reason, dev_name, wanted = cached[2]
            else:
                result = self._diagnose_block(task)
                self._diag_cache[key] = (
                    self._refusal_epoch, task.tid, result)
                reason, dev_name, wanted = result
            self.recorder.note_block(key, reason, dev_name, wanted)
        # else: class blocked until the next round — nothing that happens
        # later in this round can make it placeable (resources only shrink)
        return False

    def _diagnose_block(self, task: TaskInstance) -> tuple:
        """Classify why ``task`` (a blocked class head) could not be placed
        just now: re-walk the candidates the attempt tried with pure reads
        (never mutates scheduler, tuner, or device state — recording must
        leave placement byte-identical) and report the dominant refusal,
        ``(reason, device_name, wanted_mb)``. Precedence mirrors severity:
        capacity > bandwidth > executor > learning > offline."""
        d = task.defn
        if d.task_type == TaskType.COMPUTE:
            return "cpu", None, 0.0
        tier = task.tier
        spec = task.storage_bw
        bw = 0.0
        if is_auto(spec):
            if self.tier_objective and tier is None and self._tier_depth > 1:
                # cross-tier objective: learning while any tier's curve is
                # unlearned; afterwards diagnose with the first tier's choice
                tuner = None
                for tname in self.cluster.tier_names():
                    t = self.tuners.get(self._tuner_key(d.signature, tname))
                    if t is None or t.learning():
                        return "learning", None, 0.0
                    if tuner is None:
                        tuner = t
            else:
                key = self._tuner_key(d.signature, tier)
                tuner = self.tuners.get(key)
                if tuner is None or tuner.learning():
                    return "learning", None, 0.0
            bw = tuner.peek_choice(max(1, self.n_ready_of(
                self._sig_key(task))))
        elif isinstance(spec, StaticSpec):
            bw = spec.value
        wanted = task.sim.io_bytes
        seen: dict[str, Optional[str]] = {}
        for w in self.cluster.workers:
            devs = [w.tier_device(tier)] if tier is not None else w.tiers
            for dev in devs:
                if dev is None:
                    continue
                if dev.health == "offline":
                    seen.setdefault("offline", dev.name)
                elif w.learning_owner is not None \
                        or id(dev) in self._learning_dev_ids:
                    seen.setdefault("learning", dev.name)
                elif w.free_io_executors <= 0:
                    seen.setdefault("executor", dev.name)
                elif bw > 0 and not dev.can_allocate(bw):
                    seen.setdefault("bandwidth", dev.name)
                elif self.catalog is not None \
                        and dev.capacity_gb is not None and wanted > 0 \
                        and not dev.can_reserve_capacity(wanted):
                    seen.setdefault("capacity", dev.name)
        for reason in ("capacity", "bandwidth", "executor", "learning",
                       "offline"):
            name = seen.get(reason)
            if name is not None:
                return reason, name, wanted if reason == "capacity" else 0.0
        return "unattributed", None, 0.0

    def _try_place(self, task: TaskInstance) -> bool:
        if task.defn.task_type == TaskType.COMPUTE:
            return self._place_compute(task)
        return self._place_io(task)

    def _place_compute(self, task: TaskInstance) -> bool:
        cu = task.defn.computing_units
        for w in self.cluster.workers:
            if w.free_cpus >= cu:
                w.free_cpus -= cu
                self._start(task, w, bw=0.0)
                return True
        return False

    def _place_io(self, task: TaskInstance) -> bool:
        spec = task.storage_bw
        if is_auto(spec):
            return self._place_auto_io(task)
        bw = spec.value if isinstance(spec, StaticSpec) else 0.0
        # (unsatisfiable constraints were rejected per-class at submission)
        tier = task.tier
        candidates = self._io_candidates(task)
        if tier is not None:
            # pinned: only devices backing the named tier qualify
            for w in candidates:
                dev = w.tier_device(tier)
                if dev is not None and self._grant_io(task, w, dev, bw):
                    return True
            return False
        # tier-agnostic: fastest tier with budget wins — try every worker's
        # tier 0, then every worker's tier 1, ... (fall down the hierarchy)
        for ti in range(self._tier_depth):
            for w in candidates:
                if ti >= len(w.tiers):
                    continue
                if self._grant_io(task, w, w.tiers[ti], bw):
                    return True
        return False

    def _capacity_ok(self, task: TaskInstance, dev) -> bool:
        """Capacity side of a grant: the task's output footprint must fit on
        the device (unlimited tiers always fit). A refusal is recorded as
        *demand* so the runtime's lifecycle tick can evict to make room —
        the tier-agnostic walk meanwhile spills the task down the
        hierarchy. Gated on the catalog: with the lifecycle subsystem
        explicitly disabled nothing would ever free occupancy, so enforcing
        the budget would wedge pinned workloads — capacity_gb is then
        documentation, not a constraint."""
        if self.catalog is None or dev.capacity_gb is None \
                or task.sim.io_bytes <= 0:
            return True
        if dev.can_reserve_capacity(task.sim.io_bytes):
            return True
        did = id(dev)
        self.capacity_blocked[did] = max(
            self.capacity_blocked.get(did, 0.0), task.sim.io_bytes)
        return False

    def _reserve_capacity(self, task: TaskInstance, dev) -> None:
        """Reserve-at-grant (commit happens in on_complete)."""
        if self.catalog is None or dev.capacity_gb is None \
                or task.sim.io_bytes <= 0:
            return
        dev.reserve_capacity(task.sim.io_bytes)
        task.reserved_mb = task.sim.io_bytes

    def _grant_io(self, task: TaskInstance, w: WorkerNode, dev,
                  bw: float) -> bool:
        if w.learning_owner is not None:
            return False  # active-learning node: keep it isolated
        if id(dev) in self._learning_dev_ids:
            return False  # device under calibration (shared-tier isolation)
        if dev.health == "offline":
            return False  # failed device: bw=0 grants bypass can_allocate,
            #               so the health gate must be explicit
        if w.free_io_executors <= 0:
            return False
        if bw > 0 and not dev.can_allocate(bw):
            return False
        if not self._capacity_ok(task, dev):
            return False
        if bw > 0 and self.shard_lease is not None \
                and not self.shard_lease.acquire(self.shard_id, dev, bw):
            return False
        w.free_io_executors -= 1
        if bw >= 0:
            dev.allocate(bw)
        self._reserve_capacity(task, dev)
        self._start(task, w, bw=bw, device=dev)
        return True

    def _learning_grant(self, task: TaskInstance, key: str,
                        tier: Optional[str]) -> bool:
        """Admit the task into ``key``'s current learning epoch on that
        tuner's dedicated active-learning node (paper §4.2.3B)."""
        node = self._acquire_learning_node(key, tier)
        if node is None:
            return False
        dev = self._tier_on(node, tier)
        if dev.health == "offline":
            return False
        # the tuner models the device it actually learns on
        tuner = self._make_tuner(key, task.storage_bw, node, tier)
        c = tuner.current_constraint()
        if node.free_io_executors <= 0 or not dev.can_allocate(c):
            return False
        if not self._capacity_ok(task, dev):
            return False
        # lease before admit: un-admitting is observable tuner state, so the
        # lease (pure accounting, and always grantable after can_allocate —
        # see shardplane.LeaseBroker) is the one taken tentatively
        if c > 0 and self.shard_lease is not None \
                and not self.shard_lease.acquire(self.shard_id, dev, c):
            return False
        if not tuner.admit():
            if c > 0 and self.shard_lease is not None:
                self.shard_lease.release(self.shard_id, dev, c)
            return False  # current epoch full; wait for the next one
        node.free_io_executors -= 1
        dev.allocate(c)
        self._reserve_capacity(task, dev)
        task.epoch = tuner.epoch
        task.tuner_key = key
        self._start(task, node, bw=c, device=dev)
        return True

    def _steady_grant(self, task: TaskInstance, key: str,
                      tier: Optional[str], tuner: AutoTuner,
                      c: float) -> bool:
        """Place a steady-phase auto task under constraint ``c`` on the
        first candidate worker with budget on ``tier``."""
        for w in self._io_candidates(task):
            if w.learning_owner is not None:
                continue
            dev = self._tier_on(w, tier)
            if dev is None or id(dev) in self._learning_dev_ids \
                    or dev.health == "offline":
                continue
            if w.free_io_executors <= 0 or not dev.can_allocate(c):
                continue
            if not self._capacity_ok(task, dev):
                continue
            if c > 0 and self.shard_lease is not None \
                    and not self.shard_lease.acquire(self.shard_id, dev, c):
                continue
            w.free_io_executors -= 1
            dev.allocate(c)
            self._reserve_capacity(task, dev)
            tuner.record_choice(c)
            task.tuner_key = key
            self._start(task, w, bw=c, device=dev)
            return True
        return False

    def _place_auto_io(self, task: TaskInstance) -> bool:
        sig = task.defn.signature
        tier = task.tier
        if self.tier_objective and tier is None and self._tier_depth > 1:
            return self._place_auto_io_cross_tier(task)
        key = self._tuner_key(sig, tier)
        tuner = self.tuners.get(key)
        if tuner is None or tuner.learning():
            return self._learning_grant(task, key, tier)
        # learning done: objective fn, re-evaluated for the current backlog
        # of THIS (signature, tier) — not siblings targeting other tiers
        n = self.n_ready_of(key)
        c = tuner.peek_choice(max(1, n))
        return self._steady_grant(task, key, tier, tuner, c)

    def _place_auto_io_cross_tier(self, task: TaskInstance) -> bool:
        """Measured tier choice for tier-agnostic auto tasks: calibrate a
        tuner per tier (hierarchy order, one at a time), then compare the
        learned T(n, c) curves across tiers — plus the eviction-drain price
        of writing to a nearly-full tier — and place on the argmin. Under
        interference each tier's *effective* curve differs from its
        nameplate ordering, so the walk is a measurement, not a heuristic;
        drifted tuners re-enter calibration and the ranking follows."""
        sig = task.defn.signature
        tiers = self.cluster.tier_names()
        # phase 1: the first tier whose curve is unlearned (or stale —
        # drift re-entered calibration) learns next; one tier at a time so
        # learning-node isolation is per-device, not cluster-wide
        for tier in tiers:
            key = self._tuner_key(sig, tier)
            tuner = self.tuners.get(key)
            if tuner is None or tuner.learning():
                return self._learning_grant(task, key, tier)
        # phase 2: every tier measured — argmin of backlog completion time
        n = max(1, self.n_ready_of(self._sig_key(task)))
        ranked = []
        for ti, tier in enumerate(tiers):
            key = self._tuner_key(sig, tier)
            tuner = self.tuners[key]
            c = tuner.peek_choice(n)
            t_est = tuner.objective_time(n, c) \
                + self._eviction_price(tier, task.sim.io_bytes)
            ranked.append((t_est, ti, c, tier, key, tuner))
        ranked.sort(key=lambda r: (r[0], r[1]))  # ties: faster tier wins
        # re-probe: a tier the argmin abandons stops producing observations,
        # so a stale-pessimistic curve could lock it out even after its
        # co-tenant leaves. With drift monitoring on, every Nth steady grant
        # goes to the runner-up instead — a deterministic exploration beat
        # that keeps every arm's curve fresh enough to drift back. The beat
        # counts *grants*, not attempts: a blocked class head is retried on
        # every round, and burning beats on failures would starve the probe
        # exactly when congestion makes it matter.
        if self.drift_config is not None and len(ranked) > 1 and \
                (self._probe_counts.get(sig, 0) + 1) \
                % self.drift_config.probe_every == 0:
            ranked[0], ranked[1] = ranked[1], ranked[0]
        for _, _, c, tier, key, tuner in ranked:
            if self._steady_grant(task, key, tier, tuner, c):
                if self.drift_config is not None:
                    self._probe_counts[sig] = \
                        self._probe_counts.get(sig, 0) + 1
                return True
        return False

    def _eviction_price(self, tier: str, io_mb: float) -> float:
        """The drain cost a write of ``io_mb`` to ``tier`` would force: if
        the projected occupancy of the tier's representative device crosses
        its high watermark, the spill back down to the low watermark is a
        cross-tier move to the durable tier — time the objective must pay
        for choosing this tier. Zero without the lifecycle subsystem (no
        finite capacity means no eviction ever)."""
        if self.catalog is None or io_mb <= 0:
            return 0.0
        dev = self.cluster.tier_spec(tier)
        if dev is None or dev.capacity_mb is None:
            return 0.0
        durable = self.catalog.durable_tier
        if durable is None or durable == tier:
            return 0.0
        dst = self.cluster.tier_spec(durable)
        if dst is None:
            return 0.0
        hi, lo = self.catalog.watermarks(dev)
        cap = dev.capacity_mb
        projected = dev.occupancy_mb + io_mb
        if projected <= hi * cap:
            return 0.0
        spill = projected - lo * cap
        return cross_tier_time(dev, dst, spill)

    def _io_candidates(self, task: TaskInstance):
        # shared working directory -> first candidate node (paper §4.2.1);
        # otherwise honour data locality (inputs' producing workers first).
        if self.cluster.shared_workdir:
            return self.cluster.workers
        pref, pref_ids = [], set()
        for a in list(task.args) + list(task.kwargs.values()):
            if isinstance(a, Future) and a.task.worker is not None:
                w = a.task.worker
                if id(w) not in pref_ids:  # O(1) membership (seed: list `in`)
                    pref_ids.add(id(w))
                    pref.append(w)
        rest = [w for w in self.cluster.workers if id(w) not in pref_ids]
        return pref + rest

    def _start(self, task: TaskInstance, worker: WorkerNode, bw: float,
               device=None) -> None:
        task.worker = worker
        task.device = device
        task.granted_bw = bw
        task.state = TaskState.RUNNING
        if self.catalog is not None:
            # read penalty snapshot: inputs are charged from their fastest
            # resident tier as of this grant (must precede backend.launch,
            # which bakes the penalty into the task's finish estimate)
            self.catalog.on_grant(task)
        self.running.add(task.tid)
        self.launch_log.append((task.tid, task.defn.signature, worker.name))
        self._launch(task, worker)

    # -------------------------------------------------------------- completion
    def on_complete(self, task: TaskInstance) -> None:
        """Release resources + autotune bookkeeping. The backend/runtime is
        responsible for graph completion and follow-up scheduling."""
        self.running.discard(task.tid)
        w = task.worker
        if task.defn.task_type == TaskType.COMPUTE:
            w.free_cpus += task.defn.computing_units
        else:
            w.free_io_executors += 1
            dev = task.device or w.storage
            dev.release(task.granted_bw)
            if self.shard_lease is not None and task.granted_bw > 0:
                self.shard_lease.release(self.shard_id, dev, task.granted_bw)
            if task.reserved_mb:
                # commit-at-finish: the written bytes become resident data;
                # a failed writer's reservation is returned instead
                if task.state == TaskState.FAILED:
                    dev.cancel_reservation(task.reserved_mb)
                else:
                    dev.commit_capacity(task.reserved_mb)
        # the duration the tuner/drift feedback sees: the RealBackend
        # records the final successful attempt's wall time on the task
        # (measured_duration) — task.duration there also counts pool
        # queueing, argument resolution and failed attempts' backoff, which
        # would poison the learned T(n, c) curve. Sim tasks never set it,
        # so the modelled duration feeds through bit-identically.
        dur = task.measured_duration
        if dur is None:
            dur = task.duration
        if task.epoch is not None:
            # the grant recorded which (signature, tier) tuner admitted it —
            # under the cross-tier objective a tier-agnostic task may have
            # calibrated any tier's curve (fallback: recompute, for A/B
            # scheduler shims that predate tuner_key)
            key = task.tuner_key or self._tuner_key(
                task.defn.signature, task.tier)
            tuner = self.tuners[key]
            tuner.on_task_complete(dur)
            if not tuner.learning():
                self._release_learning_node(key)
        elif self.drift_config is not None and task.tuner_key is not None:
            # steady-phase drift feedback: compare the observed task time
            # against the learned curve; the tuner may re-enter calibration
            tuner = self.tuners.get(task.tuner_key)
            if tuner is not None:
                tuner.observe(task.granted_bw, dur)
        self.completed.append(task)
        self._dirty = True  # a resource was freed (and maybe an epoch advanced)

    def on_retry(self, task: TaskInstance) -> None:
        """Release a failed attempt's resources *without* the completion
        bookkeeping (no ``completed`` entry, no tuner feedback — the task
        is not done, it will be re-granted). Mirrors ``on_complete``'s
        resource side: executors, bandwidth, and the capacity reservation
        all return; a learning-epoch membership is un-admitted so the epoch
        can still conclude."""
        self.running.discard(task.tid)
        w = task.worker
        if task.defn.task_type == TaskType.COMPUTE:
            w.free_cpus += task.defn.computing_units
        else:
            w.free_io_executors += 1
            dev = task.device or w.storage
            dev.release(task.granted_bw)
            if self.shard_lease is not None and task.granted_bw > 0:
                self.shard_lease.release(self.shard_id, dev, task.granted_bw)
            if task.reserved_mb:
                dev.cancel_reservation(task.reserved_mb)
        if task.epoch is not None:
            # the attempt never completes, so its admission must not leave
            # the epoch waiting forever on completed >= admitted
            task.epoch.admitted -= 1
            key = task.tuner_key or self._tuner_key(
                task.defn.signature, task.tier)
            tuner = self.tuners.get(key)
            if tuner is not None and tuner.epoch is task.epoch \
                    and tuner.learning() and task.epoch.done():
                # the un-admit concluded the current epoch (its other
                # members all finished): advance as a completion would have
                tuner._advance()
                if not tuner.learning():
                    self._release_learning_node(key)
        self._dirty = True

    def end_of_stream(self) -> None:
        """Signal that no more tasks will be submitted (final barrier):
        lets partially-filled learning epochs conclude."""
        for key, tuner in self.tuners.items():
            if tuner.learning():
                tuner.end_of_stream()
                self._dirty = True
                if not tuner.learning():
                    self._release_learning_node(key)

    # ---------------------------------------------------------------- sanity
    def assert_not_stuck(self) -> None:
        if self._ready_count and not self.running:
            # one legitimate transient: an auto task waiting for a learning
            # node held by a tuner whose epoch is waiting for more arrivals.
            self.end_of_stream()
            self._dirty = True
            if self.schedule_pass() == 0 and self._ready_count \
                    and not self.running:
                names = [t.defn.name for t in self.ready[:5]]
                raise SchedulerError(
                    f"scheduler stuck: {self._ready_count} ready tasks "
                    f"(e.g. {names}) but nothing running/placeable")
