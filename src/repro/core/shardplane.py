"""Sharded scheduler control plane: scale-out beyond one scheduler state.

A single :class:`~repro.core.scheduler.Scheduler` owns one set of ready
deques, placement classes, tuners and (in the simulator) one event heap.
That design is simple and bit-reproducible, but every scheduling decision
walks data structures whose size grows with the whole cluster. This module
partitions the cluster's workers into **shards** — contiguous worker
blocks, each owned by an ordinary sub-``Scheduler`` over a sub-``Cluster``
view — and composes them behind :class:`ShardedScheduler`, a facade that
speaks the exact external scheduler interface the runtime drives.

Design contract (see docs/scale.md for the full write-up):

* **Placement confinement** — a task is owned by exactly one shard
  (``task.shard``) and only ever placed on that shard's workers. Routing
  happens once, at submission (:meth:`ShardedScheduler.route`): an explicit
  ``shard_key=`` call-time anchor wins, else the task inherits its first
  Future input's producer shard (data locality), else deterministic
  round-robin over *workers* (not shards, so the anchor a task gets does
  not depend on the shard count).
* **Global-order rounds** — one scheduling round pops class heads from a
  single heap over *all* shards' placement classes, ordered by the shared
  global readiness sequence. With one shard this is literally the plain
  scheduler's round; with N shards the merged launch log is deterministic
  and, for workloads whose placement is shard-symmetric, identical across
  shard counts.
* **Message-passing boundary** — cross-shard effects travel as ordered
  :class:`ShardBus` messages: dependency-completion readiness
  (``DEP_DONE``/``DEP_FAILED``), catalog residency updates
  (``RESIDENCY_ADD``/``RESIDENCY_DROP``) and lease movements
  (``LEASE_GRANT``/``LEASE_RELEASE``). The bus assigns each message a
  global sequence number and delivers in that order. Consistency contract:
  because shards share one address space, state mutations are applied
  synchronously (never partially) and the bus drain at every readiness
  batch and at ``schedule_pass`` entry guarantees any posted update is
  visible before the next scheduling decision of the same virtual instant.
* **Shared tiers are leased** — devices referenced by workers of two or
  more shards (the burst buffer and shared FS of ``Cluster.make_tiered``)
  are the only cross-shard resource. A :class:`LeaseBroker` splits each
  shared device's bandwidth budget evenly into per-shard lease accounts;
  a grant that exceeds the shard's lease pulls unused grant from the other
  shards in deterministic shard order (on-demand rebalancing). Because
  rebalancing can always gather the device's full free budget, the broker
  never refuses a grant the device itself could satisfy — leases change
  accounting and observability, not placement — and the over-commit
  invariant (``used <= granted`` per shard, ``sum(granted) == budget`` per
  device) is machine-checkable at any instant.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, Optional

from .graph import iter_futures
from .resources import Cluster, StorageDevice
from .scheduler import Scheduler, SchedulerError
from .task import TaskInstance, TaskState

# ---------------------------------------------------------------------------
# Message kinds (stable API: docs/scale.md and the shard tests key on these)
# ---------------------------------------------------------------------------
MSG_DEP_DONE = "DEP_DONE"            # dependency satisfied -> task ready
MSG_DEP_FAILED = "DEP_FAILED"        # failure fan-out unblocked an anti-dep
MSG_RESIDENCY_ADD = "RESIDENCY_ADD"  # catalog: object copy appeared on a tier
MSG_RESIDENCY_DROP = "RESIDENCY_DROP"
MSG_LEASE_GRANT = "LEASE_GRANT"      # broker: bandwidth drawn from a lease
MSG_LEASE_RELEASE = "LEASE_RELEASE"  # broker: bandwidth returned / rebalanced

MESSAGE_KINDS = (MSG_DEP_DONE, MSG_DEP_FAILED, MSG_RESIDENCY_ADD,
                 MSG_RESIDENCY_DROP, MSG_LEASE_GRANT, MSG_LEASE_RELEASE)

#: readiness kinds — the only ones whose delivery calls into a sub-scheduler
_READY_KINDS = (MSG_DEP_DONE, MSG_DEP_FAILED)

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Routing (pure functions — shared with the static analyzer, repro.analysis)
# ---------------------------------------------------------------------------
def shard_of_worker(widx: int, n_workers: int, n_shards: int) -> int:
    """The shard owning worker index ``widx`` under a contiguous fair
    partition of ``n_workers`` workers into ``n_shards`` blocks."""
    return widx * n_shards // n_workers


def shard_workers(shard: int, n_workers: int, n_shards: int) -> range:
    """Worker indices owned by ``shard`` (exact inverse of
    :func:`shard_of_worker`): ``w`` is owned by ``s`` iff ``s <= w *
    n_shards / n_workers < s + 1``, i.e. ``w`` in ``[ceil(s * n_workers /
    n_shards), ceil((s + 1) * n_workers / n_shards))``."""
    return range(-(-shard * n_workers // n_shards),
                 -(-(shard + 1) * n_workers // n_shards))


def anchor_worker(shard_key: int, n_workers: int) -> int:
    """The worker index an explicit ``shard_key=`` anchors to. Independent
    of the shard count: the same key always lands on the same worker, and
    :func:`shard_of_worker` then maps that worker to its owner — so a key
    that co-locates two tasks at one shard count co-locates them at every
    shard count that keeps their anchor workers in one block."""
    return int(shard_key) % n_workers


def partition_cluster(cluster: Cluster, n_shards: int) -> list[Cluster]:
    """Contiguous sub-``Cluster`` views, one per shard. Worker and device
    objects are *shared* with the parent cluster (views, not copies):
    resource accounting stays global, which is what makes shared tiers a
    real cross-shard resource and shard-private tiers naturally confined."""
    n_workers = len(cluster.workers)
    if not 1 <= n_shards <= n_workers:
        raise ValueError(
            f"n_shards must be in [1, n_workers={n_workers}], "
            f"got {n_shards}")
    return [Cluster(workers=[cluster.workers[i]
                             for i in shard_workers(s, n_workers, n_shards)],
                    shared_workdir=cluster.shared_workdir)
            for s in range(n_shards)]


def shared_devices(cluster: Cluster, n_shards: int) -> list[StorageDevice]:
    """Devices referenced by workers of two or more shards — the lease
    broker's domain. On ``Cluster.make_tiered`` these are the burst buffer
    and the shared FS; per-worker SSDs never qualify."""
    n_workers = len(cluster.workers)
    owners: dict[int, set[int]] = {}
    order: list[StorageDevice] = []
    for widx, w in enumerate(cluster.workers):
        s = shard_of_worker(widx, n_workers, n_shards)
        for dev in w.tiers:
            if id(dev) not in owners:
                owners[id(dev)] = set()
                order.append(dev)
            owners[id(dev)].add(s)
    return [d for d in order if len(owners[id(d)]) > 1]


# ---------------------------------------------------------------------------
# Bus: the ordered cross-shard message boundary
# ---------------------------------------------------------------------------
class ShardBus:
    """Ordered message channel between shards.

    Every cross-shard-visible effect is posted as a message carrying a
    global sequence number; :meth:`drain` delivers pending messages in
    sequence order through the deliver callback (readiness kinds) and
    retains per-kind / cross-vs-local counters for all of them. ``dst`` is
    a shard index, or ``None`` for broadcast state (residency updates every
    shard may read).
    """

    def __init__(self, n_shards: int,
                 deliver: Optional[Callable] = None):
        self.n_shards = n_shards
        self._deliver = deliver
        self._seq = itertools.count()
        self._pending: deque = deque()
        self.counters: dict[str, int] = {k: 0 for k in MESSAGE_KINDS}
        self.cross = 0       # src != dst (or broadcast): crossed the boundary
        self.local = 0       # src == dst: same-shard delivery
        self.delivered = 0

    def post(self, kind: str, src: int, dst: Optional[int],
             payload=None) -> int:
        """Enqueue a message; returns its global sequence number."""
        seq = next(self._seq)
        self.counters[kind] += 1
        if dst is None or src != dst:
            self.cross += 1
        else:
            self.local += 1
        self._pending.append((seq, kind, src, dst, payload))
        return seq

    def drain(self) -> int:
        """Deliver every pending message in sequence order. Returns the
        number delivered. Reentrancy-safe: a delivery that posts new
        messages extends the same drain (they still deliver in order)."""
        n = 0
        pending = self._pending
        deliver = self._deliver
        while pending:
            msg = pending.popleft()
            self.delivered += 1
            n += 1
            if deliver is not None and msg[1] in _READY_KINDS:
                deliver(msg)
        return n

    def summary(self) -> dict:
        return {"kinds": dict(self.counters), "cross": self.cross,
                "local": self.local, "delivered": self.delivered,
                "pending": len(self._pending)}


# ---------------------------------------------------------------------------
# Lease broker: per-shard quota accounts over shared devices
# ---------------------------------------------------------------------------
class LeaseAccount:
    """One shard's bandwidth lease on one shared device."""

    __slots__ = ("granted", "used")

    def __init__(self, granted: float):
        self.granted = granted   # MB/s this shard may allocate autonomously
        self.used = 0.0          # MB/s currently allocated under the lease


class LeaseBroker:
    """Per-shard bandwidth quota accounts over the shared devices.

    Each shared device's budget is split evenly at construction. A grant
    first spends the shard's own headroom; when that is short, unused grant
    is pulled from the other shards in deterministic shard order (smallest
    index first) until the need is covered — so any allocation the device
    itself could satisfy is also lease-satisfiable, and placement under
    leases is identical to placement without them. Devices the broker does
    not track (shard-private tiers) are granted trivially.

    Invariants (:meth:`check_invariants`): per shard ``0 <= used <=
    granted + eps``; per device ``sum(granted) == budget``. The property
    tests sample these at every completion of a sharded run.
    """

    def __init__(self, devices: list[StorageDevice], n_shards: int,
                 bus: Optional[ShardBus] = None):
        self.n_shards = n_shards
        self.bus = bus
        self._accounts: dict[int, tuple[StorageDevice, list[LeaseAccount]]] \
            = {}
        for dev in devices:
            share = dev.bandwidth / n_shards
            accounts = [LeaseAccount(share) for _ in range(n_shards)]
            # float-exact budget conservation: park the rounding remainder
            # on shard 0 so sum(granted) == budget bit-for-bit
            accounts[0].granted += dev.bandwidth - share * n_shards
            self._accounts[id(dev)] = (dev, accounts)
        self.grants = 0
        self.rebalances = 0
        self.denials = 0

    def tracks(self, dev: StorageDevice) -> bool:
        return id(dev) in self._accounts

    def acquire(self, shard: int, dev: StorageDevice, bw: float) -> bool:
        """Draw ``bw`` MB/s from ``shard``'s lease on ``dev`` (rebalancing
        on demand). True on success; untracked devices always succeed."""
        entry = self._accounts.get(id(dev))
        if entry is None or bw <= 0:
            return True
        accounts = entry[1]
        acct = accounts[shard]
        if acct.used + bw > acct.granted + _EPS:
            # pull unused grant from the other shards, shard order — the
            # deterministic rebalance; always covers the need when the
            # device has global headroom (the grant path checked
            # can_allocate first, so a shortfall here means a real bug)
            need = bw - (acct.granted - acct.used)
            for i in range(self.n_shards):
                if need <= _EPS:
                    break
                if i == shard:
                    continue
                other = accounts[i]
                spare = other.granted - other.used
                if spare <= _EPS:
                    continue
                take = min(spare, need)
                other.granted -= take
                acct.granted += take
                need -= take
                self.rebalances += 1
                if self.bus is not None:
                    self.bus.post(MSG_LEASE_RELEASE, i, shard,
                                  (dev.name, take))
            if acct.used + bw > acct.granted + _EPS:
                self.denials += 1
                return False
        acct.used += bw
        self.grants += 1
        if self.bus is not None:
            self.bus.post(MSG_LEASE_GRANT, shard, shard, (dev.name, bw))
        return True

    def release(self, shard: int, dev: StorageDevice, bw: float) -> None:
        entry = self._accounts.get(id(dev))
        if entry is None or bw <= 0:
            return
        acct = entry[1][shard]
        acct.used -= bw
        if acct.used < -1e-6:
            raise RuntimeError(
                f"lease accounting underflow: shard {shard} released "
                f"{bw:g} MB/s on {dev.name} it never acquired")
        if self.bus is not None:
            self.bus.post(MSG_LEASE_RELEASE, shard, shard, (dev.name, bw))

    def check_invariants(self) -> list[str]:
        """Human-readable violations; empty when consistent."""
        out = []
        for dev, accounts in self._accounts.values():
            total_granted = sum(a.granted for a in accounts)
            if abs(total_granted - dev.bandwidth) > 1e-6:
                out.append(
                    f"{dev.name}: leases sum to {total_granted:.6f} MB/s, "
                    f"budget is {dev.bandwidth:g}")
            for s, a in enumerate(accounts):
                if a.used < -1e-6:
                    out.append(f"{dev.name}: shard {s} used negative "
                               f"({a.used:.6f})")
                if a.used > a.granted + 1e-6:
                    out.append(
                        f"{dev.name}: shard {s} over-committed its lease "
                        f"(used={a.used:.6f} > granted={a.granted:.6f})")
        return out

    def summary(self) -> dict:
        devs = {}
        for dev, accounts in self._accounts.values():
            devs[dev.name] = {
                "budget": dev.bandwidth,
                "per_shard": [{"granted": a.granted, "used": a.used}
                              for a in accounts]}
        return {"grants": self.grants, "rebalances": self.rebalances,
                "denials": self.denials, "devices": devs}


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------
class ShardedScheduler:
    """N ordinary sub-schedulers behind the external scheduler interface.

    Construction splits the cluster into contiguous worker blocks (device
    objects shared, accounting global), gives every sub-scheduler the SAME
    readiness counter, launch log, completed list, running set and
    capacity-demand dict, and wires the lease broker's shard accounts into
    each sub-scheduler's grant path. ``n_shards=1`` is the plain scheduler
    with one extra (empty) bus drain per pass — bit-identical logs.
    """

    def __init__(self, cluster: Cluster,
                 launch: Callable,
                 n_shards: int,
                 scheduler_cls=Scheduler):
        self.cluster = cluster
        self.n_shards = n_shards
        self.n_workers = len(cluster.workers)
        self.bus = ShardBus(n_shards, deliver=self._deliver)
        self.broker = LeaseBroker(shared_devices(cluster, n_shards),
                                  n_shards, bus=self.bus)
        self.shards: list[Scheduler] = [
            scheduler_cls(sub, launch)
            for sub in partition_cluster(cluster, n_shards)]
        # shared identity state: ONE readiness order, ONE launch log, ONE
        # completion stream — the merged views the runtime/backend consume
        # are the primary structures, not reconciled copies
        shared_seq = itertools.count()
        self.launch_log: list = []
        self.completed: list = []
        self.running: set = set()
        self.capacity_blocked: dict = {}
        for i, s in enumerate(self.shards):
            s._ready_seq = shared_seq
            s.launch_log = self.launch_log
            s.completed = self.completed
            s.running = self.running
            s.capacity_blocked = self.capacity_blocked
            s.shard_id = i
            s.shard_lease = self.broker
        self._rr = itertools.count()     # worker round-robin (routing)
        self._fanout_src = 0             # shard of the last completed task
        self._fanout_failed = False

    # ------------------------------------------------------------- routing
    def route(self, task: TaskInstance) -> int:
        """Owning shard for ``task`` (called once, at submission): explicit
        ``shard_key=`` anchor, else first Future input's producer shard,
        else round-robin over workers."""
        key = task.shard_key
        if key is not None:
            return shard_of_worker(anchor_worker(key, self.n_workers),
                                   self.n_workers, self.n_shards)
        for a in task.args:
            for fut in iter_futures(a):
                return fut.task.shard
        for a in task.kwargs.values():
            for fut in iter_futures(a):
                return fut.task.shard
        widx = next(self._rr) % self.n_workers
        return shard_of_worker(widx, self.n_workers, self.n_shards)

    # ----------------------------------------------------------- readiness
    def _deliver(self, msg) -> None:
        task = msg[4]
        self.shards[task.shard].make_ready(task)

    def make_ready(self, task: TaskInstance) -> None:
        """Readiness at submission or retry re-queue: the message
        originates at the task's own shard (no dependency edge crossed)."""
        self.bus.post(MSG_DEP_DONE, task.shard, task.shard, task)
        self.bus.drain()

    def make_ready_many(self, tasks) -> None:
        """Completion fan-out: newly-ready children, in submission order.
        Each message's source is the shard of the task whose completion
        (or failure) satisfied the last dependency — posted as a batch,
        then drained, so delivery order matches the unsharded scheduler's
        batch order exactly."""
        kind = MSG_DEP_FAILED if self._fanout_failed else MSG_DEP_DONE
        src = self._fanout_src
        for t in tasks:
            self.bus.post(kind, src, t.shard, t)
        self.bus.drain()

    # ---------------------------------------------------------- scheduling
    def schedule_pass(self) -> int:
        self.bus.drain()   # any posted update is visible before decisions
        shards = self.shards
        if not any(s._dirty for s in shards):
            return 0
        launched = 0
        while True:
            n = self._round()
            launched += n
            if n == 0:
                break
        for s in shards:
            s._dirty = False
        return launched

    def _round(self) -> int:
        """One global-order round: a single heap over every shard's class
        heads, keyed by the shared readiness sequence — the exact attempt
        order the unsharded scheduler's round uses, with each attempt
        confined to the owning shard's workers."""
        heads = [(q[0]._ready_seq, i, key)
                 for i, s in enumerate(self.shards) if s._ready_count
                 for key, q in s._ready_q.items() if q]
        heapq.heapify(heads)
        launched = 0
        while heads:
            _, i, key = heapq.heappop(heads)
            s = self.shards[i]
            if s._attempt_head(key):
                launched += 1
                q = s._ready_q.get(key)
                if q:
                    heapq.heappush(heads, (q[0]._ready_seq, i, key))
        return launched

    # ---------------------------------------------------------- completion
    def on_complete(self, task: TaskInstance) -> None:
        self._fanout_src = task.shard
        self._fanout_failed = task.state == TaskState.FAILED
        self.shards[task.shard].on_complete(task)

    def on_retry(self, task: TaskInstance) -> None:
        self.shards[task.shard].on_retry(task)

    def end_of_stream(self) -> None:
        for s in self.shards:
            s.end_of_stream()

    def assert_not_stuck(self) -> None:
        if self.n_ready and not self.running:
            self.end_of_stream()
            self._dirty = True
            if self.schedule_pass() == 0 and self.n_ready \
                    and not self.running:
                names = [t.defn.name for t in self.ready[:5]]
                raise SchedulerError(
                    f"scheduler stuck: {self.n_ready} ready tasks "
                    f"(e.g. {names}) across {self.n_shards} shards but "
                    f"nothing running/placeable")

    # ------------------------------------------------------------- wiring
    def validate_submit(self, task: TaskInstance) -> None:
        # validated against the owning shard's sub-cluster: confinement
        # means a class its shard can never satisfy IS unsatisfiable for
        # this task, even if another shard's workers could take it
        self.shards[task.shard].validate_submit(task)

    def set_tuning(self, drift=None, tier_objective: bool = False) -> None:
        for s in self.shards:
            s.set_tuning(drift=drift, tier_objective=tier_objective)

    def set_recorder(self, recorder) -> None:
        # one recorder, every shard: sub-scheduler events interleave in
        # call order, which the global-order round makes deterministic —
        # the merged stream needs no post-hoc reconciliation
        for s in self.shards:
            s.set_recorder(recorder)

    def set_catalog(self, catalog) -> None:
        catalog.shardbus = self.bus
        for s in self.shards:
            s.set_catalog(catalog)

    # ------------------------------------------------------- merged views
    @property
    def _dirty(self) -> bool:
        return any(s._dirty for s in self.shards)

    @_dirty.setter
    def _dirty(self, value: bool) -> None:
        for s in self.shards:
            s._dirty = value

    @property
    def recorder(self):
        return self.shards[0].recorder

    @property
    def catalog(self):
        return self.shards[0].catalog

    @property
    def n_ready(self) -> int:
        return sum(s._ready_count for s in self.shards)

    def n_ready_of(self, sig: str) -> int:
        return sum(s.n_ready_of(sig) for s in self.shards)

    @property
    def ready(self) -> list:
        tasks = [t for s in self.shards for q in s._ready_q.values()
                 for t in q]
        tasks.sort(key=lambda t: t._ready_seq)
        return tasks

    @property
    def tuners(self) -> dict:
        """Merged tuner view: plain keys with one shard (drop-in for the
        unsharded scheduler), ``key#s<i>`` suffixes otherwise (two shards
        may each calibrate the same signature independently)."""
        if self.n_shards == 1:
            return self.shards[0].tuners
        out = {}
        for i, s in enumerate(self.shards):
            for key, tuner in s.tuners.items():
                out[f"{key}#s{i}"] = tuner
        return out

    def summary(self) -> dict:
        """Control-plane rollup for ``rt.stats()["shards"]``."""
        per_shard = []
        for i, s in enumerate(self.shards):
            per_shard.append({
                "workers": [w.name for w in s.cluster.workers],
                "n_launched": sum(1 for t in self.completed
                                  if t.shard == i),
                "n_ready": s._ready_count,
                "n_tuners": len(s.tuners),
            })
        return {"n_shards": self.n_shards, "per_shard": per_shard,
                "bus": self.bus.summary(), "leases": self.broker.summary(),
                "lease_violations": self.broker.check_invariants()}
