"""Analytical storage congestion model (DESIGN.md §4).

Calibrated so the simulator reproduces the paper's MareNostrum-4 measurements:

* aggregate achieved throughput for ``k`` concurrent fsync'd writers on one
  device ramps linearly (per-stream cap ``s``) until it saturates the device
  bandwidth ``B`` at the knee ``k* = B/s``, then degrades with congestion:

      A(k) = min(k*s, B) / (1 + alpha * max(0, k - k*))

* per-task rate under fair sharing is A(k)/k.

With the paper's numbers (B=450 MB/s node-local SSD, 225 I/O executors) this
yields: unbounded learning phase 2->4->8->16 stopping after the 4th epoch,
objective choosing constraint 8, throughput peaking at constraint 8, and
non-constrained runs slower than the baseline — matching Figs. 10-12.
"""
from __future__ import annotations

from .resources import StorageDevice


def aggregate_throughput(device: StorageDevice, k: int) -> float:
    """Achieved aggregate MB/s with k concurrent streams on ``device``."""
    if k <= 0:
        return 0.0
    ramp = min(k * device.per_stream_cap, device.bandwidth)
    # degraded health scales what the hardware can deliver; the guard keeps
    # healthy-path arithmetic (and golden launch logs) byte-identical
    f = device.bw_factor
    if f != 1.0:
        ramp *= f
    over = max(0, k - device.congestion_knee)
    pen = device.congestion_alpha * over + device.congestion_beta * over * over
    return ramp / (1.0 + pen)


def per_task_rate(device: StorageDevice, k: int) -> float:
    """Fair-share MB/s each of k concurrent streams achieves.

    Memoized per (device, k): the curve depends only on the device's
    calibration and health, both of which invalidate the cache when they
    change (``StorageDevice.invalidate_rates``), so the cached float is
    always the exact value the open-form arithmetic would produce — the
    simulator's golden launch logs cannot tell the difference. On the
    100k-task benchmark this call dominates the event loop (~1.7M calls
    over ~40 distinct k values per device)."""
    if k <= 0:
        return 0.0
    cache = device._rate_cache
    r = cache.get(k)
    if r is None:
        r = cache[k] = aggregate_throughput(device, k) / k
    return r


def expected_task_time(device: StorageDevice, k: int, io_mb: float) -> float:
    """Time for one of k concurrent tasks writing io_mb (steady state)."""
    r = per_task_rate(device, k)
    return float("inf") if r <= 0 else io_mb / r


def max_concurrent_tasks(device_bw: float, constraint: float) -> int:
    """maxNumTasks_c (paper §3.3.2): floor(device bandwidth / constraint)."""
    return max(1, int(device_bw // constraint))


# --------------------------------------------------------------------------
# Cross-tier transfers (multi-tier hierarchy: SSD -> burst buffer -> FS)
# --------------------------------------------------------------------------
def read_floor_time(src: StorageDevice, mb: float) -> float:
    """Lower bound on reading ``mb`` from ``src``: a single sequential
    reader streams at most at the device bandwidth. Used as the ``min_end``
    floor of runtime-generated drain/prefetch tasks — the *write* side is
    what the simulator models dynamically (the task is placed on the
    destination tier, so it sees that device's congestion) — and as the
    data-lifecycle read penalty: the catalog charges consumers this floor
    for inputs pulled from their fastest resident tier (datalife.py), which
    is what auto-prefetch staging shrinks."""
    if mb <= 0:
        return 0.0
    return mb / src.bandwidth if src.bandwidth > 0 else float("inf")


def cross_tier_time(src: StorageDevice, dst: StorageDevice, mb: float,
                    k: int = 1) -> float:
    """Analytic estimate of moving ``mb`` from ``src`` to ``dst`` as one of
    ``k`` concurrent movers: the slower of the source read floor and the
    destination fair-share write time. The simulator reproduces this shape
    dynamically; this closed form serves sizing/benchmark analysis."""
    return max(read_floor_time(src, mb), expected_task_time(dst, k, mb))
