"""Task model for the I/O-aware runtime.

Mirrors PyCOMPSs semantics (paper §4.1.1): functions become tasks via
decorators; parameter directionality (IN/INOUT/OUT) drives dependency
detection; tasks return Futures; ``@io`` marks a task as an I/O task whose
*computing* requirement is zero (paper §4.2.1) so it is scheduled on the I/O
execution platform and may overlap with compute tasks.
"""
from __future__ import annotations

import enum
import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .constraints import ConstraintSpec


class Direction(enum.Enum):
    IN = "in"
    INOUT = "inout"
    OUT = "out"


IN = Direction.IN
INOUT = Direction.INOUT
OUT = Direction.OUT


class TaskType(enum.Enum):
    COMPUTE = "compute"
    IO = "io"


class TaskState(enum.Enum):
    PENDING = "pending"      # submitted, deps not satisfied
    READY = "ready"          # deps satisfied, waiting for resources
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclass
class TaskDef:
    """Static definition attached to a decorated function."""

    fn: Callable
    name: str
    task_type: TaskType = TaskType.COMPUTE
    computing_units: int = 1
    storage_bw: Optional[ConstraintSpec] = None
    storage_tier: Optional[str] = None  # tier hint (None: fastest-with-budget)
    param_dirs: dict = field(default_factory=dict)  # name -> Direction
    returns: int = 0
    max_retries: int = 0  # I/O fault tolerance: bounded retries

    @property
    def signature(self) -> str:
        return self.name


class Future:
    """Future returned by a task invocation (one per declared return)."""

    __slots__ = ("task", "index", "_value", "_set")

    def __init__(self, task: "TaskInstance", index: int = 0):
        self.task = task
        self.index = index
        self._value = None
        self._set = False

    def set_value(self, value: Any) -> None:
        self._value = value
        self._set = True

    def resolved(self) -> bool:
        return self._set

    def value(self) -> Any:
        return self._value

    def __repr__(self) -> str:
        return f"<Future {self.task.defn.name}#{self.task.tid}[{self.index}]>"


class DataHandle:
    """Mutable datum tracked with versions (COMPSs renaming).

    Pass a DataHandle to an INOUT/OUT parameter to get write-after-read /
    write-after-write serialization.
    """

    _ids = itertools.count()

    def __init__(self, value: Any = None, name: str | None = None):
        self.did = next(DataHandle._ids)
        self.name = name or f"data{self.did}"
        self.value = value
        # dependency bookkeeping (owned by TaskGraph)
        self.last_writer: Optional["TaskInstance"] = None
        self.readers_since_write: list["TaskInstance"] = []
        self.version = 0

    def __repr__(self) -> str:
        return f"<DataHandle {self.name} v{self.version}>"


@dataclass
class SimSpec:
    """Simulation-mode execution model for a task instance."""

    duration: float = 0.0        # compute time, seconds (virtual)
    io_bytes: float = 0.0        # MB to write/read for I/O tasks
    fail: "bool | int" = False   # fault injection: the task FAILs at its
    #                              (normally computed) end time, exercising
    #                              the retry path and, once retries are
    #                              exhausted, descendant cancellation.
    #                              True: every attempt fails; an int N:
    #                              only the first N attempts fail (with
    #                              maxRetries >= N the task succeeds)


class TaskInstance:
    _ids = itertools.count()

    # __slots__: at the 1M-task bench scale (benchmarks/sched_scale.py)
    # the per-instance attribute dict dominates live memory — the launch
    # log keeps every instance alive to the end of the run, and the cache
    # pressure of those dicts is what bends the per-task cost superlinear.
    # _plan_seq is capture-mode-only and deliberately left unset elsewhere
    # (the lint rules read it via getattr-with-default).
    __slots__ = (
        "tid", "defn", "args", "kwargs", "sim", "storage_bw", "tier",
        "state", "deps", "anti_deps", "children", "futures", "worker",
        "device", "granted_bw", "tuner_key", "reserved_mb", "read_penalty",
        "_datalife", "submit_time", "start_time", "end_time",
        "measured_duration", "_telemetry_k", "epoch", "retries", "error",
        "_ready_seq", "_sim_seq", "shard", "shard_key", "_plan_seq")

    def __init__(self, defn: TaskDef, args: tuple, kwargs: dict,
                 sim: SimSpec | None = None,
                 storage_bw: Optional[ConstraintSpec] = None,
                 storage_tier: Optional[str] = None):
        self.tid = next(TaskInstance._ids)
        self.defn = defn
        self.args = args
        self.kwargs = kwargs
        self.sim = sim or SimSpec()
        # per-instance constraint override (else defn.storage_bw)
        self.storage_bw = storage_bw if storage_bw is not None else defn.storage_bw
        # resolved tier hint: per-call override, else the @constraint hint,
        # else None = tier-agnostic (fastest tier with budget wins)
        self.tier = storage_tier if storage_tier is not None else defn.storage_tier
        self.state = TaskState.PENDING
        self.deps: set[int] = set()          # tids this task waits on
        self.anti_deps: set[int] = set()     # subset of deps that are
        #                                      ordering-only (write-after-read)
        self.children: list[int] = []        # dependents, by tid (submission
        #                                      order; resolved via TaskGraph)
        self.futures = [Future(self, i) for i in range(max(defn.returns, 1))]
        # filled by the scheduler/backend
        self.worker = None
        self.device = None                   # StorageDevice the I/O was
        #                                      granted on (a tier of .worker)
        self.granted_bw: float = 0.0         # bandwidth reserved at launch
        self.tuner_key: Optional[str] = None  # the (signature, tier) tuner
        #                                      this grant drew from — under
        #                                      the measured tier objective a
        #                                      tier-agnostic task may be
        #                                      granted on any tier's tuner
        self.reserved_mb: float = 0.0        # capacity reserved at grant on
        #                                      .device (commit-at-finish)
        self.read_penalty: float = 0.0       # simulated input-read floor
        #                                      (datalife catalog, at grant)
        self._datalife = None                # lifecycle mover tag:
        #                                      ("stage"|"evict", obj, ...)
        self.submit_time: float = 0.0
        self.start_time: float = 0.0
        self.end_time: float = 0.0
        self.measured_duration: Optional[float] = None  # wall time of the
        #                                      final successful attempt alone
        #                                      (RealBackend). duration =
        #                                      end-start also counts pool
        #                                      queueing, argument resolution
        #                                      and failed attempts' backoff;
        #                                      the tuner/drift feedback wants
        #                                      the I/O itself. None under the
        #                                      simulator (modelled duration).
        self._telemetry_k: int = 0           # in-flight count on the device
        #                                      at launch (TelemetryHub)
        self.epoch = None                    # learning epoch membership
        self.retries = 0
        self.error: Optional[BaseException] = None
        self._ready_seq = -1                 # global readiness order (scheduler)
        self._sim_seq = -1                   # launch order (sim event queue)
        # sharded control plane (core.shardplane): owning shard and the
        # optional explicit routing anchor (``shard_key=`` call-time kwarg)
        self.shard = 0
        self.shard_key = None

    @property
    def duration(self) -> float:
        return self.end_time - self.start_time

    @property
    def is_io(self) -> bool:
        return self.defn.task_type == TaskType.IO

    def future(self) -> Future:
        return self.futures[0]

    def __repr__(self) -> str:
        return f"<Task {self.defn.name}#{self.tid} {self.state.value}>"


def resolved_future(value: Any = None, name: str = "resolved") -> Future:
    """A Future that is already resolved to ``value``, backed by a DONE
    task that never entered any graph. Used where an operation short-
    circuits (e.g. a drain/prefetch that is already satisfied per the data
    catalog): downstream tasks may depend on it — the DONE producer
    satisfies the edge immediately."""
    inst = TaskInstance(TaskDef(fn=lambda: value, name=name), (), {})
    inst.state = TaskState.DONE
    fut = inst.futures[0]
    fut.set_value(value)
    return fut


class Barrier:
    """Completion latch used by wait_on / runtime barrier (real backend)."""

    def __init__(self):
        self._event = threading.Event()

    def release(self):
        self._event.set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)
