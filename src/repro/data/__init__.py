from .pipeline import PrefetchLoader, SyntheticCorpus
