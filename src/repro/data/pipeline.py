"""Deterministic synthetic corpus + host-sharded loader with background
prefetch through the I/O-aware runtime (reads are I/O tasks, so batch
preparation overlaps the train step — the paper's reading-task case).
"""
from __future__ import annotations

import numpy as np

from ..core import current_runtime, io, task


class SyntheticCorpus:
    """Stateless, reproducible token stream: batch(step) is a pure function
    of (seed, step, host slice) — restart-safe by construction."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_index: int = 0,
                 structured: bool = True, noise: float = 0.1):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.host = host_index
        self.structured = structured  # learnable affine next-token pattern
        self.noise = noise

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host, step]))
        B, S, V = self.local_batch, self.seq + 1, self.vocab
        if not self.structured:
            toks = rng.integers(0, V, size=(B, S), dtype=np.int32)
        else:
            toks = np.empty((B, S), dtype=np.int32)
            toks[:, 0] = rng.integers(0, V, size=B)
            for i in range(1, S):
                toks[:, i] = (toks[:, i - 1] * 31 + 7) % V
            corrupt = rng.random((B, S)) < self.noise
            toks[corrupt] = rng.integers(0, V, size=int(corrupt.sum()))
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


@io
@task(returns=1)
def _fetch_task(corpus, step):
    return corpus.batch(step)


class PrefetchLoader:
    """Issues batch(step+1..step+depth) as I/O tasks while step runs."""

    def __init__(self, corpus: SyntheticCorpus, depth: int = 2):
        self.corpus = corpus
        self.depth = depth
        self._pending: dict[int, object] = {}

    def get(self, step: int) -> dict:
        rt = current_runtime()
        if rt is None:
            return self.corpus.batch(step)
        for s in range(step, step + self.depth + 1):
            if s not in self._pending:
                self._pending[s] = _fetch_task(self.corpus, s)
        fut = self._pending.pop(step)
        return rt.wait_on(fut)
