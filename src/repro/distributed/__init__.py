from .sharding import (LOGICAL_RULES, STRATEGIES, MeshContext, batch_axes,
                       current_mesh, logical_to_sharding, mesh_context,
                       shard_activation, shard_params)
