"""Divisibility-aware logical-axis sharding (DESIGN.md §5).

Params/activations are annotated with *logical axis name* tuples; rules map
logical names to mesh axes. A rule is applied only when the dimension size is
divisible by the product of the mesh-axis sizes — otherwise the dim stays
replicated (this is what lets e.g. smollm's 15 heads lower cleanly on a
16-way "model" axis: its attention weights simply replicate).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes). None -> replicate.
LOGICAL_RULES: dict[str, object] = {
    "embed": "data",        # FSDP: weights stored sharded over data;
    #                         SPMD all-gathers one layer at a time inside scan
    "mlp": "model",         # TP
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": None,        # expert count (8/60) rarely divisible; TP via mlp
    "layers": None,
    "head_dim": None,
    "norm": None,
    "state": None,
    "conv": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
}


# Named sharding strategies (perf iterations, EXPERIMENTS.md §Perf).
# "tp_fsdp": TP over "model" + FSDP weight storage over "data" (default).
# "fsdp":    no tensor parallelism — batch shards over every mesh axis and
#            weights are fully sharded for storage with per-layer all-gather
#            (ZeRO-3). Kills all per-layer activation collectives; the right
#            regime whenever batch >= chips and the layer fits one chip.
STRATEGIES: dict[str, dict] = {
    "tp_fsdp": dict(LOGICAL_RULES),
    "fsdp": {**LOGICAL_RULES,
             "embed": ("data", "model"),
             "mlp": None, "heads": None, "kv_heads": None, "vocab": None,
             "batch": ("pod", "data", "model")},
    # dp_fsdp (perf iteration 4): no tensor parallelism; weights FSDP over
    # "data" only (replicated over "model" so XLA keeps grad reduction a
    # clean AR(model)+RS(data) instead of the in-loop full-grad ARs the
    # 2-D weight sharding provokes), batch over every axis, optimizer state
    # sharded 2-D separately (OPT_RULES).
    "dp_fsdp": {**LOGICAL_RULES,
                "embed": ("data",),
                "mlp": None, "heads": None, "kv_heads": None, "vocab": None,
                "batch": ("pod", "data", "model")},
    # tp_serve (perf iteration 5, decode cells): weight-stationary serving —
    # pure TP over "model", NO FSDP storage sharding, so a decode step never
    # all-gathers weights; batch over (pod, data); KV caches shard over
    # heads/batch. Right when the TP-sharded model fits chip memory.
    "tp_serve": {**LOGICAL_RULES, "embed": None},
    # dp_tp_moe (perf iteration 6, MoE trainers): dense parts pure-DP/FSDP
    # like dp_fsdp, but expert FFNs keep TP over "model" (the per-expert
    # d_ff shards) because expert weights dominate parameters and cannot
    # replicate; batch over (pod, data) only.
    "dp_tp_moe": {**LOGICAL_RULES,
                  "embed": ("data",), "heads": None, "kv_heads": None,
                  "vocab": None, "mlp": "model",
                  "batch": ("pod", "data")},
}

# optimizer-state rules per strategy (None -> same sharding as params)
OPT_RULES: dict[str, dict | None] = {
    "tp_fsdp": None,
    "fsdp": None,
    "dp_fsdp": {**LOGICAL_RULES,
                "embed": ("data", "model"), "mlp": ("model",),
                "heads": None, "kv_heads": None, "vocab": None},
}


class MeshContext(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = dict(LOGICAL_RULES)


_ctx = MeshContext()


def current_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def current_rules() -> dict:
    return _ctx.rules


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: dict | None = None):
    prev_mesh, prev_rules = _ctx.mesh, _ctx.rules
    _ctx.mesh = mesh
    _ctx.rules = {**LOGICAL_RULES, **(rules or {})}
    try:
        with mesh:  # classic Mesh context (shard_map gets mesh explicitly)
            yield mesh
    finally:
        _ctx.mesh, _ctx.rules = prev_mesh, prev_rules


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    n = 1
    for a in mesh_axes:
        n *= mesh.shape[a]
    return n


def divisible_prefix(dim: int, axes, mesh: Mesh, used=()) -> tuple:
    """Longest prefix of ``axes`` present in the mesh, unused, and whose
    size product divides ``dim`` (graceful degradation: batch=256 on a
    512-chip mesh shards over (pod, data) and replicates over model)."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
    axes = tuple(a for a in axes if a in mesh.shape and a not in used)
    while axes and dim % _axis_size(mesh, axes) != 0:
        axes = axes[:-1]
    return axes


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             mesh: Mesh, rules: dict | None = None) -> P:
    """PartitionSpec for an array with the given logical axes, degrading any
    rule whose mesh-axis product does not divide the dimension to its
    longest divisible prefix, and never using a mesh axis twice."""
    rules = rules or current_rules()
    parts, used = [], set()
    for dim, name in zip(shape, logical_axes):
        mesh_axes = rules.get(name) if name else None
        tup = divisible_prefix(dim, mesh_axes, mesh, used)
        if not tup:
            parts.append(None)
            continue
        used.update(tup)
        parts.append(tup[0] if len(tup) == 1 else tup)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_to_sharding(shape, logical_axes, mesh=None, rules=None):
    mesh = mesh or current_mesh()
    return NamedSharding(mesh, spec_for(shape, logical_axes, mesh, rules))


def shard_params(params, axes_tree, mesh=None, rules=None):
    """Tree of NamedShardings matching a params tree + logical-axes tree."""
    mesh = mesh or current_mesh()

    def f(leaf, axes):
        return logical_to_sharding(leaf.shape, axes, mesh, rules)
    # params is a structural prefix of axes_tree (its leaves are arrays where
    # axes_tree holds tuples of logical axis names), which tree.map allows.
    return jax.tree.map(f, params, axes_tree)


def shard_activation(x, logical_axes=None):
    """with_sharding_constraint for activations: batch dim over the batch
    rule, everything else replicated. No-op without a mesh context (CPU
    smoke tests). This anchors XLA's sharding propagation — without it the
    embedding table's layout leaks into the residual stream and the batch
    ends up replicated (perf iteration 3, EXPERIMENTS.md §Perf)."""
    mesh = current_mesh()
    if mesh is None or isinstance(mesh, jax.sharding.AbstractMesh):
        return x
    names = logical_axes or ("batch",) + (None,) * (x.ndim - 1)
    spec = spec_for(x.shape, names, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def batch_axes(mesh: Mesh | None = None, dim: int | None = None) -> tuple:
    """Mesh axes a global batch dimension shards over (strategy-aware; with
    ``dim`` given, degrades to the longest divisible prefix)."""
    mesh = mesh or current_mesh()
    ax = current_rules().get("batch") or ()
    if dim is None:
        ax = (ax,) if isinstance(ax, str) else tuple(ax)
        return tuple(a for a in ax if a in mesh.shape)
    return divisible_prefix(dim, ax, mesh)
