"""Flash attention for TPU (pl.pallas_call + explicit BlockSpec VMEM tiling).

Online-softmax tiling: grid (B, KV, G, nq, nk); the nk axis is sequential
("arbitrary") and carries running max / denominator / accumulator in VMEM
scratch; q/k/v blocks are MXU-aligned (block sizes multiples of 128 on the
contracting dims; head_dim is the lane dim). Causal and sliding-window masks
are applied blockwise; fully-masked blocks short-circuit via pl.when.

TPU adaptation notes (DESIGN.md): the CUDA flash algorithm's warp-level
shuffles have no TPU analogue — the TPU-native formulation keeps the
(block_q, head_dim) accumulator resident in VMEM across the sequential nk
grid dimension and lets the MXU do the (block_q x hd) @ (hd x block_k)
products; masking is vectorised on the VPU with 2-D iotas.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int, seq_len: int):
    ki = pl.program_id(4)
    qi = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level reachability: skip fully-masked tiles
    reachable = True
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window:
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 > q_start - window) \
            if causal else (k_start + block_k - 1 > q_start - window)

    @pl.when(reachable if (causal or window) else True)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        block_q=512, block_k=512, interpret=False):
    """q: (B,S,H,hd) bf16/f32; k, v: (B,S,KV,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(S, block_k)
    grid = (B, KV, G, nq, nk)
    kern = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, block_q=block_q, block_k=block_k, nk=nk, seq_len=S)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, kv, g, qi, ki: (b, qi, kv * G + g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, kv, g, qi, ki: (b, ki, kv, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, kv, g, qi, ki: (b, ki, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, kv, g, qi, ki: (b, qi, kv * G + g, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        compiler_params=tpu_compiler_params()(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
