"""jit'd public wrapper for the flash-attention kernel.

Forward runs the Pallas kernel (interpret=True on CPU); backward is a
custom_vjp that recomputes attention through the jnp oracle — numerically
the same math, so training through the kernel is supported without a
dedicated backward kernel (a future perf iteration).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, block_q=512, block_k=512):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_on_cpu())


def _fwd(q, k, v, causal, window, block_q, block_k):
    out = flash_attention(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v)


def _bwd(causal, window, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: attention_ref(q, k, v, causal=causal, window=window),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
