"""Pure-jnp oracle for the flash-attention kernel (GQA, causal /
bidirectional / sliding-window). Shapes: q (B,S,H,hd), k/v (B,S,KV,hd)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool) if not causal else (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(B, S, H, hd)
