"""Mamba2 SSD chunked scan for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Grid (b, H/block_h, nc): the chunk axis is sequential ("arbitrary") and
carries the (block_h, N, P) SSM state in VMEM scratch across chunks — the
inter-chunk recurrence never touches HBM. Within a chunk the quadratic
(Q x Q) intra-chunk term runs on the MXU; B/C projections are shared across
heads (n_groups=1), so their blocks are broadcast over the head grid axis.

TPU adaptation (DESIGN.md): the original SSD CUDA kernel leans on warp-wide
segsum primitives; here the segment-sum is a VPU cumsum + broadcasted
subtraction, and state passing is VMEM-resident scratch rather than
shared-memory tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _ssd_kernel(la_ref, x_ref, b_ref, c_ref, dt_ref, d_ref,
                y_ref, hlast_ref, h_ref, *, nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[0, 0].astype(jnp.float32)          # (Q, bh)
    x = x_ref[0, 0].astype(jnp.float32)            # (Q, bh, P)
    bm = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)           # (Q, N)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q, bh)
    dvec = d_ref[...].astype(jnp.float32)          # (bh,)

    lcum = jnp.cumsum(la, axis=0)                  # (Q, bh)
    seg = lcum[:, None, :] - lcum[None, :, :]      # (Q, Q, bh)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where((jj <= ii)[..., None], jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb[..., None] * L                          # (Q, Q, bh)
    xdt = x * dt[..., None]                        # (Q, bh, P)
    y = jnp.einsum("ijh,jhp->ihp", w, xdt)         # intra-chunk
    h = h_ref[...]                                 # (bh, N, P)
    y = y + jnp.einsum("in,hnp->ihp", cm, h) * jnp.exp(lcum)[..., None]
    decay_end = jnp.exp(lcum[-1:, :] - lcum)       # (Q, bh)
    s_c = jnp.einsum("jn,jhp->hnp", bm, xdt * decay_end[..., None])
    h_new = h * jnp.exp(lcum[-1, :])[:, None, None] + s_c
    h_ref[...] = h_new
    y = y + dvec[None, :, None] * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        hlast_ref[0] = h_new.astype(hlast_ref.dtype)


def ssd_scan_fwd(x, dt, B, C, la, D, *, block_h: int = 0, interpret=False):
    """x (b,nc,Q,H,P); dt,la (b,nc,Q,H); B,C (b,nc,Q,N); D (H,).
    Returns (y (b, nc*Q, H, P), h_last (b, H, N, P))."""
    b, nc, Q, H, P = x.shape
    N = B.shape[-1]
    block_h = block_h or min(H, 8)
    assert H % block_h == 0
    nh = H // block_h
    grid = (b, nh, nc)
    kern = functools.partial(_ssd_kernel, nc=nc, chunk=Q)
    y, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, block_h), lambda bi, hi, ci: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, Q, block_h, P),
                         lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, Q, block_h), lambda bi, hi, ci: (bi, ci, 0, hi)),
            pl.BlockSpec((block_h,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, block_h, P),
                         lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, block_h, N, P), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, Q, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, N, P), jnp.float32)],
        compiler_params=tpu_compiler_params()(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(la, x, B, C, dt, D)
    return y.reshape(b, nc * Q, H, P), h_last
