"""jit'd wrapper for the SSD scan kernel; backward recomputes through the
jnp oracle (custom_vjp), so cfg.use_ssd_kernel works under jax.grad."""
from __future__ import annotations

import jax

from .kernel import ssd_scan_fwd
from .ref import ssd_scan_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@jax.custom_vjp
def ssd_scan(x, dt, B, C, la, D):
    return ssd_scan_fwd(x, dt, B, C, la, D, interpret=_on_cpu())


def _fwd(x, dt, B, C, la, D):
    return ssd_scan(x, dt, B, C, la, D), (x, dt, B, C, la, D)


def _bwd(res, g):
    _, vjp = jax.vjp(ssd_scan_ref, *res)
    return vjp(g)


ssd_scan.defvjp(_fwd, _bwd)
