"""Pure-jnp oracle for the Mamba2 SSD chunked scan.

Inputs (pre-chunked): x (b,nc,Q,H,P), dt (b,nc,Q,H), B,C (b,nc,Q,N),
la = dt * A (log-decay per step) (b,nc,Q,H), D (H,).
Returns y (b, nc*Q, H, P) and final state (b, H, N, P) — the contract of
models/mamba2.ssd_chunked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, B, C, la, D):
    b, nc, Q, H, P = x.shape
    N = B.shape[-1]
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def scan_fn(h, inp):
        la_c, x_c, b_c, c_c, dt_c = inp
        lcum = jnp.cumsum(la_c, axis=1)
        seg = lcum[:, :, None, :] - lcum[:, None, :, :]
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)
        w = cb[..., None] * L
        xdt = x_c.astype(jnp.float32) * dt_c[..., None]
        y = jnp.einsum("bijh,bjhp->bihp", w, xdt)
        y = y + jnp.einsum("bin,bhnp->bihp", c_c, h) * jnp.exp(lcum)[..., None]
        decay_to_end = jnp.exp(lcum[:, -1:, :] - lcum)
        s_c = jnp.einsum("bjn,bjhp->bhnp", b_c, xdt * decay_to_end[..., None])
        h_new = h * jnp.exp(lcum[:, -1, :])[..., None, None] + s_c
        return h_new, y

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(la, 1, 0), jnp.moveaxis(x, 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0))
    h_last, ys = jax.lax.scan(scan_fn, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype).reshape(b, nc * Q, H, P)
    y = y + (D[:, None] * x.astype(jnp.float32).reshape(b, nc * Q, H, P)
             ).astype(x.dtype)
    return y, h_last
