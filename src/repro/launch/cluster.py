"""Multi-host/TPU-pod process wiring (real-cluster path).

On an actual pod fleet every host runs the same entrypoint;
``initialize_cluster()`` wires jax.distributed from environment (TPU
metadata when present, otherwise COORDINATOR_ADDR/NUM_PROCESSES/PROCESS_ID
as used by launch_pod.sh), and ``global_runtime_cluster()`` builds the
I/O-aware runtime's resource view of the fleet: one worker entry per host,
all referencing the shared checkpoint filesystem device so the paper's
bandwidth constraints are accounted fleet-wide.

Failure/elasticity protocol (DESIGN.md §7): the launcher script relaunches
survivors with a smaller NUM_PROCESSES after a node failure; checkpoints
store logical shardings only, so `CheckpointManager.restore(...,
shardings=new_mesh_shardings)` re-shards onto whatever mesh the relaunch
built (tested in tests/test_distributed_exec.py).
"""
from __future__ import annotations

import os

import jax

from ..core import Cluster, StorageDevice, WorkerNode


def initialize_cluster() -> dict:
    """Idempotent jax.distributed init from environment. Returns topology
    info. Safe to call on single-host (no-op)."""
    coord = os.environ.get("COORDINATOR_ADDR")
    nproc = int(os.environ.get("NUM_PROCESSES", "1"))
    pid = int(os.environ.get("PROCESS_ID", "0"))
    if nproc > 1:
        jax.distributed.initialize(coordinator_address=coord,
                                   num_processes=nproc, process_id=pid)
    return {"process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "local_devices": jax.local_device_count(),
            "global_devices": jax.device_count()}


def global_runtime_cluster(ckpt_bw_mbs: float = 2000.0,
                           io_executors_per_host: int = 8) -> Cluster:
    """The I/O-aware runtime's fleet view: hosts share one checkpoint-FS
    device, so storage-bandwidth constraints bound CONCURRENT WRITERS
    FLEET-WIDE — the pod-scale analogue of the paper's congestion control.
    Per-host runtimes schedule only their own shards; the budget each host
    may assume is its fair slice (coordinator-free, conservative)."""
    n = max(jax.process_count(), 1)
    shared = StorageDevice(name="ckpt-fs", bandwidth=ckpt_bw_mbs / n,
                           per_stream_cap=ckpt_bw_mbs / n / 4)
    me = WorkerNode(name=f"host{jax.process_index()}", cpus=4,
                    io_executors=io_executors_per_host, storage=shared)
    return Cluster(workers=[me])
