import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) combination:
  jax.jit(step).lower(**ShapeDtypeStructs).compile()
must succeed on the 16x16 single-pod mesh AND the 2x16x16 two-pod mesh.
Per cell we record memory_analysis, cost_analysis, and the collective
traffic parsed from the partitioned HLO — the roofline reads these JSONs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--force]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from ..compat import cost_analysis_dict
from ..configs import ARCHS, SHAPES, cell_supported, get_config
from ..distributed import mesh_context
from ..launch.mesh import make_production_mesh
from ..launch.specs import build_cell

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%([\w.\-]+), body=%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")


def _split_computations(hlo_text: str):
    comps: dict[str, list[str]] = {}
    cur, entry = None, None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMP_RE.match(s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if s.startswith("ENTRY") or line.startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            comps[cur].append(s)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    consts = [int(m.group(1)) for l in cond_lines
              for m in _CONST_RE.finditer(l)]
    return max(consts) if consts else 1


def parse_collectives(hlo_text: str) -> dict:
    """Trip-count-aware per-device collective traffic (DESIGN.md §8).

    Collectives inside while bodies (lax.scan over layers / chunks) appear
    once in the HLO text; we multiply by the loop trip count parsed from the
    cond region's s32 constant. Traffic model: bytes = result_size * factor;
    factor: all-reduce 2, reduce-scatter g, others 1 (ring models — the
    all-gather result already includes the group factor)."""
    comps, entry = _split_computations(hlo_text)
    # nesting: computation -> [(child_body, trip)], from while ops inside it
    children: dict[str, list[tuple[str, int]]] = {}
    for name, lines in comps.items():
        for l in lines:
            w = _WHILE_RE.search(l)
            if w:
                cond, body = w.groups()
                trip = _trip_count(comps.get(cond, []))
                children.setdefault(name, []).append((body, trip))
    # multipliers via BFS from entry
    mult: dict[str, float] = {}
    stack = [(entry, 1.0)] if entry else []
    while stack:
        name, m = stack.pop()
        if name in mult and mult[name] >= m:
            continue
        mult[name] = m
        for body, trip in children.get(name, []):
            stack.append((body, m * trip))
    # computations never reached from the entry via while (fusions etc.)
    # inherit 1x; the collectives we care about sit directly in region bodies
    per_op: dict[str, float] = {}
    count: dict[str, int] = {}
    per_op_static: dict[str, float] = {}
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for l in lines:
            cm = _COLL_RE.search(l)
            if not cm:
                continue
            dtype, dims, op = cm.groups()
            nbytes = _DTYPE_BYTES.get(dtype)
            if nbytes is None:
                continue
            size = nbytes
            for d in dims.split(","):
                if d:
                    size *= int(d)
            g = 1
            gm = _GROUPS_RE.search(l)
            if gm:
                g = int(gm.group(2))
            else:
                gl = _GROUPS_LIST_RE.search(l)
                if gl:
                    g = len(gl.group(1).split(","))
            factor = {"all-reduce": 2.0,
                      "reduce-scatter": float(max(g, 1))}.get(op, 1.0)
            per_op[op] = per_op.get(op, 0.0) + size * factor * m
            per_op_static[op] = per_op_static.get(op, 0.0) + size * factor
            count[op] = count.get(op, 0) + 1
    return {"bytes_by_op": per_op, "count_by_op": count,
            "bytes_by_op_body_once": per_op_static,
            "total_bytes": sum(per_op.values())}


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = repr(ma)
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, force: bool = False,
             tag: str = "", cfg_override=None, strategy: str = "tp_fsdp") -> dict:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape}__{mesh_kind}{tag}"
    path = ARTIFACTS / f"{name}.json"
    if path.exists() and not force:
        cached = json.loads(path.read_text())
        if cached.get("status") != "error":
            return cached  # errors are retried (they are bugs being fixed)

    cfg = cfg_override or get_config(arch)
    cell = SHAPES[shape]
    ok, reason = cell_supported(cfg, cell)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag,
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=reason)
        path.write_text(json.dumps(rec, indent=1))
        return rec

    from ..distributed.sharding import STRATEGIES
    rec["strategy"] = strategy
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with mesh_context(mesh, rules=STRATEGIES[strategy]):
            if cell.kind == "decode" and \
                    cell.global_batch % (mesh.devices.size // mesh.shape["model"]):
                cfg = cfg.replace(decode_batch_replicated=True)
            from ..distributed.sharding import OPT_RULES
            fn, args, out_sh = build_cell(cfg, cell, mesh,
                                          opt_rules=OPT_RULES.get(strategy))
            jitted = jax.jit(fn, out_shardings=out_sh) if out_sh else jax.jit(fn)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            cost = cost_analysis_dict(compiled) or {}
            cost = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))
                    and "utilization" not in k}
            hlo = compiled.as_text()
            coll = parse_collectives(hlo)
            rec.update(
                status="ok",
                lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                n_devices=int(mesh.devices.size),
                cost_analysis={k: cost[k] for k in sorted(cost)[:40]},
                flops=float(cost.get("flops", 0.0)),
                bytes_accessed=float(cost.get("bytes accessed", 0.0)),
                collectives=coll,
                memory=memory_summary(compiled),
                hlo_bytes=len(hlo),
            )
    except Exception as e:  # record failures: they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--strategy", default="tp_fsdp")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = cell_supported(get_config(a), SHAPES[s])
                print(f"{a:24} {s:12} {'RUN' if ok else 'SKIP: ' + why}")
        return 0

    failures = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                rec = run_cell(a, s, m, force=args.force,
                               strategy=args.strategy, tag=args.tag)
                line = f"{a:24} {s:12} {m:6} {rec['status']:8}"
                if rec["status"] == "ok":
                    line += (f" compile={rec['compile_s']:7.1f}s "
                             f"flops={rec['flops']:.3e} "
                             f"coll={rec['collectives']['total_bytes']:.3e}B")
                elif rec["status"] == "error":
                    line += " " + rec["error"][:120]
                    failures += 1
                else:
                    line += " " + rec.get("reason", "")
                print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
