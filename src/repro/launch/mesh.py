"""Production mesh builders (DESIGN.md §5).

Defined as FUNCTIONS so importing this module never touches jax device
state; dryrun.py sets XLA_FLAGS before importing anything.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh on whatever devices exist — smoke tests / CPU runs."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
