"""Batched serving driver (deliverable b): continuous-batching-style loop —
prefill new requests, decode the active batch one token per step, retire
finished sequences, measure tokens/s. Request arrivals and trace dumps run
through the I/O-aware runtime (reads/log-writes are I/O tasks overlapping
the decode compute, the paper's serving-side analogue).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_smoke_config
from ..core import Cluster, IORuntime, RealBackend, StorageDevice, WorkerNode, io, task
from ..models import Model
from ..obs.report import percentile, span_latencies


@io
@task(returns=1)
def _dump_trace(path, record, prev=None):
    # `prev` is the previous dump's future: chaining it serializes appends
    # to the shared trace file (unordered writers on one path is exactly
    # lint diagnostic IO301 — and a real interleaving hazard on the
    # RealBackend's I/O thread pool)
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")
    return path


def serve(cfg, *, n_requests=8, prompt_len=32, max_new=16, batch=4,
          trace_path=None, seed=0):
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len).astype(np.int32)
               for _ in range(n_requests)]

    prefill = jax.jit(lambda p, b: model.prefill(p, b, prompt_len + max_new))
    decode = jax.jit(model.decode_step)

    dev = StorageDevice(name="trace-fs", bandwidth=500, per_stream_cap=125)
    cluster = Cluster(workers=[WorkerNode(name="h0", cpus=2, io_executors=4,
                                          storage=dev)])
    done, t0 = [], time.monotonic()
    new_tokens = 0
    trace_tok = None
    lat = []
    with IORuntime(cluster, backend=RealBackend(), trace=True) as rt:
        rec = rt.trace()  # None under repro.lint's capture mode
        now = rec.now if rec is not None else (lambda: time.monotonic() - t0)
        queue = list(enumerate(prompts))
        while queue:
            wave, queue = queue[:batch], queue[batch:]
            admit = {rid: now() for rid, _ in wave}
            toks = jnp.asarray(np.stack([p for _, p in wave]))
            logits, state = prefill(params, {"tokens": toks})
            out = [[] for _ in wave]
            nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
            first_tok = {}
            for step in range(max_new):
                for i, (rid, _) in enumerate(wave):
                    out[i].append(int(nxt[i]))
                    if rid not in first_tok:
                        first_tok[rid] = now()
                logits, state = decode(params, state, nxt)
                nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
                new_tokens += len(wave)
            for (rid, _), o in zip(wave, out):
                t_end = now()
                lat.append(t_end - admit[rid])
                row = {"request": rid, "tokens": o,
                       "t": time.monotonic() - t0}
                done.append(row)
                if rec is not None:
                    # admission -> first-token -> finish span; the span
                    # event *is* the JSONL trace row, so the dumped file
                    # and the recorder's stream stay one schema
                    row = rec.span(
                        f"req-{rid}", cat="request", t0=admit[rid],
                        t1=t_end, request=rid, n_tokens=len(o),
                        first_token_s=first_tok[rid] - admit[rid])
                if trace_path:
                    trace_tok = _dump_trace(trace_path, row, trace_tok)
        if rec is not None:
            lat = span_latencies(rec, cat="request")
    wall = time.monotonic() - t0
    return {"requests": len(done), "new_tokens": new_tokens,
            "tokens_per_s": new_tokens / wall, "wall_s": wall,
            "p50_s": percentile(lat, 0.50), "p99_s": percentile(lat, 0.99),
            "completions": done}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--trace", default=None)
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    out = serve(cfg, n_requests=args.requests, prompt_len=args.prompt_len,
                max_new=args.max_new, batch=args.batch, trace_path=args.trace)
    print(f"[serve] {out['requests']} requests, {out['new_tokens']} tokens, "
          f"{out['tokens_per_s']:.1f} tok/s, wall {out['wall_s']:.1f}s, "
          f"latency p50 {out['p50_s']:.3f}s p99 {out['p99_s']:.3f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
