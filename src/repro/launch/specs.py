"""ShapeDtypeStruct input specs + step-function builders for every
(architecture × shape-cell). No device allocation: everything goes through
jax.eval_shape and NamedSharding-annotated ShapeDtypeStructs — the pattern
the multi-pod dry-run requires.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCell
from ..distributed import batch_axes
from ..distributed.sharding import spec_for, current_rules
from ..models import Model
from ..optim import AdamWConfig, adamw_init, adamw_update


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def model_shapes_and_axes(model: Model):
    """(params ShapeDtypeStruct tree, logical-axes tree) without allocating."""
    box = {}

    def f(r):
        p, ax = model.init(r)
        box["ax"] = ax
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["ax"]


def tree_shardings(sds_tree, axes_tree, mesh):
    def f(sds, axes):
        return NamedSharding(mesh, spec_for(sds.shape, axes, mesh))
    return jax.tree.map(f, sds_tree, axes_tree)


def with_shardings(sds_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, shardings_tree)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """ShapeDtypeStructs for the model inputs of one shape cell."""
    B, S = cell.global_batch, cell.seq_len
    bx = batch_axes(mesh, B)
    bspec = bx if bx else None
    tok = partial(_sds, dtype=jnp.int32, mesh=mesh)
    if cell.kind == "decode":
        return {"tokens": tok((B,), spec=P(bspec))}
    if cfg.input_mode == "tokens":
        return {"tokens": tok((B, S), spec=P(bspec, None)),
                "targets": tok((B, S), spec=P(bspec, None))}
    if cfg.input_mode == "embeds":
        return {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                               P(bspec, None, None)),
                "targets": tok((B, S), spec=P(bspec, None))}
    if cfg.input_mode == "vlm":
        sv = cfg.vision_seq
        st = S - sv
        return {"vision_embeds": _sds((B, sv, cfg.d_model), jnp.bfloat16,
                                      mesh, P(bspec, None, None)),
                "tokens": tok((B, st), spec=P(bspec, None)),
                "targets": tok((B, st), spec=P(bspec, None))}
    raise ValueError(cfg.input_mode)


def _ax_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def decode_state_specs(model: Model, cell: ShapeCell, mesh):
    sds = jax.eval_shape(
        lambda: model.init_decode_state(cell.global_batch, cell.seq_len))
    sh = tree_shardings(sds, model.decode_state_axes(), mesh)
    return with_shardings(sds, sh), sh


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh,
               opt_cfg: AdamWConfig | None = None, opt_rules: dict | None = None):
    """Returns (step_fn, example_args (SDS w/ shardings), out_shardings|None).

    step_fn signatures:
      train:   (params, opt_state, batch) -> (params, opt_state, loss, gnorm)
      prefill: (params, batch) -> (logits, state)
      decode:  (params, state, tokens) -> (logits, state)
    """
    model = Model(cfg)
    p_sds, p_axes = model_shapes_and_axes(model)
    p_sh = tree_shardings(p_sds, p_axes, mesh)
    p_in = with_shardings(p_sds, p_sh)
    opt_cfg = opt_cfg or AdamWConfig()

    if cell.kind == "train":
        o_sds = jax.eval_shape(adamw_init, p_sds)
        o_sh = jax.tree.map(
            lambda s: NamedSharding(
                mesh, spec_for(s.shape, (None,) * len(s.shape), mesh))
            if s.shape == () else None, o_sds)
        # m/v share the params' sharding unless the strategy shards the
        # optimizer state differently (ZeRO-1-style); count replicated
        from ..optim.adamw import AdamWState
        if opt_rules is not None:
            mv_sh = jax.tree.map(
                lambda s, ax: NamedSharding(
                    mesh, spec_for(s.shape, ax, mesh, opt_rules)),
                p_sds, p_axes)
        else:
            mv_sh = p_sh
        o_sh = AdamWState(m=mv_sh, v=mv_sh,
                          count=NamedSharding(mesh, P()))
        o_in = with_shardings(o_sds, o_sh)
        b_in = batch_specs(cfg, cell, mesh)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            new_p, new_o, gnorm = adamw_update(grads, params, opt_state,
                                               opt_cfg)
            return new_p, new_o, loss, gnorm

        out_sh = (p_sh, o_sh, NamedSharding(mesh, P()),
                  NamedSharding(mesh, P()))
        return train_step, (p_in, o_in, b_in), out_sh

    if cell.kind == "prefill":
        b_in = batch_specs(cfg, cell, mesh)
        if cfg.family == "encoder":
            def prefill(params, batch):
                return model.encode(params, batch)
        else:
            def prefill(params, batch):
                return model.prefill(params, batch, cell.seq_len)
        return prefill, (p_in, b_in), None

    # decode
    s_in, s_sh = decode_state_specs(model, cell, mesh)
    b_in = batch_specs(cfg, cell, mesh)

    def decode(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return decode, (p_in, s_in, b_in["tokens"]), None
