"""End-to-end training driver (deliverable b).

Wires every substrate together: model zoo + AdamW + synthetic data pipeline
+ the I/O-aware runtime for async checkpointing (auto-constrained shard
writes overlapping train steps), resume-from-latest, SIGTERM preemption
save, and optional baseline mode (--io-aware=off: synchronous checkpoints,
the paper's non-I/O-aware baseline).

  PYTHONPATH=src python -m repro.launch.train --preset 20m --steps 50 \
      --ckpt-dir /tmp/ck --ckpt-every 10
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import ARCHS, get_config, get_smoke_config
from ..configs.base import ModelConfig
from ..core import Cluster, IORuntime, RealBackend, StorageDevice, WorkerNode
from ..data import PrefetchLoader, SyntheticCorpus
from ..distributed import mesh_context
from ..models import Model
from ..optim import AdamWConfig, adamw_init, adamw_update
from .mesh import make_local_mesh

PRESETS = {
    # ~100M-class model for real-hardware runs; smaller ones for CPU demos
    "100m": ModelConfig(name="repro-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                        vocab_size=32000, remat=False),
    "20m": ModelConfig(name="repro-20m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
                       vocab_size=8192, remat=False),
    "5m": ModelConfig(name="repro-5m", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv_heads=4, d_ff=768,
                      vocab_size=4096, remat=False),
}


def build_cluster(io_executors: int = 8, device_bw: float = 2000.0):
    """One 'host' with a checkpoint filesystem device. The bandwidth number
    is the budget the scheduler constrains against (MB/s)."""
    dev = StorageDevice(name="ckpt-fs", bandwidth=device_bw,
                        per_stream_cap=device_bw / 4)
    return Cluster(workers=[WorkerNode(name="host0", cpus=4,
                                       io_executors=io_executors,
                                       storage=dev)])


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, ckpt_every: int, io_aware: bool = True,
          resume: bool = True, log_path: str | None = None,
          opt: AdamWConfig | None = None, seed: int = 0):
    model = Model(cfg)
    opt = opt or AdamWConfig(total_steps=max(steps, 2), warmup_steps=max(steps // 10, 1))
    params, _ = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    corpus = SyntheticCorpus(cfg.vocab_size, seq, batch, seed=seed)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_p, new_o, gnorm = adamw_update(grads, params, opt_state, opt)
        return new_p, new_o, loss, gnorm

    mgr = CheckpointManager(ckpt_dir, n_shards=8) if ckpt_dir else None
    start_step = 0
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), start_step = mgr.restore((params, opt_state))
        start_step += 1
        print(f"[train] resumed from step {start_step - 1}", flush=True)

    stop = {"now": False}

    def _sigterm(signum, frame):
        stop["now"] = True  # preemption: finish step, sync-save, exit
    old = signal.signal(signal.SIGTERM, _sigterm)

    log_f = open(log_path, "a") if log_path else None
    cluster = build_cluster()
    losses = []
    t_start = time.monotonic()
    with IORuntime(cluster, backend=RealBackend()) as rt:
        loader = PrefetchLoader(corpus, depth=2) if io_aware else None
        for step in range(start_step, steps):
            b = loader.get(step) if io_aware else corpus.batch(step)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt_state, loss, gnorm = train_step(params, opt_state, b)
            losses.append(float(loss))
            if log_f:
                log_f.write(json.dumps({"step": step, "loss": float(loss),
                                        "gnorm": float(gnorm),
                                        "t": time.monotonic() - t_start}) + "\n")
                log_f.flush()
            if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
                mgr.save(step, (params, opt_state), sync=not io_aware)
            if stop["now"]:
                print(f"[train] SIGTERM at step {step}: final sync save",
                      flush=True)
                if mgr:
                    mgr.save(step, (params, opt_state), sync=True)
                break
        if mgr:
            mgr.wait()
        stats = rt.stats()
    signal.signal(signal.SIGTERM, old)
    if log_f:
        log_f.close()
    return {"losses": losses, "steps_run": len(losses),
            "final_loss": losses[-1] if losses else None,
            "runtime_stats": {k: v for k, v in stats.items()
                              if k not in ("tuners",)},
            "wall_s": time.monotonic() - t_start,
            "params": params, "opt_state": opt_state}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default=None)
    ap.add_argument("--preset", choices=list(PRESETS), default="20m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-io-aware", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--log", default=None)
    args = ap.parse_args(argv)

    if args.arch:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        cfg = PRESETS[args.preset]
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                io_aware=not args.no_io_aware, resume=not args.no_resume,
                log_path=args.log)
    print(f"[train] {out['steps_run']} steps, final loss "
          f"{out['final_loss']:.4f}, wall {out['wall_s']:.1f}s")
    first, last = out["losses"][0], out["final_loss"]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
