"""``python -m repro.lint <script.py> ...`` — static I/O-plan analyzer CLI.

Runs each script under *forced capture*: every ``IORuntime`` the script
constructs is hijacked into capture mode (the backend it asked for is
replaced by :class:`repro.analysis.CaptureBackend`), so the full task DAG
is recorded but **no task body executes**. The recorded plans are then run
through the lint rule engine (repro.analysis.lint; catalog in
docs/lint.md).

Exit status: 0 when every script is clean, 1 when any diagnostic was
emitted, 2 on harness errors (missing file). Script exceptions *after*
the DAG was captured are reported as notes, not failures — under capture
every future resolves to ``None``, so result post-processing in a script
may legitimately fail.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .analysis.lint import lint_script


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Static I/O-plan analyzer: capture each script's task "
                    "DAG without executing it and report IO1xx-IO4xx "
                    "diagnostics (see docs/lint.md).")
    parser.add_argument("scripts", nargs="+", metavar="script.py",
                        help="Python scripts to capture and lint")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable output (one JSON document)")
    args = parser.parse_args(argv)

    results = []
    status = 0
    for path in args.scripts:
        if not os.path.isfile(path):
            print(f"repro.lint: no such file: {path}", file=sys.stderr)
            return 2
        diags, notes = lint_script(path)
        results.append((path, diags, notes))
        if diags:
            status = 1

    if args.as_json:
        doc = [{"script": path,
                "diagnostics": [{"code": d.code, "category": d.category,
                                 "task": d.task, "tid": d.tid,
                                 "message": d.message} for d in diags],
                "notes": notes}
               for path, diags, notes in results]
        print(json.dumps(doc, indent=2))
        return status

    total = 0
    for path, diags, notes in results:
        for note in notes:
            print(f"note: {note}", file=sys.stderr)
        for d in diags:
            print(f"{path}: {d}")
        total += len(diags)
        if not diags:
            print(f"{path}: clean")
    if total:
        print(f"{total} diagnostic(s) across {len(results)} script(s)",
              file=sys.stderr)
    return status


if __name__ == "__main__":
    raise SystemExit(main())
