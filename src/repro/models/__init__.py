from .model import Model
