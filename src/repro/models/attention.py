"""GQA/MQA attention with RoPE, causal / bidirectional / sliding-window
masks, full-sequence forward (train & prefill) and single-token decode
against a (optionally rolling) KV cache.

The full-sequence path can route through the Pallas flash-attention kernel
(``cfg.use_flash``); the default XLA path is the lowering used by the
dry-run/roofline (kernels target real TPUs and are validated separately in
interpret mode).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import _init, apply_rope


def attn_init(rng, d_model, n_heads, n_kv, head_dim, dtype):
    kq, kk, kv, ko = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "q": _init(kq, (d_model, n_heads, head_dim), s, dtype),
        "k": _init(kk, (d_model, n_kv, head_dim), s, dtype),
        "v": _init(kv, (d_model, n_kv, head_dim), s, dtype),
        "o": _init(ko, (n_heads, head_dim, d_model), 1.0 / math.sqrt(n_heads * head_dim), dtype),
    }
    ax = {
        "q": ("embed", "heads", "head_dim"),
        "k": ("embed", "kv_heads", "head_dim"),
        "v": ("embed", "kv_heads", "head_dim"),
        "o": ("heads", "head_dim", "embed"),
    }
    return p, ax


def _mask(q_pos, k_pos, causal: bool, window: int):
    """(..., Sq, Sk) boolean mask. window=0 -> unbounded."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool) \
        if not causal else (k_pos[..., None, :] <= q_pos[..., :, None])
    if window:
        m = m & (k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m


def _dense_attn(q, k, v, positions, causal, window):
    """Materialises the full (S, S) score matrix — short sequences only."""
    B, S, KV, hd = k.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / math.sqrt(hd)
    mask = _mask(positions, positions, causal, window)      # (B, S, S)
    scores = jnp.where(mask[:, None, None], scores.astype(jnp.float32), -1e9)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(B, S, H, hd)


def _chunked_attn(q, k, v, positions, causal, window, chunk_q):
    """Scan over query chunks: peak score temp is (B,KV,G,Qc,S) instead of
    (B,KV,G,S,S) — the XLA-path analogue of flash attention's tiling."""
    B, S, KV, hd = k.shape
    H = q.shape[2]
    G = H // KV
    nq = S // chunk_q
    qg = q.reshape(B, nq, chunk_q, KV, G, hd)
    qpos = positions.reshape(B, nq, chunk_q)

    def body(_, inp):
        qc, pc = inp                                        # (B,Qc,KV,G,hd)
        scores = jnp.einsum("bskgh,btkh->bkgst", qc, k) / math.sqrt(hd)
        mask = _mask(pc, positions, causal, window)         # (B, Qc, S)
        scores = jnp.where(mask[:, None, None],
                           scores.astype(jnp.float32), -1e9)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgst,btkh->bskgh", w, v)
        return 0, o

    _, outs = jax.lax.scan(body, 0, (jnp.moveaxis(qg, 1, 0),
                                     jnp.moveaxis(qpos, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def multihead_attn(p, x, positions, *, causal=True, window=0, rope_theta=1e4,
                   use_flash=False, flash_block=512, chunk_q_threshold=8192,
                   chunk_q=1024):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, hd = p["q"].shape[1], p["q"].shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["k"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["v"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if use_flash:
        from ..kernels.flash_attention import ops as flash_ops
        o = flash_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      block_q=flash_block, block_k=flash_block)
    elif S >= chunk_q_threshold and S % chunk_q == 0:
        o = _chunked_attn(q, k, v, positions, causal, window, chunk_q)
    else:
        o = _dense_attn(q, k, v, positions, causal, window)
    return jnp.einsum("bshk,hkd->bsd", o, p["o"])


# --------------------------------------------------------------------------
# Decode with (rolling) KV cache
# --------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array          # (B, C, KV, hd)
    v: jax.Array          # (B, C, KV, hd)
    slot_pos: jax.Array   # (C,) int32, position stored in each slot (-1 empty)

    @staticmethod
    def init(batch, capacity, n_kv, head_dim, dtype):
        return KVCache(
            k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
            v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
            slot_pos=jnp.full((capacity,), -1, jnp.int32),
        )


def cache_capacity(seq_len: int, window: int) -> int:
    return min(seq_len, window) if window else seq_len


def decode_attn(p, x, cache: KVCache, pos, *, window=0, rope_theta=1e4):
    """x: (B, D) one new token at position ``pos`` (scalar int32).
    Returns (out (B, D), new_cache). Rolling write when window is set."""
    B, D = x.shape
    H, hd = p["q"].shape[1], p["q"].shape[2]
    KV = p["k"].shape[1]
    C = cache.k.shape[1]
    q = jnp.einsum("bd,dhk->bhk", x, p["q"])
    k = jnp.einsum("bd,dhk->bhk", x, p["k"])
    v = jnp.einsum("bd,dhk->bhk", x, p["v"])
    pos_b = jnp.broadcast_to(pos, (B, 1))
    q = apply_rope(q[:, None], pos_b, rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos_b, rope_theta)[:, 0]
    slot = jnp.where(window, pos % jnp.maximum(C, 1), pos).astype(jnp.int32)
    nk = jax.lax.dynamic_update_slice_in_dim(cache.k, k[:, None], slot, axis=1)
    nv = jax.lax.dynamic_update_slice_in_dim(cache.v, v[:, None], slot, axis=1)
    npos = cache.slot_pos.at[slot].set(pos.astype(jnp.int32))
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bckh->bkgc", qg, nk) / math.sqrt(hd)
    valid = (npos >= 0) & (npos <= pos)
    if window:
        valid = valid & (npos > pos - window)
    scores = jnp.where(valid[None, None, None, :], scores.astype(jnp.float32), -1e9)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgc,bckh->bkgh", w, nv).reshape(B, H, hd)
    out = jnp.einsum("bhk,hkd->bd", o, p["o"])
    return out, KVCache(nk, nv, npos)
