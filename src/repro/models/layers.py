"""Shared building blocks (pure JAX, no flax): norms, RoPE, MLP, embeddings,
losses. Params are plain dict pytrees; a parallel tree of *logical axis*
tuples drives sharding (distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _init(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# Param spec helpers: each init returns (params, logical_axes) twin trees.
# --------------------------------------------------------------------------
def dense_init(rng, d_in, d_out, axes, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return _init(rng, (d_in, d_out), scale, dtype), axes


def rmsnorm_init(d):
    return jnp.ones((d,), dtype=jnp.float32), ("norm",)


def rmsnorm(x, w, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))
    return jnp.asarray(inv)  # (head_dim/2,)


def apply_rope(x, positions, theta=10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------
def mlp_init(rng, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "gate": _init(k1, (d_model, d_ff), 1 / math.sqrt(d_model), dtype),
        "up": _init(k2, (d_model, d_ff), 1 / math.sqrt(d_model), dtype),
        "down": _init(k3, (d_ff, d_model), 1 / math.sqrt(d_ff), dtype),
    }
    ax = {"gate": ("embed", "mlp"), "up": ("embed", "mlp"), "down": ("mlp", "embed")}
    return p, ax


def mlp_apply(p, x):
    g = jnp.einsum("...d,df->...f", x, p["gate"])
    u = jnp.einsum("...d,df->...f", x, p["up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["down"])


# --------------------------------------------------------------------------
# Embedding + loss
# --------------------------------------------------------------------------
def pad_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def embed_init(rng, vocab_padded, d_model, dtype):
    return _init(rng, (vocab_padded, d_model), 1.0, dtype), ("vocab", "embed")


def softmax_xent(logits, labels, vocab_real: int, z_loss: float = 0.0):
    """Cross-entropy in fp32 with padded-vocab masking. labels==-1 ignored."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad > vocab_real:
        neg = jnp.full((vpad - vocab_real,), -1e9, dtype=jnp.float32)
        logits = logits.at[..., vocab_real:].set(neg) if False else \
            jnp.concatenate([logits[..., :vocab_real],
                             jnp.broadcast_to(neg, logits[..., vocab_real:].shape)],
                            axis=-1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    valid = labels >= 0
    labels_safe = jnp.where(valid, labels, 0)
    picked = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * valid
    loss = nll.sum() / jnp.maximum(valid.sum(), 1)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse) * valid)
    return loss
