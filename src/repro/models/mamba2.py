"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) block in pure JAX.

Chunked SSD forward (train & prefill): intra-chunk quadratic term + inter-
chunk first-order recurrence over chunk states (lax.scan over chunks).
Single-token recurrent decode against a (conv window, SSM state) cache.

Projection layout (perf iteration 1, EXPERIMENTS.md §Perf): x/z/B/C/dt are
SEPARATE projections rather than one fused in_proj. A fused projection puts
head-shardable channels (x, z) and head-SHARED channels (B, C) in one
tensor-parallel-sharded output, forcing an activation reshard every layer;
split projections keep the SSD entirely head-local under TP (B/C replicate,
x/z shard on the head axis).

The chunk-local compute is mirrored by the Pallas kernel in
kernels/ssd_scan (cfg.use_ssd_kernel routes through it).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import _init, rmsnorm


def mamba2_init(rng, d_model, *, expand=2, headdim=64, ssm_state=128,
                conv_dim=4, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    H = d_inner // headdim
    N = ssm_state
    ks = jax.random.split(rng, 7)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "in_x": _init(ks[0], (d_model, d_inner), s, dtype),
        "in_z": _init(ks[1], (d_model, d_inner), s, dtype),
        "in_bc": _init(ks[2], (d_model, 2 * N), s, dtype),
        "in_dt": _init(ks[3], (d_model, H), s, dtype),
        "conv_x": _init(ks[4], (conv_dim, d_inner), 0.5, dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc": _init(ks[5], (conv_dim, 2 * N), 0.5, dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus(-2) ~ 0.13
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _init(ks[6], (d_inner, d_model), 1.0 / math.sqrt(d_inner), dtype),
    }
    ax = {
        "in_x": ("embed", "mlp"),
        "in_z": ("embed", "mlp"),
        "in_bc": ("embed", None),      # B/C are shared across heads: replicate
        "in_dt": ("embed", "heads"),
        "conv_x": ("conv", "mlp"),
        "conv_x_b": ("mlp",),
        "conv_bc": ("conv", None),
        "conv_bc_b": (None,),
        "A_log": ("heads",),
        "D": ("heads",),
        "dt_bias": ("heads",),
        "norm_w": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }
    return p, ax


def _causal_conv(x, w, b):
    """Depthwise causal conv, window K. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, B, C, A_log, D, chunk: int, use_kernel: bool = False):
    """SSD scan. x: (b, S, H, P); dt: (b, S, H); B, C: (b, S, N).
    Returns y: (b, S, H, P) and final state (b, H, N, P)."""
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    nc = S // chunk
    A = -jnp.exp(A_log)                                     # (H,)
    dt32 = dt.astype(jnp.float32)
    la = (dt32 * A).reshape(b, nc, chunk, H)                # log decay / step
    xr = x.reshape(b, nc, chunk, H, Pd)
    Br = B.reshape(b, nc, chunk, N).astype(jnp.float32)
    Cr = C.reshape(b, nc, chunk, N).astype(jnp.float32)
    dtr = dt32.reshape(b, nc, chunk, H)

    if use_kernel:
        from ..kernels.ssd_scan import ops as ssd_ops
        return ssd_ops.ssd_scan(xr, dtr, Br, Cr, la, D)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_fn(h, inp):
        # one chunk at a time: peak temp is (b,Q,Q,H) not (b,nc,Q,Q,H)
        la_c, x_c, b_c, c_c, dt_c = inp            # (b,Q,H) (b,Q,H,P) (b,Q,N)...
        lcum = jnp.cumsum(la_c, axis=1)            # (b,Q,H)
        seg = lcum[:, :, None, :] - lcum[:, None, :, :]      # (b,Q,Q,H)
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)            # (b,Q,Q)
        w = cb[..., None] * L                                # (b,Q,Q,H)
        xdt = x_c.astype(jnp.float32) * dt_c[..., None]      # (b,Q,H,P)
        y = jnp.einsum("bijh,bjhp->bihp", w, xdt)            # intra-chunk
        y = y + jnp.einsum("bin,bhnp->bihp", c_c, h) * \
            jnp.exp(lcum)[..., None]                         # inter-chunk
        decay_to_end = jnp.exp(lcum[:, -1:, :] - lcum)       # (b,Q,H)
        s_c = jnp.einsum("bjn,bjhp->bhnp", b_c, xdt * decay_to_end[..., None])
        h_new = h * jnp.exp(lcum[:, -1, :])[..., None, None] + s_c
        return h_new, y

    h0 = jnp.zeros((b, H, N, Pd), jnp.float32)
    xs = (jnp.moveaxis(la, 1, 0), jnp.moveaxis(xr, 1, 0),
          jnp.moveaxis(Br, 1, 0), jnp.moveaxis(Cr, 1, 0),
          jnp.moveaxis(dtr, 1, 0))
    h_last, ys = jax.lax.scan(scan_fn, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype).reshape(b, S, H, Pd)
    y = y + (D[:, None] * x.astype(jnp.float32)).astype(x.dtype)
    return y, h_last


class MambaCache(NamedTuple):
    conv_x: jax.Array   # (B, K-1, d_inner) last inputs to the x conv
    conv_bc: jax.Array  # (B, K-1, 2N)
    h: jax.Array        # (B, H, N, P) SSM state

    @staticmethod
    def init(batch, d_model, *, expand=2, headdim=64, ssm_state=128,
             conv_dim=4, dtype=jnp.bfloat16):
        d_inner = expand * d_model
        H = d_inner // headdim
        return MambaCache(
            conv_x=jnp.zeros((batch, conv_dim - 1, d_inner), dtype),
            conv_bc=jnp.zeros((batch, conv_dim - 1, 2 * ssm_state), dtype),
            h=jnp.zeros((batch, H, ssm_state, headdim), jnp.float32),
        )


def mamba2_forward(p, u, *, chunk=256, use_kernel=False):
    """u: (B, S, D) -> (B, S, D); returns (out, final_state)."""
    Bsz, S, Dm = u.shape
    d_inner = p["out_proj"].shape[0]
    H = p["A_log"].shape[0]
    Pd = d_inner // H
    N = p["in_bc"].shape[1] // 2
    z = jnp.einsum("bsd,de->bse", u, p["in_z"])
    x = jnp.einsum("bsd,de->bse", u, p["in_x"])
    bc = jnp.einsum("bsd,de->bse", u, p["in_bc"])
    dt = jnp.einsum("bsd,dh->bsh", u, p["in_dt"])
    x = _causal_conv(x, p["conv_x"], p["conv_x_b"]).reshape(Bsz, S, H, Pd)
    bc = _causal_conv(bc, p["conv_bc"], p["conv_bc_b"])
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, h_last = ssd_chunked(x, dt, Bm, Cm, p["A_log"], p["D"], chunk,
                            use_kernel=use_kernel)
    y = y.reshape(Bsz, S, d_inner)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), h_last


def mamba2_decode(p, u, cache: MambaCache):
    """u: (B, D) single token. Returns (out (B, D), new cache)."""
    Bsz, Dm = u.shape
    d_inner = p["out_proj"].shape[0]
    H = p["A_log"].shape[0]
    Pd = d_inner // H
    N = p["in_bc"].shape[1] // 2
    z = jnp.einsum("bd,de->be", u, p["in_z"])
    x = jnp.einsum("bd,de->be", u, p["in_x"])
    bc = jnp.einsum("bd,de->be", u, p["in_bc"])
    dt = jnp.einsum("bd,dh->bh", u, p["in_dt"])
    # causal conv over (cached K-1 inputs, current token)
    wx = jnp.concatenate([cache.conv_x, x[:, None, :]], axis=1)   # (B,K,C)
    x = jax.nn.silu(jnp.einsum("bkc,kc->bc", wx, p["conv_x"]) + p["conv_x_b"])
    wbc = jnp.concatenate([cache.conv_bc, bc[:, None, :]], axis=1)
    bc = jax.nn.silu(jnp.einsum("bkc,kc->bc", wbc, p["conv_bc"]) + p["conv_bc_b"])
    x = x.reshape(Bsz, H, Pd).astype(jnp.float32)
    Bm = bc[..., :N].astype(jnp.float32)
    Cm = bc[..., N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                          # (B,H)
    xdt = x * dt[..., None]                                          # (B,H,P)
    h_new = cache.h * decay[..., None, None] + \
        jnp.einsum("bn,bhp->bhnp", Bm, xdt)
    y = jnp.einsum("bn,bhnp->bhp", Cm, h_new) + p["D"][:, None] * x
    y = y.reshape(Bsz, d_inner).astype(u.dtype)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    return jnp.einsum("be,ed->bd", y, p["out_proj"]), \
        MambaCache(wx[:, 1:, :], wbc[:, 1:, :], h_new)
