"""Uniform model facade: init / loss / prefill / decode_step for every
assigned architecture family.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed import shard_activation
from .layers import (_init, embed_init, pad_vocab, rmsnorm, rmsnorm_init,
                     softmax_xent)
from .mamba2 import MambaCache, mamba2_decode, mamba2_forward, mamba2_init
from .transformer import (DecodeState, transformer_decode_step,
                          transformer_init, transformer_loss,
                          transformer_prefill)
from .zamba2 import (HybridState, zamba2_decode_step, zamba2_forward,
                     zamba2_init, zamba2_init_state)


# --------------------------------------------------------------------------
# Pure-SSM LM (mamba2-2.7b)
# --------------------------------------------------------------------------
def ssm_init(rng, cfg):
    dtype = cfg.dtype
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    vpad = pad_vocab(cfg.vocab_size)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embed_init(k_emb, vpad, cfg.d_model, dtype)
    lkeys = jax.random.split(k_layers, cfg.n_layers)

    def one(k):
        p, _ = mamba2_init(k, cfg.d_model, expand=cfg.ssm_expand,
                           headdim=cfg.ssm_headdim, ssm_state=cfg.ssm_state,
                           dtype=dtype)
        p["ln"], _ = rmsnorm_init(cfg.d_model)
        return p

    _, ax0 = mamba2_init(jax.random.PRNGKey(0), cfg.d_model,
                         expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                         ssm_state=cfg.ssm_state, dtype=dtype)
    ax0["ln"] = ("norm",)
    params["layers"] = jax.vmap(one)(lkeys)
    axes["layers"] = jax.tree.map(lambda t: ("layers",) + t, ax0,
                                  is_leaf=lambda x: isinstance(x, tuple))
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = _init(k_head, (cfg.d_model, vpad),
                               1.0 / math.sqrt(cfg.d_model), dtype)
        axes["head"] = ("embed", "vocab")
    return params, axes


def _ssm_backbone(params, cfg, h):
    def body(hh, lp):
        hh = shard_activation(hh)
        out, _ = mamba2_forward(lp, rmsnorm(hh, lp["ln"], cfg.norm_eps),
                                chunk=cfg.ssm_chunk,
                                use_kernel=cfg.use_ssd_kernel)
        return hh + out, None
    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return h


def _lm_logits(params, cfg, h):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, params["embed"])
    return jnp.einsum("...d,dv->...v", h, params["head"])


def ssm_loss(params, cfg, batch):
    h = shard_activation(jnp.take(params["embed"], batch["tokens"], axis=0))
    h = _ssm_backbone(params, cfg, h)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return softmax_xent(_lm_logits(params, cfg, h), batch["targets"],
                        cfg.vocab_size)


class SSMState(NamedTuple):
    caches: MambaCache  # stacked (L, ...)
    pos: jax.Array


def ssm_prefill(params, cfg, batch, cache_len):
    h = shard_activation(jnp.take(params["embed"], batch["tokens"], axis=0))
    B = h.shape[0]

    def body(hh, lp):
        hh = shard_activation(hh)
        out, h_last = mamba2_forward(lp, rmsnorm(hh, lp["ln"], cfg.norm_eps),
                                     chunk=cfg.ssm_chunk,
                                     use_kernel=cfg.use_ssd_kernel)
        return hh + out, h_last
    if cfg.remat:
        body = jax.checkpoint(body)
    h, h_states = jax.lax.scan(body, h, params["layers"])
    # conv cache: last K-1 conv inputs must be reconstructed; prefill-then-
    # decode uses the final tokens' activations — recompute cheaply by
    # initialising conv cache to zeros (decode continues with fresh conv
    # window; a 3-token warmup suffices in practice and is noted in DESIGN).
    base = MambaCache.init(B, cfg.d_model, expand=cfg.ssm_expand,
                           headdim=cfg.ssm_headdim, ssm_state=cfg.ssm_state,
                           dtype=cfg.dtype)
    conv_x = jnp.broadcast_to(base.conv_x,
                              (cfg.n_layers,) + base.conv_x.shape)
    conv_bc = jnp.broadcast_to(base.conv_bc,
                               (cfg.n_layers,) + base.conv_bc.shape)
    caches = MambaCache(conv_x=conv_x, conv_bc=conv_bc, h=h_states)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, h[:, -1])
    S = batch["tokens"].shape[1]
    return logits, SSMState(caches, jnp.asarray(S, jnp.int32))


def ssm_decode_step(params, cfg, state: SSMState, tokens):
    h = shard_activation(jnp.take(params["embed"], tokens, axis=0))

    def body(hh, xs):
        lp, cache = xs
        out, nc = mamba2_decode(lp, rmsnorm(hh, lp["ln"], cfg.norm_eps),
                                MambaCache(*cache))
        return hh + out, tuple(nc)

    h, new = jax.lax.scan(body, h, (params["layers"], tuple(state.caches)))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return _lm_logits(params, cfg, h), SSMState(MambaCache(*new), state.pos + 1)


# --------------------------------------------------------------------------
# Hybrid (zamba2)
# --------------------------------------------------------------------------
def hybrid_loss(params, cfg, batch):
    h = shard_activation(jnp.take(params["embed"], batch["tokens"], axis=0))
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = zamba2_forward(params, cfg, h, positions)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return softmax_xent(_lm_logits(params, cfg, h), batch["targets"],
                        cfg.vocab_size)


def hybrid_prefill(params, cfg, batch, cache_len):
    # prefill = forward + decode-state seeding; for the dry-run we seed the
    # state by running the last token through a decode step after forward.
    h = shard_activation(jnp.take(params["embed"], batch["tokens"], axis=0))
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    hf = zamba2_forward(params, cfg, h, positions)
    hf = rmsnorm(hf, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, hf[:, -1])
    state = zamba2_init_state(cfg, B, cache_len, cfg.dtype)
    return logits, state


def hybrid_decode_step(params, cfg, state, tokens):
    h = shard_activation(jnp.take(params["embed"], tokens, axis=0))
    h, new_state = zamba2_decode_step(params, cfg, state, h)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return _lm_logits(params, cfg, h), new_state


# --------------------------------------------------------------------------
# Facade
# --------------------------------------------------------------------------
class Model:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, rng):
        f = self.cfg.family
        if f == "ssm":
            return ssm_init(rng, self.cfg)
        if f == "hybrid":
            return zamba2_init(rng, self.cfg)
        return transformer_init(rng, self.cfg)

    def loss(self, params, batch):
        f = self.cfg.family
        if f == "ssm":
            return ssm_loss(params, self.cfg, batch)
        if f == "hybrid":
            return hybrid_loss(params, self.cfg, batch)
        return transformer_loss(params, self.cfg, batch)

    def prefill(self, params, batch, cache_len):
        f = self.cfg.family
        if f == "ssm":
            return ssm_prefill(params, self.cfg, batch, cache_len)
        if f == "hybrid":
            return hybrid_prefill(params, self.cfg, batch, cache_len)
        return transformer_prefill(params, self.cfg, batch, cache_len)

    def decode_step(self, params, state, tokens):
        f = self.cfg.family
        if f == "ssm":
            return ssm_decode_step(params, self.cfg, state, tokens)
        if f == "hybrid":
            return hybrid_decode_step(params, self.cfg, state, tokens)
        return transformer_decode_step(params, self.cfg, state, tokens)

    def encode(self, params, batch):
        """Encoder-only forward: logits over the whole sequence (hubert)."""
        from .transformer import _embed_inputs, _logits, _scan_layers
        cfg = self.cfg
        h = _embed_inputs(params, cfg, batch)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, _ = _scan_layers(params, cfg, h, positions)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, cfg, h)

    def decode_state_axes(self):
        """Logical-axes tree matching init_decode_state's structure."""
        cfg = self.cfg
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        sp = ("layers", "kv_seq")
        mamba = MambaCache(conv_x=("layers", "batch", "conv", "mlp"),
                           conv_bc=("layers", "batch", "conv", None),
                           h=("layers", "batch", "heads", "state", "head_dim"))
        if cfg.family == "ssm":
            return SSMState(caches=mamba, pos=())
        if cfg.family == "hybrid":
            from .zamba2 import HybridState as HS
            from .attention import KVCache as KC
            return HS(mamba=mamba, attn=KC(k=kv, v=kv, slot_pos=sp), pos=())
        from .attention import KVCache as KC
        return DecodeState(caches=KC(k=kv, v=kv, slot_pos=sp), pos=())

    def init_decode_state(self, batch, cache_len):
        """Decode-state pytree (for dry-run ShapeDtypeStructs)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            base = MambaCache.init(batch, cfg.d_model, expand=cfg.ssm_expand,
                                   headdim=cfg.ssm_headdim,
                                   ssm_state=cfg.ssm_state, dtype=cfg.dtype)
            caches = MambaCache(*jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                tuple(base)))
            return SSMState(caches, jnp.asarray(cache_len, jnp.int32))
        if cfg.family == "hybrid":
            st = zamba2_init_state(cfg, batch, cache_len, cfg.dtype)
            return HybridState(st.mamba, st.attn,
                               jnp.asarray(cache_len, jnp.int32))
        from .transformer import init_cache
        caches = init_cache(cfg, batch, cache_len, cfg.dtype)
        return DecodeState(caches, jnp.asarray(cache_len, jnp.int32))
