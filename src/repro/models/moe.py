"""Mixture-of-Experts FFN (mixtral-style top-k routed + qwen-style shared
experts) with sort-based token dispatch and capacity dropping.

Dispatch runs *locally per data shard* under shard_map so the token sort
never becomes a global collective; the only cross-device communication is
the tensor-parallel psum of the down-projection (contracting dim sharded
over "model"). When no mesh context is active (CPU smoke tests) the same
function runs unpartitioned.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map_no_check
from ..distributed import current_mesh, batch_axes
from ..distributed.sharding import current_rules
from .layers import _init, mlp_init, mlp_apply


def moe_init(rng, d_model, moe_d_ff, n_experts, dtype, shared_d_ff=0):
    ks = jax.random.split(rng, 5)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "router": _init(ks[0], (d_model, n_experts), s, jnp.float32),
        "gate": _init(ks[1], (n_experts, d_model, moe_d_ff), s, dtype),
        "up": _init(ks[2], (n_experts, d_model, moe_d_ff), s, dtype),
        "down": _init(ks[3], (n_experts, moe_d_ff, d_model),
                      1.0 / math.sqrt(moe_d_ff), dtype),
    }
    ax = {
        "router": ("embed", "experts"),
        "gate": ("experts", "embed", "mlp"),
        "up": ("experts", "embed", "mlp"),
        "down": ("experts", "mlp", "embed"),
    }
    if shared_d_ff:
        p["shared"], ax["shared"] = mlp_init(ks[4], d_model, shared_d_ff, dtype)
    return p, ax


def _dispatch_ffn(p, xt, n_top: int, capacity_factor: float, tp_axis):
    """xt: (T, D) local tokens. Returns (T, D). Runs inside shard_map (or
    unpartitioned when tp_axis is None)."""
    T, D = xt.shape
    E = p["router"].shape[1]
    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, n_top)                    # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    flat_e = topi.reshape(-1)                                   # (T*k,)
    flat_w = topv.reshape(-1)
    flat_t = jnp.arange(T * n_top, dtype=jnp.int32) // n_top
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=E)                     # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * n_top, dtype=jnp.int32) - starts[se]
    C = max(1, int(math.ceil(capacity_factor * T * n_top / E)))
    keep = rank < C
    dst = jnp.where(keep, se * C + rank, E * C)                 # drop row E*C
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[dst].set(xt[st])
    xe = buf[: E * C].reshape(E, C, D)
    g = jnp.einsum("ecd,edf->ecf", xe, p["gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["down"])
    contrib = jnp.concatenate(
        [ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)])[dst]
    contrib = contrib * (sw * keep)[:, None].astype(ye.dtype)
    y = jnp.zeros((T, D), ye.dtype).at[st].add(contrib)
    if tp_axis is not None:
        # TP reduction AFTER the scatter-back: psum the (T, D) output, not
        # the (E, C, D) dispatch buffer — k*capacity_factor*x less traffic
        # (everything between the partial down-proj and here is linear, so
        # the reordering is exact). Perf iteration 6, EXPERIMENTS.md §Perf.
        y = jax.lax.psum(y, tp_axis)
    # load-balance auxiliary loss (Switch-style), returned for logging
    frac_tokens = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32),
                           axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux


def moe_apply(p, x, *, n_top: int, capacity_factor: float = 1.25,
              batch_replicated: bool = False):
    """x: (B, S, D) -> (B, S, D). Shared experts (if present) are added."""
    B, S, D = x.shape
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        y, aux = _dispatch_ffn(p, x.reshape(B * S, D), n_top,
                               capacity_factor, None)
        y = y.reshape(B, S, D)
    else:
        rules = current_rules()
        bax = batch_axes(mesh, B)  # () when B doesn't divide -> replicate
        bax = bax if bax else None
        E, Dm, F = p["gate"].shape
        mlp_ax = rules.get("mlp")
        tp = mlp_ax if isinstance(mlp_ax, str) else None
        if not (tp and tp in mesh.shape and F % mesh.shape[tp] == 0):
            tp = None
        wspec = {
            "router": P(None, None),
            "gate": P(None, None, tp),
            "up": P(None, None, tp),
            "down": P(None, tp, None),
        }
        xspec = P(bax, None, None)

        def body(pw, xl):
            Bl, Sl, Dl = xl.shape
            yl, aux = _dispatch_ffn(pw, xl.reshape(Bl * Sl, Dl), n_top,
                                    capacity_factor, tp)
            if tp is None:
                # weights replicated over model: outputs identical; no psum
                pass
            return yl.reshape(Bl, Sl, Dl), aux

        pw = {k: p[k] for k in ("router", "gate", "up", "down")}
        y, aux = shard_map_no_check(
            body, mesh=mesh,
            in_specs=(wspec, xspec),
            out_specs=(xspec, P()),
        )(pw, x)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x)
    return y, aux
