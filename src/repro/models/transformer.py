"""Decoder/encoder transformer family: dense llama-style (GQA/MQA, optional
sliding window), MoE variants, encoder-only (hubert) and VLM (llava) whose
modality frontends are stubs feeding precomputed embeddings (per assignment).

All models scan over a stacked layer pytree; remat policy wraps the scan
body. Uniform entry points: init / loss / prefill / decode_step.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attn_init, cache_capacity, decode_attn,
                        multihead_attn)
from .layers import (_init, embed_init, mlp_apply, mlp_init, pad_vocab,
                     rmsnorm, rmsnorm_init, softmax_xent)
from .moe import moe_apply, moe_init
from ..distributed import shard_activation


def _head_dim(cfg):
    return getattr(cfg, "head_dim", 0) or cfg.d_model // cfg.n_heads


def block_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    p, ax = {}, {}
    p["ln1"], ax["ln1"] = rmsnorm_init(cfg.d_model)
    p["attn"], ax["attn"] = attn_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, _head_dim(cfg), dtype)
    p["ln2"], ax["ln2"] = rmsnorm_init(cfg.d_model)
    if cfg.n_experts:
        p["moe"], ax["moe"] = moe_init(
            k2, cfg.d_model, cfg.moe_d_ff, cfg.n_experts, dtype,
            shared_d_ff=cfg.shared_d_ff)
    else:
        p["mlp"], ax["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p, ax


def block_apply(p, h, cfg, positions, *, batch_replicated=False):
    a = multihead_attn(
        p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), positions,
        causal=cfg.causal, window=cfg.sliding_window,
        rope_theta=cfg.rope_theta, use_flash=cfg.use_flash)
    h = h + a
    ff_in = rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, aux = moe_apply(p["moe"], ff_in, n_top=cfg.n_experts_per_tok,
                            batch_replicated=batch_replicated)
    else:
        ff, aux = mlp_apply(p["mlp"], ff_in), 0.0
    return h + ff, aux


def transformer_init(rng, cfg):
    dtype = cfg.dtype
    vpad = pad_vocab(cfg.vocab_size)
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    params, axes = {}, {}
    if cfg.input_mode in ("tokens", "vlm"):
        params["embed"], axes["embed"] = embed_init(k_emb, vpad, cfg.d_model, dtype)
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    p0, ax0 = block_init(lkeys[0], cfg, dtype)
    params["layers"] = jax.vmap(lambda k: block_init(k, cfg, dtype)[0])(lkeys)
    axes["layers"] = jax.tree.map(lambda t: ("layers",) + t, ax0,
                                  is_leaf=lambda x: isinstance(x, tuple))
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = _init(k_head, (cfg.d_model, vpad),
                               1.0 / math.sqrt(cfg.d_model), dtype)
        axes["head"] = ("embed", "vocab")
    return params, axes


def _scan_layers(params, cfg, h, positions, *, batch_replicated=False):
    def body(carry, lp):
        hh, aux = carry
        hh = shard_activation(hh)   # anchor: batch over data axes
        hh, a = block_apply(lp, hh, cfg, positions,
                            batch_replicated=batch_replicated)
        return (hh, aux + a), None
    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll_layers:
        carry = (h, 0.0)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            carry, _ = body(carry, lp)
        h, aux = carry
        return h, aux
    (h, aux), _ = jax.lax.scan(body, (h, 0.0), params["layers"])
    return h, aux


def _logits(params, cfg, h):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, params["embed"])
    return jnp.einsum("...d,dv->...v", h, params["head"])


def _embed_inputs(params, cfg, batch):
    if cfg.input_mode == "tokens":
        return jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.input_mode == "embeds":            # encoder/audio frontend stub
        return batch["embeds"].astype(cfg.dtype)
    if cfg.input_mode == "vlm":               # vision stub + text tokens
        txt = jnp.take(params["embed"], batch["tokens"], axis=0)
        return jnp.concatenate([batch["vision_embeds"].astype(cfg.dtype), txt],
                               axis=1)
    raise ValueError(cfg.input_mode)


def transformer_loss(params, cfg, batch):
    h = shard_activation(_embed_inputs(params, cfg, batch))
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, aux = _scan_layers(params, cfg, h, positions)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.input_mode == "vlm":               # loss over the text tail only
        sv = batch["vision_embeds"].shape[1]
        h = h[:, sv:]
    logits = _logits(params, cfg, h)
    loss = softmax_xent(logits, batch["targets"], cfg.vocab_size)
    if cfg.n_experts:
        loss = loss + cfg.moe_aux_weight * aux / cfg.n_layers
    return loss


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------
class DecodeState(NamedTuple):
    caches: KVCache     # stacked (L, ...) leaves
    pos: jax.Array      # scalar int32: next position to write


def init_cache(cfg, batch, seq_len, dtype):
    cap = cache_capacity(seq_len, cfg.sliding_window)
    single = KVCache.init(batch, cap, cfg.n_kv_heads, _head_dim(cfg), dtype)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), single)
    return KVCache(*stacked)


def transformer_prefill(params, cfg, batch, cache_len):
    """Run the prompt, fill the KV cache. Returns (last logits, DecodeState)."""
    h = shard_activation(_embed_inputs(params, cfg, batch))
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    caches = init_cache(cfg, B, cache_len, cfg.dtype)

    cap = caches.k.shape[2]

    # reuse block_apply for hidden states; also emit each layer's K/V so the
    # cache is filled in the same pass
    def body2(carry, lp):
        hh = shard_activation(carry)
        x_n = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", x_n, lp["attn"]["k"])
        v = jnp.einsum("bsd,dhk->bshk", x_n, lp["attn"]["v"])
        from .layers import apply_rope
        k = apply_rope(k, positions, cfg.rope_theta)
        hh, _ = block_apply(lp, hh, cfg, positions)
        return hh, (k, v)

    if cfg.remat:
        body2 = jax.checkpoint(body2)
    if cfg.unroll_layers:
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, kv = body2(h, lp)
            kvs.append(kv)
        ks = jnp.stack([k for k, _ in kvs])
        vs = jnp.stack([v for _, v in kvs])
    else:
        h, (ks, vs) = jax.lax.scan(body2, h, params["layers"])
    # write the last `cap` positions into the cache (rolling for SWA)
    take = min(S, cap)
    ks, vs = ks[:, :, S - take:], vs[:, :, S - take:]
    slot0 = (S - take) % cap if cfg.sliding_window else 0
    # positions stored
    pos_ids = jnp.arange(S - take, S, dtype=jnp.int32)
    slots = (jnp.arange(take) + slot0) % cap if cfg.sliding_window \
        else jnp.arange(take)
    k_cache = caches.k.at[:, :, slots].set(ks)
    v_cache = caches.v.at[:, :, slots].set(vs)
    slot_pos = caches.slot_pos.at[:, slots].set(
        jnp.broadcast_to(pos_ids, (cfg.n_layers, take)))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1])
    state = DecodeState(KVCache(k_cache, v_cache, slot_pos),
                        jnp.asarray(S, jnp.int32))
    return logits, state


def transformer_decode_step(params, cfg, state: DecodeState, tokens):
    """tokens: (B,) int32. One decode step. Returns (logits, new state)."""
    h = shard_activation(jnp.take(params["embed"], tokens, axis=0))  # (B, D)
    pos = state.pos

    def body(carry, xs):
        hh = carry
        lp, cache = xs
        a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        a, new_cache = decode_attn(lp["attn"], a_in, cache, pos,
                                   window=cfg.sliding_window,
                                   rope_theta=cfg.rope_theta)
        hh = hh + a
        ff_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            ff, _ = moe_apply(lp["moe"], ff_in[:, None], n_top=cfg.n_experts_per_tok,
                              batch_replicated=cfg.decode_batch_replicated)
            ff = ff[:, 0]
        else:
            ff = mlp_apply(lp["mlp"], ff_in)
        return hh + ff, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["layers"], state.caches))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h)
    return logits, DecodeState(new_caches, pos + 1)
