"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* attention block
(one set of weights) applied every ``attn_every`` layers (arXiv:2411.15242).
The shared block attends over concat(hidden, initial_embedding) — the Zamba
trick that lets one block serve many depths. Per-application LoRA deltas are
omitted (noted in DESIGN.md).

Layers are statically segmented (python loop over attention sites, lax.scan
within each segment) so the HLO contains exactly n_sites attention blocks —
keeps cost_analysis faithful for the roofline.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed import shard_activation
from .attention import KVCache, decode_attn, multihead_attn
from .layers import _init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init
from .mamba2 import MambaCache, mamba2_decode, mamba2_forward, mamba2_init


def _sites(cfg) -> list[int]:
    return list(range(0, cfg.n_layers, cfg.attn_every))


def shared_block_init(rng, cfg, dtype):
    D, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = D // H
    ks = jax.random.split(rng, 5)
    s = 1.0 / math.sqrt(2 * D)
    p = {
        "ln1": jnp.ones((2 * D,), jnp.float32),
        "q": _init(ks[0], (2 * D, H, hd), s, dtype),
        "k": _init(ks[1], (2 * D, KV, hd), s, dtype),
        "v": _init(ks[2], (2 * D, KV, hd), s, dtype),
        "o": _init(ks[3], (H, hd, D), 1.0 / math.sqrt(H * hd), dtype),
    }
    ax = {
        "ln1": ("norm",),
        "q": ("embed", "heads", "head_dim"),
        "k": ("embed", "kv_heads", "head_dim"),
        "v": ("embed", "kv_heads", "head_dim"),
        "o": ("heads", "head_dim", "embed"),
    }
    p["ln2"], ax["ln2"] = rmsnorm_init(cfg.d_model)
    p["mlp"], ax["mlp"] = mlp_init(ks[4], cfg.d_model, cfg.d_ff, dtype)
    return p, ax


def _shared_attn_full(p, h, h0, cfg, positions):
    xcat = jnp.concatenate([h, h0], axis=-1)
    a_in = rmsnorm(xcat, p["ln1"], cfg.norm_eps)
    attn_p = {k: p[k] for k in ("q", "k", "v", "o")}
    a = multihead_attn(attn_p, a_in, positions, causal=True,
                       window=cfg.sliding_window, rope_theta=cfg.rope_theta,
                       use_flash=cfg.use_flash)
    h = h + a
    h = h + mlp_apply(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h


def _shared_attn_step(p, h, h0, cfg, cache, pos):
    xcat = jnp.concatenate([h, h0], axis=-1)      # (B, 2D)
    a_in = rmsnorm(xcat, p["ln1"], cfg.norm_eps)
    attn_p = {k: p[k] for k in ("q", "k", "v", "o")}
    a, new_cache = decode_attn(attn_p, a_in, cache, pos,
                               window=cfg.sliding_window,
                               rope_theta=cfg.rope_theta)
    h = h + a
    h = h + mlp_apply(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h, new_cache


def zamba2_init(rng, cfg):
    from .layers import embed_init, pad_vocab
    dtype = cfg.dtype
    k_emb, k_m, k_a, k_h = jax.random.split(rng, 4)
    vpad = pad_vocab(cfg.vocab_size)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embed_init(k_emb, vpad, cfg.d_model, dtype)
    lkeys = jax.random.split(k_m, cfg.n_layers)

    def one(k):
        kk1, kk2 = jax.random.split(k)
        p, _ = mamba2_init(kk1, cfg.d_model, expand=cfg.ssm_expand,
                           headdim=cfg.ssm_headdim, ssm_state=cfg.ssm_state,
                           dtype=dtype)
        p["ln"], _ = rmsnorm_init(cfg.d_model)
        return p

    _, ax0 = mamba2_init(jax.random.PRNGKey(0), cfg.d_model,
                         expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                         ssm_state=cfg.ssm_state, dtype=dtype)
    ax0["ln"] = ("norm",)
    params["mamba_layers"] = jax.vmap(one)(lkeys)
    axes["mamba_layers"] = jax.tree.map(
        lambda t: ("layers",) + t, ax0, is_leaf=lambda x: isinstance(x, tuple))
    params["shared"], axes["shared"] = shared_block_init(k_a, cfg, dtype)
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = _init(k_h, (cfg.d_model, vpad),
                               1.0 / math.sqrt(cfg.d_model), dtype)
        axes["head"] = ("embed", "vocab")
    return params, axes


def _segments(cfg):
    sites = _sites(cfg)
    segs = []
    for i, s in enumerate(sites):
        end = sites[i + 1] if i + 1 < len(sites) else cfg.n_layers
        segs.append((s, end))
    return segs


def _mamba_body(cfg):
    def body(h, lp):
        h = shard_activation(h)
        out, _ = mamba2_forward(lp, rmsnorm(h, lp["ln"], cfg.norm_eps),
                                chunk=cfg.ssm_chunk,
                                use_kernel=cfg.use_ssd_kernel)
        return h + out, None
    if cfg.remat:
        body = jax.checkpoint(body)
    return body


def zamba2_forward(params, cfg, h, positions):
    h = shard_activation(h)
    h0 = h
    body = _mamba_body(cfg)
    for lo, hi in _segments(cfg):
        h = _shared_attn_full(params["shared"], h, h0, cfg, positions)
        seg = jax.tree.map(lambda x: x[lo:hi], params["mamba_layers"])
        h, _ = jax.lax.scan(body, h, seg)
    return h


class HybridState(NamedTuple):
    mamba: MambaCache   # stacked (L, ...)
    attn: KVCache       # stacked (n_sites, ...)
    pos: jax.Array


def zamba2_init_state(cfg, batch, cache_len, dtype):
    from .attention import cache_capacity
    n_sites = len(_sites(cfg))
    m = MambaCache.init(batch, cfg.d_model, expand=cfg.ssm_expand,
                        headdim=cfg.ssm_headdim, ssm_state=cfg.ssm_state,
                        dtype=dtype)
    m = MambaCache(*jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), m))
    cap = cache_capacity(cache_len, cfg.sliding_window)
    a = KVCache.init(batch, cap, cfg.n_kv_heads, cfg.d_model // cfg.n_heads,
                     dtype)
    a = KVCache(*jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_sites,) + x.shape), a))
    return HybridState(m, a, jnp.asarray(0, jnp.int32))


def zamba2_decode_step(params, cfg, state: HybridState, h):
    """h: (B, D) embedded token. Returns (h_out, new state)."""
    h0 = h
    pos = state.pos
    mcaches, acaches = state.mamba, state.attn

    def mstep(h, lp, cache):
        out, new_cache = mamba2_decode(
            lp, rmsnorm(h, lp["ln"], cfg.norm_eps), cache)
        return h + out, new_cache

    for si, (lo, hi) in enumerate(_segments(cfg)):
        site_cache = jax.tree.map(lambda x: x[si], acaches)
        h, new_site = _shared_attn_step(params["shared"], h, h0, cfg,
                                        KVCache(*site_cache), pos)
        acaches = KVCache(*jax.tree.map(
            lambda full, new: full.at[si].set(new), tuple(acaches),
            tuple(new_site)))
        seg_p = jax.tree.map(lambda x: x[lo:hi], params["mamba_layers"])
        seg_c = jax.tree.map(lambda x: x[lo:hi], mcaches)

        def sbody(carry, xs):
            lp, cache = xs
            hh, nc = mstep(carry, lp, MambaCache(*cache))
            return hh, tuple(nc)
        h, new_seg = jax.lax.scan(sbody, h, (seg_p, tuple(seg_c)))
        mcaches = MambaCache(*jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                full, new, lo, axis=0), tuple(mcaches), new_seg))
    return h, HybridState(mcaches, acaches, pos + 1)
