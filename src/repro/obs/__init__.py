"""Unified I/O observability: event tracing, per-tier metrics timelines,
and wait-state attribution (docs/observability.md).

Enable per-runtime with ``IORuntime(cluster, trace=True)`` (or pass a
:class:`TraceConfig` / prebuilt :class:`TraceRecorder`), then read
``rt.trace()`` / ``rt.stats()["wait_states"]``. The ``python -m
repro.trace`` CLI instead sets :data:`FORCE`, which turns tracing on for
every runtime a script constructs and registers it here — the same
hijack pattern ``repro.lint`` uses for capture mode.
"""
from __future__ import annotations

from .recorder import (EVENT_SCHEMA, WAIT_STATES, MetricsTimeline,
                       TraceConfig, TraceRecorder)
from .telemetry import (TelemetryHub, apply_tier_config, fit_samples,
                        fit_tiers)
from . import compare, perfetto, report

#: When true, every IORuntime constructed enables tracing and registers
#: its recorder in RUNS (set only by the ``repro.trace`` CLI driver).
FORCE = False

#: ``(label, runtime)`` pairs registered while FORCE was on.
RUNS: list = []

#: Backend-substitution hook (set only by the ``repro.compare`` CLI
#: driver): a callable ``(cluster, requested_backend) -> Backend | None``
#: consulted by every IORuntime at construction. Returning a backend
#: swaps it in (the sim-vs-real harness runs the same unmodified script
#: once under SimBackend and once under RealBackend(tier_dirs=));
#: returning None keeps the script's own choice. Capture mode (the lint
#: hijack) always wins — a static analysis must never execute bodies.
FORCE_BACKEND = None


def register(runtime) -> None:
    RUNS.append((f"runtime-{len(RUNS) + 1}", runtime))


__all__ = [
    "EVENT_SCHEMA", "WAIT_STATES", "MetricsTimeline", "TraceConfig",
    "TraceRecorder", "TelemetryHub", "apply_tier_config", "fit_samples",
    "fit_tiers", "compare", "perfetto", "report", "FORCE", "RUNS",
    "FORCE_BACKEND", "register",
]
