"""Unified I/O observability: event tracing, per-tier metrics timelines,
and wait-state attribution (docs/observability.md).

Enable per-runtime with ``IORuntime(cluster, trace=True)`` (or pass a
:class:`TraceConfig` / prebuilt :class:`TraceRecorder`), then read
``rt.trace()`` / ``rt.stats()["wait_states"]``. The ``python -m
repro.trace`` CLI instead sets :data:`FORCE`, which turns tracing on for
every runtime a script constructs and registers it here — the same
hijack pattern ``repro.lint`` uses for capture mode.
"""
from __future__ import annotations

from .recorder import (EVENT_SCHEMA, WAIT_STATES, MetricsTimeline,
                       TraceConfig, TraceRecorder)
from . import perfetto, report

#: When true, every IORuntime constructed enables tracing and registers
#: its recorder in RUNS (set only by the ``repro.trace`` CLI driver).
FORCE = False

#: ``(label, runtime)`` pairs registered while FORCE was on.
RUNS: list = []


def register(runtime) -> None:
    RUNS.append((f"runtime-{len(RUNS) + 1}", runtime))


__all__ = [
    "EVENT_SCHEMA", "WAIT_STATES", "MetricsTimeline", "TraceConfig",
    "TraceRecorder", "perfetto", "report", "FORCE", "RUNS", "register",
]
