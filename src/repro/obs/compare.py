"""Sim-vs-real validation: align two runs of the same DAG and report how
far the simulator's congestion model is from measured reality.

The harness (``python -m repro.compare <script>`` or
``benchmarks/sim_vs_real.py``) runs the same task graph once under
``SimBackend`` (predicted durations from the modelled
:class:`StorageDevice` parameters) and once under
``RealBackend(tier_dirs=)`` (measured wall times + TelemetryHub
samples). This module pairs the two completed-task populations, computes
the per-task / per-signature / per-tier / per-device model error, and —
together with :func:`repro.obs.telemetry.fit_tiers` — produces the
calibration report (fitted vs configured bandwidth per tier) that a
``--fit`` re-run feeds back into the simulator.

Alignment: task ids are assigned in submission order, so for an
identical DAG the Nth submitted task of a signature in the sim run *is*
the Nth submitted task of that signature in the real run — pairing is by
``(signature, per-signature submission rank)``, robust to the two
backends finishing work in different orders.
"""
from __future__ import annotations

from typing import Optional

from .telemetry import fit_tiers


def measured_duration(task) -> float:
    """The duration a real task actually took: the final successful
    attempt's wall time when the backend measured it, else end - start."""
    if task.measured_duration is not None:
        return task.measured_duration
    return task.duration


def _by_signature(rt) -> dict:
    groups: dict[str, list] = {}
    for t in sorted(rt.scheduler.completed, key=lambda t: t.tid):
        groups.setdefault(t.defn.signature, []).append(t)
    return groups


def align_tasks(sim_rt, real_rt) -> list:
    """``(sim_task, real_task)`` pairs by (signature, submission rank)."""
    sim_g, real_g = _by_signature(sim_rt), _by_signature(real_rt)
    pairs = []
    for sig in sim_g:
        pairs.extend(zip(sim_g[sig], real_g.get(sig, [])))
    return pairs


def _median(vals: list) -> Optional[float]:
    if not vals:
        return None
    s = sorted(vals)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def duration_error_report(sim_rt, real_rt, min_wall_s: float = 1e-6) -> dict:
    """Per-task model error (predicted vs measured duration) with
    per-signature / per-tier / per-device aggregates.

    ``rel_err = (predicted - measured) / measured`` — positive means the
    model over-estimates (sim slower than reality). The headline
    ``median_abs_rel_error`` covers I/O tasks placed on a device (the
    population the congestion model actually predicts);
    ``median_abs_rel_error_all`` includes compute tasks too."""
    rows = []
    for s, r in align_tasks(sim_rt, real_rt):
        meas = measured_duration(r)
        if meas < min_wall_s:
            meas = min_wall_s
        pred = s.duration
        rel = (pred - meas) / meas
        rows.append({
            "sig": s.defn.signature,
            "tid_sim": s.tid,
            "tid_real": r.tid,
            "predicted_s": pred,
            "measured_s": meas,
            "rel_err": rel,
            "abs_rel_err": abs(rel),
            "is_io": s.is_io,
            "device": s.device.name if s.device is not None else None,
            "tier": s.device.tier if s.device is not None else None,
        })

    def agg(key) -> dict:
        out: dict = {}
        for row in rows:
            k = row[key]
            if k is None:
                continue
            out.setdefault(k, []).append(row["abs_rel_err"])
        return {k: {"n": len(v), "median_abs_rel_err": _median(v)}
                for k, v in sorted(out.items())}

    io_errs = [r["abs_rel_err"] for r in rows
               if r["is_io"] and r["device"] is not None]
    return {
        "n_pairs": len(rows),
        "n_io_pairs": len(io_errs),
        "tasks": rows,
        "by_signature": agg("sig"),
        "by_tier": agg("tier"),
        "by_device": agg("device"),
        "median_abs_rel_error": _median(io_errs),
        "median_abs_rel_error_all": _median(
            [r["abs_rel_err"] for r in rows]),
    }


def tier_fit_report(real_rt, sim_cluster) -> dict:
    """Fitted-vs-configured congestion parameters per tier: what the real
    run measured (TelemetryHub fit) against what the sim cluster's
    :class:`StorageDevice` objects assume."""
    hub = getattr(real_rt.backend, "telemetry", None)
    fitted = fit_tiers(hub) if hub is not None else {}
    configured: dict = {}
    for dev in sim_cluster.devices:
        cfg = configured.setdefault(dev.tier, {
            "bandwidth": dev.bandwidth,
            "per_stream_cap": dev.per_stream_cap,
            "congestion_alpha": dev.congestion_alpha,
        })
        # several devices per tier share the spec by construction; keep
        # the first seen
        del cfg
    out = {}
    for tier in sorted(set(fitted) | set(configured)):
        f, c = fitted.get(tier), configured.get(tier)
        entry: dict = {"fitted": f, "configured": c}
        if f and c and c["bandwidth"] > 0:
            entry["bandwidth_ratio"] = f["bandwidth"] / c["bandwidth"]
        out[tier] = entry
    return out


def format_report(rep: dict, fit: Optional[dict] = None) -> str:
    """Human-readable rendering of a duration-error report (+ optional
    tier-fit report) for the CLI."""
    lines = []
    med = rep["median_abs_rel_error"]
    lines.append(
        f"paired tasks: {rep['n_pairs']} ({rep['n_io_pairs']} I/O)")
    lines.append(
        "median |rel err|: "
        + (f"{med:.3g}" if med is not None else "n/a (no I/O pairs)")
        + f" (all tasks: {rep['median_abs_rel_error_all']:.3g})")
    if rep["by_tier"]:
        lines.append("per tier:")
        for tier, a in rep["by_tier"].items():
            lines.append(f"  {tier:<6} n={a['n']:<4} "
                         f"median |rel err|={a['median_abs_rel_err']:.3g}")
    if fit:
        lines.append("fitted vs configured (per tier):")
        for tier, entry in fit.items():
            f, c = entry.get("fitted"), entry.get("configured")
            if f and c:
                lines.append(
                    f"  {tier:<6} bandwidth {f['bandwidth']:.1f} MB/s "
                    f"(configured {c['bandwidth']:.1f}), per-stream "
                    f"{f['per_stream_cap']:.1f} "
                    f"(configured {c['per_stream_cap']:.1f}), "
                    f"alpha {f['congestion_alpha']:.4f}")
            elif c:
                lines.append(f"  {tier:<6} no measured samples "
                             f"(configured {c['bandwidth']:.1f} MB/s)")
    return "\n".join(lines)
