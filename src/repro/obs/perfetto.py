"""Chrome trace-event (Perfetto) export for a :class:`TraceRecorder`.

Layout (one process per track group, ``ui.perfetto.dev`` renders each as
a collapsible track):

* pid 1 — **tasks**: one thread per worker, "X" complete events for every
  task attempt; data movers (``tier_drain`` / ``tier_prefetch`` /
  ``lineage_recover`` signatures) additionally emit "b"/"e" async spans
  on their device's pid so transfers line up with tier state.
* pid 2 — **requests**: async spans recorded via ``recorder.span`` (the
  serve loop's admission -> first-token -> finish windows) and checkpoint
  save/wait/restore phases.
* pid 10+k — one per **device**, named ``tier:<tier> <device>``: burst
  "b"/"e" async spans, health-transition and eviction "i" instants, and
  "C" counter tracks from the metrics timeline (allocated vs background
  bandwidth, occupancy, active streams).

Timestamps are microseconds (recorder seconds x 1e6). All ids derive from
deterministic counters and the dump sorts keys, so a seeded sim run
exports byte-identical JSON (pinned by tests/test_obs.py).
"""
from __future__ import annotations

import json


def _us(t: float) -> float:
    return round(float(t) * 1e6, 3)


def to_perfetto(recorder) -> dict:
    """Build the ``{"traceEvents": [...]}`` document for ``recorder``."""
    events = list(recorder.events)
    out: list[dict] = []

    def meta(pid: int, name: str) -> None:
        out.append({"ph": "M", "pid": pid, "tid": 0,
                    "name": "process_name", "args": {"name": name}})

    meta(1, "tasks")
    meta(2, "requests")

    # stable pid/tid assignment in first-seen order
    device_pid: dict[str, int] = {}
    worker_tid: dict[str, int] = {}

    def dev_pid(name: str, tier) -> int:
        pid = device_pid.get(name)
        if pid is None:
            pid = device_pid[name] = 10 + len(device_pid)
            meta(pid, f"tier:{tier or '-'} {name}")
        return pid

    def wtid(name: str) -> int:
        tid = worker_tid.get(name)
        if tid is None:
            tid = worker_tid[name] = 1 + len(worker_tid)
            out.append({"ph": "M", "pid": 1, "tid": tid,
                        "name": "thread_name", "args": {"name": name}})
        return tid

    # pre-register device pids from the timeline so counter tracks exist
    # even for devices that never appear in a discrete event
    for name in recorder.timeline.devices:
        dev_pid(name, recorder.timeline.device_tiers.get(name))

    open_launch: dict[int, dict] = {}   # tid -> launch event
    span_id = 0
    mover_sigs = ("tier_drain", "tier_prefetch", "lineage_recover")

    for ev in events:
        et = ev["type"]
        if et == "launch":
            open_launch[ev["tid"]] = ev
        elif et in ("complete", "retry"):
            la = open_launch.pop(ev["tid"], None)
            if la is None:
                continue
            dur = _us(ev["t"]) - _us(la["t"])
            args = {"tid": ev["tid"], "device": la["device"],
                    "tier": la["tier"], "bw": la["bw"],
                    "attempt": la["attempt"]}
            if et == "retry" or ev.get("failed"):
                args["failed"] = True
            out.append({"ph": "X", "pid": 1, "tid": wtid(la["worker"]),
                        "ts": _us(la["t"]), "dur": dur, "name": la["sig"],
                        "cat": "task", "args": args})
            sig = la["sig"]
            if la["device"] is not None and \
                    any(sig.startswith(m) for m in mover_sigs):
                pid = dev_pid(la["device"], la["tier"])
                span_id += 1
                base = {"pid": pid, "tid": 0, "cat": "mover",
                        "id": span_id, "name": sig}
                out.append({**base, "ph": "b", "ts": _us(la["t"]),
                            "args": args})
                out.append({**base, "ph": "e", "ts": _us(ev["t"])})
        elif et == "burst":
            pid = dev_pid(ev["device"], ev["tier"])
            base = {"pid": pid, "tid": 0, "cat": "burst",
                    "name": "background_burst"}
            if ev["phase"] == "start":
                span_id += 1
                out.append({**base, "ph": "b", "id": span_id,
                            "ts": _us(ev["t"]),
                            "args": {"streams": ev["streams"],
                                     "bw": ev["bw"],
                                     "capacity_mb": ev["capacity_mb"]}})
            else:
                out.append({**base, "ph": "e", "id": span_id,
                            "ts": _us(ev["t"])})
        elif et == "health":
            pid = dev_pid(ev["device"], None)
            out.append({"ph": "i", "pid": pid, "tid": 0, "s": "p",
                        "ts": _us(ev["t"]), "cat": "health",
                        "name": f"health:{ev['prev']}->{ev['state']}",
                        "args": {"prev": ev["prev"],
                                 "state": ev["state"]}})
        elif et == "evict":
            pid = dev_pid(ev["device"], ev["tier"])
            out.append({"ph": "i", "pid": pid, "tid": 0, "s": "p",
                        "ts": _us(ev["t"]), "cat": "evict",
                        "name": f"evict:{ev['mode']}",
                        "args": {"object": ev["name"],
                                 "mode": ev["mode"],
                                 "size_mb": ev["size_mb"]}})
        elif et == "ckpt":
            span_id += 1
            out.append({"ph": "i", "pid": 2, "tid": 0, "s": "g",
                        "ts": _us(ev["t"]), "cat": "ckpt",
                        "name": f"ckpt:{ev['phase']}",
                        "args": {"step": ev["step"], "mode": ev["mode"],
                                 "n_shards": ev["n_shards"]}})
        elif et == "telemetry":
            # measured real-backend throughput as counter tracks on the
            # device's pid, alongside the modelled bandwidth counters
            pid = dev_pid(ev["device"], ev["tier"])
            ts = _us(ev["t"])
            out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                        "name": "measured_mbs",
                        "args": {"window": ev["mbps"],
                                 "stream": ev["stream_mbps"]}})
            out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                        "name": "measured_inflight",
                        "args": {"inflight": ev["inflight"]}})
        elif et == "span":
            span_id += 1
            base = {"pid": 2, "tid": 0, "cat": ev["cat"],
                    "id": span_id, "name": ev["name"]}
            out.append({**base, "ph": "b", "ts": _us(ev["t"]),
                        "args": dict(ev["args"])})
            out.append({**base, "ph": "e",
                        "ts": _us(ev["t"] + ev["dur"])})

    # counter tracks from the metrics timeline
    for name in recorder.timeline.devices:
        pid = device_pid[name]
        for row in recorder.timeline.device_rows(name):
            ts = _us(row["t"])
            out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                        "name": "bandwidth_mbs",
                        "args": {"allocated": row["allocated_bw"],
                                 "background": row["background_bw"],
                                 "free": row["available_bw"]}})
            out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                        "name": "occupancy_mb",
                        "args": {"used": row["used_mb"],
                                 "reserved": row["reserved_mb"],
                                 "background": row["background_mb"]}})
            out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                        "name": "streams",
                        "args": {"tasks": row["active_io"],
                                 "background":
                                     row["background_streams"]}})
    for row in recorder.timeline.sched:
        out.append({"ph": "C", "pid": 1, "tid": 0, "ts": _us(row[0]),
                    "name": "scheduler",
                    "args": {"ready": row[1], "running": row[2],
                             "blocked_demand_mb": row[3]}})

    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs", "schema": 1}}


def dumps(recorder) -> str:
    """Deterministic (sorted-keys) JSON dump of the Perfetto document."""
    return json.dumps(to_perfetto(recorder), sort_keys=True,
                      separators=(",", ":"))
