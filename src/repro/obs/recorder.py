"""TraceRecorder: the unified observability event stream (docs/observability.md).

One recorder per :class:`~repro.core.runtime.IORuntime` (``trace=True``),
wired by the runtime into every existing event site: backend launch/
complete/retry and stuck-path steps, scheduler readiness + grant-refusal
diagnosis, datalife eviction/staging/pin lifecycle, interference burst
boundaries, failure-engine health transitions, and checkpoint
save/restore. It produces:

* a typed append-only **event stream** (:data:`EVENT_SCHEMA` is frozen —
  fields may be added under new event types, never removed or retyped);
* a per-device **metrics timeline** (:class:`MetricsTimeline`), sampled at
  the instants device state changes;
* a per-task **wait-state breakdown** (:data:`WAIT_STATES` taxonomy):
  dependency-wait, bandwidth-wait, capacity-blocked, failure-retry,
  running — plus the auxiliary executor/learning/offline/cpu states and an
  explicit unattributed/residual remainder, so every task's end-to-end
  latency is accounted for.

Design constraints (pinned by tests/test_obs.py):

* **inert when disabled** — every hook site guards on ``recorder is not
  None``; a disabled run costs one comparison per site and the launch log
  stays bit-identical (golden ``test_sched_scale`` is the proof);
* **pure reads** — recording never mutates scheduler/simulator state, so
  an *enabled* run is also bit-identical to a disabled one;
* **clock-agnostic** — timestamps come from the bound ``clock`` callable
  (``SimBackend.now`` = virtual seconds, ``RealBackend.now`` = monotonic
  seconds since backend start), never from ``time.*`` directly, so a
  seeded sim run exports a byte-identical trace every time;
* **thread-safe** — RealBackend completions arrive on worker threads; all
  mutators take the recorder's lock.
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Callable, Optional

_EPS = 1e-12

#: Frozen event catalog: event type -> required fields and their types.
#: ``t`` is seconds on the recorder's clock. New event types may be added;
#: existing fields are never removed or retyped (tests validate every
#: recorded event against this table).
EVENT_SCHEMA: dict[str, dict[str, tuple]] = {
    # task lifecycle (backends.py / runtime.py / scheduler.py)
    "submit":   {"t": (float,), "tid": (int,), "sig": (str,)},
    "ready":    {"t": (float,), "tid": (int,), "sig": (str,)},
    "launch":   {"t": (float,), "tid": (int,), "sig": (str,),
                 "worker": (str,), "device": (str, type(None)),
                 "tier": (str, type(None)), "bw": (float, int),
                 "attempt": (int,)},
    "complete": {"t": (float,), "tid": (int,), "sig": (str,),
                 "failed": (bool,)},
    "retry":    {"t": (float,), "tid": (int,), "sig": (str,),
                 "attempt": (int,)},
    # grant-refusal diagnosis (scheduler.py): why a ready class head could
    # not be placed at t (one event per reason *change* per class)
    "blocked":  {"t": (float,), "cls": (str,), "reason": (str,),
                 "device": (str, type(None)), "wanted_mb": (float, int)},
    # co-tenant burst boundaries (interference.py)
    "burst":    {"t": (float,), "device": (str,), "tier": (str, type(None)),
                 "phase": (str,), "streams": (int,), "bw": (float, int),
                 "capacity_mb": (float, int)},
    # device health transitions (failures.py)
    "health":   {"t": (float,), "device": (str,), "prev": (str,),
                 "state": (str,)},
    # data lifecycle (datalife.py): mode in {drop, discard, drain, lost}
    "evict":    {"t": (float,), "oid": (int,), "name": (str,),
                 "device": (str,), "tier": (str, type(None)),
                 "mode": (str,), "size_mb": (float, int)},
    "stage":    {"t": (float,), "oid": (int,), "name": (str,),
                 "tier": (str,), "size_mb": (float, int)},
    "pin":      {"t": (float,), "oid": (int,), "name": (str,),
                 "pinned": (bool,)},
    # checkpoint manager (checkpoint/manager.py): phase in
    # {save, wait, restore}; mode in {sync, flat, reroute, burst-buffer}
    "ckpt":     {"t": (float,), "phase": (str,), "step": (int,),
                 "mode": (str,), "n_shards": (int,)},
    # simulator stuck-path steps (backends.py): kind in {bg_step, fail_step}
    "stall":    {"t": (float,), "kind": (str,)},
    # measured real-backend throughput (obs/telemetry.py): one sample per
    # completed I/O op on a device — windowed aggregate MB/s, this op's
    # effective per-stream rate, queue depth after the completion. Never
    # appears in sim streams (the simulator has no TelemetryHub).
    "telemetry": {"t": (float,), "device": (str,),
                  "tier": (str, type(None)), "mbps": (float, int),
                  "stream_mbps": (float, int), "inflight": (int,),
                  "mb": (float, int), "wall_s": (float, int)},
    # generic async span (serve requests etc.): [t, t+dur]
    "span":     {"t": (float,), "name": (str,), "cat": (str,),
                 "dur": (float, int), "args": (dict,)},
}

#: Frozen wait-state taxonomy (docs/observability.md). The first five are
#: the paper-facing breakdown; the rest make the accounting exhaustive.
WAIT_STATES = (
    "dependency",     # submit -> first readiness (inputs not done)
    "bandwidth",      # ready, no device could allocate the storageBW
    "capacity",       # ready, output footprint does not fit any device
    "failure-retry",  # failed attempts' run time + requeue-to-relaunch
    "running",        # final successful attempt's execution
    "executor",       # ready, no free I/O executor on any candidate
    "learning",       # ready, waiting on a learning node / epoch admission
    "offline",        # ready, every eligible device offline
    "cpu",            # compute task waiting for computing units
    "unattributed",   # ready interval with no recorded refusal diagnosis
)


class TraceConfig:
    """Recorder knobs. ``timeline=False`` skips per-device sampling (the
    event stream and wait profile survive); ``waits=False`` skips the
    per-task attribution bookkeeping."""

    __slots__ = ("timeline", "waits")

    def __init__(self, timeline: bool = True, waits: bool = True):
        self.timeline = bool(timeline)
        self.waits = bool(waits)


class MetricsTimeline:
    """Per-device time series, sampled whenever a recorded event changes
    device state. One row per sample:

    ``(t, active_io, background_streams, allocated_bw, background_bw,
    available_bw, used_mb, reserved_mb, background_mb, occupancy_mb,
    health)``

    plus a scheduler series ``(t, n_ready, n_running, blocked_demand_mb)``
    (queue depth and capacity-blocked demand)."""

    ROW_FIELDS = ("t", "active_io", "background_streams", "allocated_bw",
                  "background_bw", "available_bw", "used_mb", "reserved_mb",
                  "background_mb", "occupancy_mb", "health")
    SCHED_FIELDS = ("t", "n_ready", "n_running", "blocked_demand_mb")
    #: measured telemetry is a SEPARATE per-device series (real runs only)
    #: so the modelled ROW_FIELDS schema above stays frozen
    TELEMETRY_FIELDS = ("t", "mbps", "stream_mbps", "inflight")

    def __init__(self):
        self.devices: dict[str, list[tuple]] = {}
        self.device_tiers: dict[str, Optional[str]] = {}
        self.sched: list[tuple] = []
        self.telemetry: dict[str, list[tuple]] = {}

    def sample_device(self, t: float, dev) -> None:
        rows = self.devices.get(dev.name)
        if rows is None:
            rows = self.devices[dev.name] = []
            self.device_tiers[dev.name] = dev.tier
        row = (t, dev.active_io, dev.background_streams,
               dev.bandwidth - dev.available_bw - dev.background_bw,
               dev.background_bw, dev.available_bw, dev.used_mb,
               dev.reserved_mb, dev.background_mb, dev.occupancy_mb,
               dev.health)
        if rows and rows[-1][0] == t:
            rows[-1] = row  # collapse same-instant samples to the latest
        else:
            rows.append(row)

    def sample_sched(self, t: float, n_ready: int, n_running: int,
                     blocked_mb: float) -> None:
        row = (t, n_ready, n_running, blocked_mb)
        if self.sched and self.sched[-1][0] == t:
            self.sched[-1] = row
        else:
            self.sched.append(row)

    def sample_telemetry(self, t: float, device: str, mbps: float,
                         stream_mbps: float, inflight: int) -> None:
        self.telemetry.setdefault(device, []).append(
            (t, mbps, stream_mbps, inflight))

    def device_rows(self, name: str) -> list[dict]:
        return [dict(zip(self.ROW_FIELDS, r))
                for r in self.devices.get(name, ())]

    def telemetry_rows(self, name: str) -> list[dict]:
        return [dict(zip(self.TELEMETRY_FIELDS, r))
                for r in self.telemetry.get(name, ())]


class _Wait:
    """Per-task wait bookkeeping (internal)."""

    __slots__ = ("tid", "sig", "cls", "submit_t", "ready_t", "last_ready_t",
                 "launch_t", "end_t", "retry_since", "attempts", "buckets")

    def __init__(self, tid: int, sig: str, submit_t: float):
        self.tid = tid
        self.sig = sig
        self.cls = None
        self.submit_t = submit_t
        self.ready_t = None       # first readiness (dependency-wait end)
        self.last_ready_t = None  # current attempt's readiness
        self.launch_t = None
        self.end_t = None
        self.retry_since = None   # set while re-queued after a failure
        self.attempts = 0
        self.buckets: dict[str, float] = {}

    def add(self, bucket: str, dt: float) -> None:
        if dt > 0:
            self.buckets[bucket] = self.buckets.get(bucket, 0.0) + dt

    def breakdown(self) -> dict:
        total = (self.end_t - self.submit_t) \
            if self.end_t is not None else 0.0
        out = {k: self.buckets.get(k, 0.0) for k in WAIT_STATES}
        residual = total - sum(out.values())
        out["total"] = total
        out["residual"] = residual
        out["coverage"] = 1.0 - abs(residual) / total if total > 0 else 1.0
        return out


class TraceRecorder:
    """Append-only typed event stream + metrics timeline + wait profiler.

    Construct with a ``clock`` callable (the backend's ``now``); the
    runtime binds it via :meth:`bind`."""

    def __init__(self, config: Optional[TraceConfig] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.config = config or TraceConfig()
        self._clock = clock or (lambda: 0.0)
        self._sched = None       # scheduler probe (queue depth sampling)
        self._lock = threading.Lock()
        self.events: list[dict] = []
        self.timeline = MetricsTimeline()
        self.waits: dict[int, _Wait] = {}
        # per placement-class refusal-reason marks: cls -> [(t, reason)],
        # appended only when the reason changes (segments extend until the
        # next different reason; see docs/observability.md)
        self._class_marks: dict = {}
        self._mark_times: dict = {}

    # ----------------------------------------------------------- wiring
    def bind(self, clock: Callable[[], float], scheduler=None) -> None:
        self._clock = clock
        self._sched = scheduler

    def now(self) -> float:
        return float(self._clock())

    # ------------------------------------------------------------ stream
    def event(self, type_: str, **fields) -> None:
        """Append one typed event (fields per :data:`EVENT_SCHEMA`)."""
        ev = {"type": type_, **fields}
        with self._lock:
            self.events.append(ev)

    def _sample_dev(self, t: float, dev) -> None:
        if self.config.timeline and dev is not None:
            self.timeline.sample_device(t, dev)

    def _sample_sched(self, t: float) -> None:
        sched = self._sched
        if not self.config.timeline or sched is None:
            return
        blocked = getattr(sched, "capacity_blocked", None)
        self.timeline.sample_sched(
            t, sched.n_ready, len(sched.running),
            float(sum(blocked.values())) if blocked else 0.0)

    # ----------------------------------------------------- task lifecycle
    def on_submit(self, task) -> None:
        t = task.submit_time
        with self._lock:
            self.events.append({"type": "submit", "t": t, "tid": task.tid,
                                "sig": task.defn.signature})
            if self.config.waits:
                self.waits[task.tid] = _Wait(
                    task.tid, task.defn.signature, t)

    def on_ready(self, task, cls: tuple) -> None:
        t = self.now()
        with self._lock:
            self.events.append({"type": "ready", "t": t, "tid": task.tid,
                                "sig": task.defn.signature})
            w = self.waits.get(task.tid)
            if w is None:
                return
            w.cls = cls
            if w.ready_t is None:
                w.ready_t = t
                w.add("dependency", t - w.submit_t)
            w.last_ready_t = t

    def on_launch(self, task, worker) -> None:
        t = task.start_time
        dev = task.device
        with self._lock:
            self.events.append({
                "type": "launch", "t": t, "tid": task.tid,
                "sig": task.defn.signature, "worker": worker.name,
                "device": dev.name if dev is not None else None,
                "tier": dev.tier if dev is not None else None,
                "bw": task.granted_bw, "attempt": task.retries})
            w = self.waits.get(task.tid)
            if w is not None:
                if w.retry_since is not None:
                    # requeue-to-relaunch window after a failed attempt
                    w.add("failure-retry", t - w.retry_since)
                    w.retry_since = None
                elif w.last_ready_t is not None:
                    self._attribute_ready_wait(w, w.last_ready_t, t)
                w.launch_t = t
                w.attempts += 1
        self._sample_dev(t, dev)
        self._sample_sched(t)

    def on_complete(self, task, failed: bool) -> None:
        t = task.end_time
        with self._lock:
            self.events.append({"type": "complete", "t": t, "tid": task.tid,
                                "sig": task.defn.signature,
                                "failed": bool(failed)})
            w = self.waits.get(task.tid)
            if w is not None and w.launch_t is not None:
                w.add("failure-retry" if failed else "running",
                      t - w.launch_t)
                w.end_t = t
        self._sample_dev(t, task.device)
        self._sample_sched(t)

    def on_retry(self, task) -> None:
        """A failed attempt re-enters the ready queue (SimBackend retry
        path). The attempt's run time and the wait until the next launch
        both land in the failure-retry bucket."""
        t = self.now()
        with self._lock:
            self.events.append({"type": "retry", "t": t, "tid": task.tid,
                                "sig": task.defn.signature,
                                "attempt": task.retries})
            w = self.waits.get(task.tid)
            if w is not None:
                if w.launch_t is not None:
                    w.add("failure-retry", t - w.launch_t)
                w.retry_since = t
        self._sample_dev(t, task.device)

    # -------------------------------------------------- refusal diagnosis
    def note_block(self, cls: tuple, reason: str,
                   device: Optional[str] = None,
                   wanted_mb: float = 0.0) -> None:
        """The scheduler could not place the head of placement class
        ``cls`` right now, for ``reason``. Marks extend until the next
        *different* reason, so the event stream stays O(reason changes)."""
        t = self.now()
        with self._lock:
            marks = self._class_marks.get(cls)
            if marks is None:
                marks = self._class_marks[cls] = []
                self._mark_times[cls] = []
            if marks and marks[-1][1] == reason:
                return
            marks.append((t, reason))
            self._mark_times[cls].append(t)
            self.events.append({"type": "blocked", "t": t, "cls": str(cls),
                                "reason": reason, "device": device,
                                "wanted_mb": float(wanted_mb)})
        self._sample_sched(t)

    def _attribute_ready_wait(self, w: _Wait, r: float, l: float) -> None:
        """Split the ready->launch interval ``[r, l]`` across the class's
        refusal-reason segments (called under the lock)."""
        if l - r <= _EPS:
            return
        marks = self._class_marks.get(w.cls)
        if not marks:
            w.add("unattributed", l - r)
            return
        times = self._mark_times[w.cls]
        i = bisect_right(times, r) - 1
        cur = r
        while cur < l - _EPS:
            if i < 0:
                seg_end = min(l, times[0])
                reason = "unattributed"
            else:
                reason = marks[i][1]
                seg_end = min(l, times[i + 1]) if i + 1 < len(marks) else l
            w.add(reason, seg_end - cur)
            cur = seg_end
            i += 1

    # ------------------------------------------------- subsystem hooks
    def on_burst(self, t: float, dev, phase: str, streams: int, bw: float,
                 capacity_mb: float) -> None:
        with self._lock:
            self.events.append({"type": "burst", "t": t, "device": dev.name,
                                "tier": dev.tier, "phase": phase,
                                "streams": int(streams), "bw": float(bw),
                                "capacity_mb": float(capacity_mb)})
        self._sample_dev(t, dev)

    def on_health(self, t: float, dev, prev: str, state: str) -> None:
        with self._lock:
            self.events.append({"type": "health", "t": t, "device": dev.name,
                                "prev": prev, "state": state})
        self._sample_dev(t, dev)

    def on_evict(self, t: float, obj, dev, mode: str) -> None:
        with self._lock:
            self.events.append({"type": "evict", "t": t, "oid": obj.oid,
                                "name": obj.name, "device": dev.name,
                                "tier": dev.tier, "mode": mode,
                                "size_mb": obj.size_mb})
        self._sample_dev(t, dev)

    def on_stage(self, t: float, obj, tier: str) -> None:
        with self._lock:
            self.events.append({"type": "stage", "t": t, "oid": obj.oid,
                                "name": obj.name, "tier": tier,
                                "size_mb": obj.size_mb})

    def on_pin(self, t: float, obj, pinned: bool) -> None:
        with self._lock:
            self.events.append({"type": "pin", "t": t, "oid": obj.oid,
                                "name": obj.name, "pinned": bool(pinned)})

    def on_ckpt(self, phase: str, step: int, mode: str,
                n_shards: int) -> None:
        self.event("ckpt", t=self.now(), phase=phase, step=int(step),
                   mode=mode, n_shards=int(n_shards))

    def on_stall(self, t: float, kind: str) -> None:
        self.event("stall", t=t, kind=kind)

    def on_telemetry(self, t: float, device: str, tier: Optional[str],
                     mbps: float, stream_mbps: float, inflight: int,
                     mb: float, wall_s: float) -> None:
        """Measured-throughput sample from the RealBackend's TelemetryHub
        (one per completed I/O op; real runs only)."""
        with self._lock:
            self.events.append({"type": "telemetry", "t": float(t),
                                "device": device, "tier": tier,
                                "mbps": float(mbps),
                                "stream_mbps": float(stream_mbps),
                                "inflight": int(inflight),
                                "mb": float(mb), "wall_s": float(wall_s)})
            if self.config.timeline:
                self.timeline.sample_telemetry(
                    t, device, float(mbps), float(stream_mbps),
                    int(inflight))

    def span(self, name: str, cat: str, t0: float, t1: float,
             **args) -> dict:
        """Record a generic async span (e.g. a serving request's
        admission->finish window). Returns the event dict."""
        ev = {"type": "span", "t": float(t0), "name": name, "cat": cat,
              "dur": float(t1) - float(t0), "args": args}
        with self._lock:
            self.events.append(ev)
        return ev

    # ----------------------------------------------------------- rollups
    def task_breakdown(self, tid: int) -> Optional[dict]:
        w = self.waits.get(tid)
        return w.breakdown() if w is not None else None

    def wait_state_summary(self) -> dict:
        """Attribution rollup: totals and per-signature sums over every
        finished task, with the residual reported explicitly. This is the
        dict ``rt.stats()`` exposes under ``"wait_states"``."""
        totals = {k: 0.0 for k in WAIT_STATES}
        by_sig: dict[str, dict] = {}
        residual = 0.0
        latency = 0.0
        n = 0
        min_cov = 1.0
        with self._lock:
            waits = list(self.waits.values())
        for w in waits:
            if w.end_t is None:
                continue
            b = w.breakdown()
            n += 1
            latency += b["total"]
            residual += abs(b["residual"])
            min_cov = min(min_cov, b["coverage"])
            sig = by_sig.setdefault(
                w.sig, {k: 0.0 for k in WAIT_STATES})
            for k in WAIT_STATES:
                totals[k] += b[k]
                sig[k] += b[k]
        return {
            "states": dict(totals),
            "by_signature": by_sig,
            "n_tasks": n,
            "total_latency": latency,
            "residual": residual,
            "min_task_coverage": min_cov,
        }

    def critical_path_report(self, graph) -> dict:
        """Walk the approximate critical path (from the last-finishing task
        back through each task's latest-finishing dependency) and sum the
        wait-state buckets along it — the per-run quantification of the
        paper's congestion claim: how much of the makespan is I/O
        contention (bandwidth + capacity) rather than work."""
        tasks = getattr(graph, "tasks", {})
        done = [w for w in self.waits.values() if w.end_t is not None]
        if not done:
            return {"path": [], "length": 0.0, "states": {},
                    "congestion_fraction": 0.0}
        tail = max(done, key=lambda w: (w.end_t, w.tid))
        path = []
        seen = set()
        w = tail
        while w is not None and w.tid not in seen:
            seen.add(w.tid)
            path.append(w.tid)
            t = tasks.get(w.tid)
            nxt = None
            if t is not None and t.deps:
                best = None
                for dep in t.deps:
                    # graph deps are TaskInstances; waits is keyed by tid
                    dw = self.waits.get(getattr(dep, "tid", dep))
                    if dw is None or dw.end_t is None:
                        continue
                    if best is None or (dw.end_t, dw.tid) > \
                            (best.end_t, best.tid):
                        best = dw
                nxt = best
            w = nxt
        path.reverse()
        states = {k: 0.0 for k in WAIT_STATES}
        for tid in path:
            b = self.waits[tid].breakdown()
            for k in WAIT_STATES:
                states[k] += b[k]
        length = tail.end_t - min(self.waits[t].submit_t for t in path)
        congestion = states["bandwidth"] + states["capacity"]
        return {
            "path": path,
            "length": length,
            "states": states,
            "congestion_fraction": congestion / length if length > 0
            else 0.0,
        }

    # ------------------------------------------------------------ export
    def to_jsonl(self) -> str:
        """The event stream as one JSON document per line (stable key
        order; byte-identical across same-seed sim runs)."""
        import json
        with self._lock:
            events = list(self.events)
        return "\n".join(json.dumps(ev, sort_keys=True) for ev in events)

    def summary(self) -> dict:
        by_type: dict[str, int] = {}
        with self._lock:
            for ev in self.events:
                by_type[ev["type"]] = by_type.get(ev["type"], 0) + 1
        return {
            "n_events": sum(by_type.values()),
            "events_by_type": by_type,
            "n_devices_sampled": len(self.timeline.devices),
            "wait_states": self.wait_state_summary(),
        }
