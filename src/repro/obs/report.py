"""Human- and benchmark-facing rollups over a :class:`TraceRecorder`.

``attribution(recorder)`` is what benchmarks embed in their BENCH JSON
envelopes; ``format_summary`` renders the ``python -m repro.trace`` table;
``percentile`` is the shared quantile helper the serve loop uses for
p50/p99 over recorded request spans.
"""
from __future__ import annotations

from .recorder import WAIT_STATES


def percentile(values, q: float) -> float:
    """Linear-interpolation quantile (q in [0, 1]) without numpy, so the
    core stays dependency-free. Empty input -> 0.0."""
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    if len(vals) == 1:
        return vals[0]
    pos = q * (len(vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def attribution(recorder, graph=None) -> dict:
    """The trace attribution summary benchmarks attach to BENCH JSONs:
    wait-state rollup plus (when the task graph is supplied) the
    critical-path congestion report."""
    out = {"wait_states": recorder.wait_state_summary()}
    if graph is not None:
        out["critical_path"] = recorder.critical_path_report(graph)
    return out


def span_latencies(recorder, cat: str = "request") -> list[float]:
    """Durations (seconds) of recorded spans in category ``cat``."""
    return [ev["dur"] for ev in recorder.events
            if ev["type"] == "span" and ev["cat"] == cat]


def format_summary(recorder, label: str = "") -> str:
    """Fixed-width summary table: event counts, sampled devices, and the
    wait-state rollup with its residual."""
    s = recorder.summary()
    ws = s["wait_states"]
    lines = []
    title = f"trace summary{': ' + label if label else ''}"
    lines.append(title)
    lines.append("=" * len(title))
    lines.append(f"{'events':<24}{s['n_events']}")
    for name in sorted(s["events_by_type"]):
        lines.append(f"  {name:<22}{s['events_by_type'][name]}")
    lines.append(f"{'devices sampled':<24}{s['n_devices_sampled']}")
    lines.append(f"{'finished tasks':<24}{ws['n_tasks']}")
    total = ws["total_latency"]
    lines.append(f"{'total task latency':<24}{total:.3f} s")
    if ws["n_tasks"]:
        lines.append("wait-state attribution")
        for k in WAIT_STATES:
            v = ws["states"][k]
            if v <= 0:
                continue
            pct = 100.0 * v / total if total > 0 else 0.0
            lines.append(f"  {k:<22}{v:>10.3f} s  {pct:5.1f}%")
        lines.append(f"  {'residual':<22}{ws['residual']:>10.3f} s")
        lines.append(f"  {'min task coverage':<22}"
                     f"{100.0 * ws['min_task_coverage']:>9.2f}%")
    return "\n".join(lines)
