"""Measured per-device I/O telemetry for the RealBackend.

The simulator *models* device throughput; outside it the runtime was
flying blind — nothing measured what the storage actually delivered.
:class:`TelemetryHub` closes that gap: the ``RealBackend`` feeds it on
every I/O launch/complete (bytes moved, measured wall time of the final
attempt, in-flight concurrency) and it maintains, per device:

- sliding-window measured throughput (MB/s over the last ``window_s``),
- the effective per-stream rate of each completed op (``mb / wall_s``),
- the current queue depth (in-flight op count),
- lifetime totals (ops, MB, wall seconds, peak windowed MB/s).

Every successful sample is also emitted as a frozen-schema ``telemetry``
event through the bound :class:`TraceRecorder` (when the run is traced),
rolled into ``rt.stats()["telemetry"]`` and exported as Perfetto counter
tracks. The hub is real-backend-only: ``SimBackend`` never touches it,
so sim traces and launch logs stay byte-identical.

:func:`fit_tiers` turns the collected samples into a calibration — a
per-tier ``{bandwidth, per_stream_cap, congestion_alpha}`` estimate of
the measured congestion curve — and :func:`apply_tier_config` feeds it
back into a cluster's :class:`StorageDevice` parameters, which is what
``python -m repro.compare --fit`` and ``benchmarks/sim_vs_real.py`` use
to shrink the sim-vs-real model error.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Optional


class DeviceTelemetry:
    """Measured state of one device (identified by name)."""

    __slots__ = ("name", "tier", "inflight", "n_ops", "n_failed",
                 "total_mb", "total_wall_s", "peak_mbps", "last_t",
                 "samples")

    def __init__(self, name: str, tier: Optional[str], max_samples: int):
        self.name = name
        self.tier = tier
        self.inflight = 0            # ops launched, not yet completed
        self.n_ops = 0               # successful completions
        self.n_failed = 0
        self.total_mb = 0.0
        self.total_wall_s = 0.0
        self.peak_mbps = 0.0         # max windowed throughput seen
        self.last_t = 0.0
        # (t_end, mb, wall_s, k) per successful op; k = concurrency the op
        # ran under (max of launch-time and completion-time in-flight)
        self.samples: deque = deque(maxlen=max_samples)


class TelemetryHub:
    """Per-device measured-throughput aggregator (RealBackend-fed).

    Call sites hold the runtime lock already (``launch`` and the
    completion block both run under it), but the hub keeps its own small
    lock so it is safe to read from any thread (``summary()`` during a
    live run, the fit harness after it).
    """

    def __init__(self, window_s: float = 5.0, max_samples: int = 4096):
        self.window_s = float(window_s)
        self.max_samples = int(max_samples)
        self.recorder = None         # TraceRecorder, bound by the backend
        self._lock = threading.Lock()
        self.devices: dict[str, DeviceTelemetry] = {}

    def bind(self, recorder) -> None:
        self.recorder = recorder

    def _dev(self, device) -> DeviceTelemetry:
        d = self.devices.get(device.name)
        if d is None:
            d = self.devices[device.name] = DeviceTelemetry(
                device.name, getattr(device, "tier", None), self.max_samples)
        return d

    # ------------------------------------------------------------- feeding
    def on_launch(self, t: float, device) -> int:
        """An I/O op was launched on ``device`` at backend time ``t``.
        Returns the in-flight count including this op (the launch-side
        concurrency snapshot the backend stashes on the task)."""
        with self._lock:
            d = self._dev(device)
            d.inflight += 1
            return d.inflight

    def on_complete(self, t: float, device, mb: float,
                    wall_s: Optional[float], *, failed: bool = False,
                    launch_inflight: int = 0) -> None:
        """An I/O op completed at backend time ``t`` having moved ``mb``
        MB in ``wall_s`` measured seconds. Failed ops (and ops with no
        measured wall time) still decrement the queue depth but record no
        throughput sample."""
        rec = self.recorder
        ev = None
        with self._lock:
            d = self._dev(device)
            k = max(d.inflight, 1)          # completion-side concurrency
            d.inflight = max(d.inflight - 1, 0)
            if failed:
                d.n_failed += 1
                return
            if wall_s is None or wall_s <= 0.0:
                return
            k = max(k, int(launch_inflight))
            mb = float(mb)
            wall_s = float(wall_s)
            d.n_ops += 1
            d.total_mb += mb
            d.total_wall_s += wall_s
            d.last_t = t
            d.samples.append((float(t), mb, wall_s, k))
            mbps = self._windowed_mbps(d, t)
            d.peak_mbps = max(d.peak_mbps, mbps)
            if rec is not None:
                ev = dict(t=float(t), device=d.name, tier=d.tier,
                          mbps=mbps, stream_mbps=mb / wall_s,
                          inflight=d.inflight, mb=mb, wall_s=wall_s)
        if ev is not None:
            rec.on_telemetry(**ev)

    # ------------------------------------------------------------- reading
    def _window(self, d: DeviceTelemetry, t: float) -> list:
        lo = t - self.window_s
        return [s for s in d.samples if s[0] >= lo]

    def _windowed_mbps(self, d: DeviceTelemetry, t: float) -> float:
        """Aggregate measured throughput over the sliding window ending at
        ``t``: MB completed in the window divided by the span the window's
        ops actually covered (from the earliest op *start* in the window,
        clipped to ``window_s``) — so early samples aren't diluted by the
        part of the window before any I/O ran."""
        win = self._window(d, t)
        if not win:
            return 0.0
        start = min(s[0] - s[2] for s in win)
        span = min(self.window_s, max(t - start, 1e-9))
        return sum(s[1] for s in win) / span

    def summary(self) -> dict:
        """Per-device rollup for ``rt.stats()["telemetry"]``."""
        out: dict = {"window_s": self.window_s, "devices": {}}
        with self._lock:
            for name in sorted(self.devices):
                d = self.devices[name]
                win = self._window(d, d.last_t)
                stream = (sum(s[1] / s[2] for s in win) / len(win)
                          if win else 0.0)
                out["devices"][name] = {
                    "tier": d.tier,
                    "n_ops": d.n_ops,
                    "n_failed": d.n_failed,
                    "inflight": d.inflight,
                    "total_mb": d.total_mb,
                    "mbps": self._windowed_mbps(d, d.last_t),
                    "peak_mbps": d.peak_mbps,
                    "stream_mbps": stream,
                    "last_t": d.last_t,
                    "n_samples": len(d.samples),
                }
        return out

    def snapshot_samples(self) -> dict:
        """``{device_name: [(t, mb, wall_s, k), ...]}`` copy for fitting."""
        with self._lock:
            return {name: list(d.samples)
                    for name, d in self.devices.items()}


# --------------------------------------------------------------------------
# Fitting measured samples back into StorageDevice parameters
# --------------------------------------------------------------------------
def fit_samples(samples: list) -> Optional[dict]:
    """Fit ``{bandwidth, per_stream_cap, congestion_alpha}`` from a list of
    ``(t, mb, wall_s, k)`` samples of one device. Deterministic; returns
    None when no sample moved any data (latency-only ops can't constrain a
    bandwidth model)."""
    by_k: dict[int, list[float]] = {}
    for _, mb, wall_s, k in samples:
        if mb > 0.0 and wall_s > 0.0:
            by_k.setdefault(max(int(k), 1), []).append(mb / wall_s)
    if not by_k:
        return None
    mean_rate = {k: sum(v) / len(v) for k, v in by_k.items()}
    k_min = min(mean_rate)
    # single stream (or the least-contended concurrency observed) sets the
    # per-stream cap; aggregate throughput A(k) ~= k * mean_rate(k) peaks
    # at the measured bandwidth ceiling
    per_stream = mean_rate[k_min]
    bandwidth = max(k * r for k, r in mean_rate.items())
    bandwidth = max(bandwidth, per_stream)
    # congestion ramp: past the knee the model divides A(k) by
    # (1 + alpha*over) (the quadratic term is negligible at these depths);
    # estimate alpha from the aggregate decline at the deepest measured k
    knee = max(1, int(bandwidth / per_stream)) if per_stream > 0 else 1
    alpha = 0.0
    deep = [(k, k * r) for k, r in mean_rate.items() if k > knee]
    if deep:
        k_deep, a_deep = max(deep)
        over = k_deep - knee
        if a_deep > 0 and over > 0 and bandwidth > a_deep:
            alpha = min(max((bandwidth / a_deep - 1.0) / over, 0.0), 1.0)
    return {"bandwidth": bandwidth, "per_stream_cap": per_stream,
            "congestion_alpha": alpha,
            "n_samples": sum(len(v) for v in by_k.values()),
            "max_k": max(by_k)}


def fit_tiers(hub: TelemetryHub) -> dict:
    """Per-tier calibration from a hub's measured samples: device fits
    grouped by tier label, averaged when a tier has several devices."""
    per_tier: dict[str, list[dict]] = {}
    snap = hub.snapshot_samples()
    with hub._lock:
        tiers = {name: d.tier for name, d in hub.devices.items()}
    for name in sorted(snap):
        fit = fit_samples(snap[name])
        if fit is not None:
            per_tier.setdefault(tiers.get(name) or "default", []).append(fit)
    out = {}
    for tier, fits in sorted(per_tier.items()):
        n = len(fits)
        out[tier] = {
            "bandwidth": sum(f["bandwidth"] for f in fits) / n,
            "per_stream_cap": sum(f["per_stream_cap"] for f in fits) / n,
            "congestion_alpha": sum(f["congestion_alpha"] for f in fits) / n,
            "n_samples": sum(f["n_samples"] for f in fits),
            "max_k": max(f["max_k"] for f in fits),
        }
    return out


def apply_tier_config(cluster, tier_config: dict) -> int:
    """Overwrite the congestion-model parameters of every device whose tier
    appears in ``tier_config`` (a :func:`fit_tiers`-shaped dict). Returns
    the number of devices updated. Only meaningful before a run starts —
    the dynamic state (available_bw) is reset to the new ceiling."""
    n = 0
    for dev in cluster.devices:
        cfg = tier_config.get(dev.tier)
        if cfg is None:
            continue
        dev.bandwidth = float(cfg["bandwidth"])
        dev.per_stream_cap = float(cfg["per_stream_cap"])
        if "congestion_alpha" in cfg:
            dev.congestion_alpha = float(cfg["congestion_alpha"])
        dev.congestion_knee = max(1, int(dev.bandwidth / dev.per_stream_cap))
        dev.available_bw = dev.bandwidth
        dev.invalidate_rates()  # memoized T(k) curve is stale (storage_model)
        n += 1
    return n
