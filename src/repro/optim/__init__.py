from .adamw import AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm
from .compression import compressed_grads, compressed_psum, quantize_int8
