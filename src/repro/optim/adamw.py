"""AdamW with decoupled weight decay, global-norm clipping and cosine
schedule. Pure pytree ops — optimizer state (m, v in fp32) inherits the
parameters' logical sharding, so with FSDP rules the state is fully sharded
across (pod, data, model): ZeRO-style, no redundant optimizer memory.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    m: object
    v: object
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, params, state: AdamWState, cfg: AdamWConfig):
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    # three passes; XLA CSE merges the duplicate math under jit
    new_p = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[0],
                         params, grads, state.m, state.v)
    new_m = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                         params, grads, state.m, state.v)
    new_v = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                         params, grads, state.m, state.v)
    return new_p, AdamWState(new_m, new_v, count), gnorm
