"""Int8 gradient compression for data-parallel all-reduce.

For DP-replicated training (the dp_fsdp regime's small-model cousin), the
gradient all-reduce can move int8 instead of bf16/f32: per-tensor absmax
quantisation, psum in int32 (exact — no overflow below 2^23 summands),
dequantise with the max of the per-shard scales. 4x less ICI traffic for
~1e-2 relative error, switchable per step (e.g. skip compression on
clipped/spiky steps).

Used via ``compressed_grads`` inside a shard_map'd DP step
(tests/test_compression.py); the dry-run strategy tables note where it
applies (pure-DP axes only — FSDP-sharded grads are already partitioned).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g):
    """(int8 values, f32 scale). Symmetric per-tensor absmax."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, axis_name):
    """All-reduce one gradient tensor in int8 payload over ``axis_name``.
    Scales are maxed across shards first so the int32 sum is consistent."""
    q, scale = quantize_int8(g)
    scale = jax.lax.pmax(scale, axis_name)
    # requantise against the global scale (cheap: one mul + round)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127) \
        .astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype)


def compressed_grads(grads, axis_name):
    """Mean-reduce a gradient pytree over a mesh axis with int8 payloads."""
    return jax.tree.map(lambda g: compressed_psum(g, axis_name), grads)
