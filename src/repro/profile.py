"""``python -m repro.profile`` — cProfile/pstats wrapper over any script.

Profiles an unmodified script (task bodies and all) under cProfile and
prints the top-N hot spots by cumulative time, the same table the
scheduler-scale work uses to pick optimization targets::

    PYTHONPATH=src python -m repro.profile benchmarks/sched_scale.py \
        --top 25 --json PROFILE.json -- --n 100000

Everything after ``--`` is passed to the script as its own ``sys.argv``.
``--sort`` accepts any pstats key (``cumulative``, ``tottime``,
``ncalls``, ...); ``--json`` additionally writes the table as structured
rows so successive runs can be diffed mechanically (the pre/post evidence
tables in PR descriptions come from this).
"""
from __future__ import annotations

import argparse
import cProfile
import io
import json
import pstats
import runpy
import sys


def profile_script(path: str, argv: list[str] | None = None,
                   run_name: str = "__main__") -> pstats.Stats:
    """Execute ``path`` under cProfile with ``sys.argv`` set to
    ``[path] + argv`` and return the collected :class:`pstats.Stats`.
    The script's ``SystemExit`` (argparse, sys.exit) is swallowed so the
    profile of a partial run still comes back."""
    old_argv = sys.argv
    sys.argv = [path] + list(argv or [])
    prof = cProfile.Profile()
    try:
        prof.enable()
        try:
            runpy.run_path(path, run_name=run_name)
        except SystemExit:
            pass
        finally:
            prof.disable()
    finally:
        sys.argv = old_argv
    return pstats.Stats(prof)


def stats_rows(stats: pstats.Stats, sort: str = "cumulative",
               top: int = 25) -> list[dict]:
    """The top-``top`` entries of ``stats`` as structured rows:
    ``{func, file, line, ncalls, primcalls, tottime, cumtime}``."""
    stats.sort_stats(sort)
    rows = []
    for func in stats.fcn_list[:top]:  # sorted order
        cc, nc, tt, ct, _callers = stats.stats[func]
        filename, line, name = func
        rows.append({
            "func": name,
            "file": filename,
            "line": line,
            "ncalls": nc,
            "primcalls": cc,
            "tottime": round(tt, 6),
            "cumtime": round(ct, 6),
        })
    return rows


def format_table(rows: list[dict]) -> str:
    """Human-readable hot-spot table (fixed-width, pstats-like)."""
    lines = [f"{'ncalls':>12} {'tottime':>9} {'cumtime':>9}  function"]
    for r in rows:
        calls = str(r["ncalls"])
        if r["primcalls"] != r["ncalls"]:
            calls = f"{r['ncalls']}/{r['primcalls']}"
        where = f"{r['file']}:{r['line']}" if r["line"] else r["file"]
        lines.append(f"{calls:>12} {r['tottime']:>9.3f} {r['cumtime']:>9.3f}"
                     f"  {r['func']}  ({where})")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="python -m repro.profile",
        description="Profile a script under cProfile and print the top-N "
                    "hot spots (args after -- go to the script).")
    ap.add_argument("script", help="path of the script to profile")
    ap.add_argument("--top", type=int, default=25,
                    help="number of entries to show (default 25)")
    ap.add_argument("--sort", default="cumulative",
                    help="pstats sort key (default: cumulative)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write the table as JSON rows to this path")
    args, script_args = ap.parse_known_args(argv)
    if script_args and script_args[0] == "--":
        script_args = script_args[1:]

    stats = profile_script(args.script, script_args)
    rows = stats_rows(stats, sort=args.sort, top=args.top)
    total = sum(tt for _, (_, _, tt, _, _) in stats.stats.items())
    print(f"profiled {args.script}: {total:.2f}s total in "
          f"{len(stats.stats)} functions; top {len(rows)} by {args.sort}:")
    print(format_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"script": args.script, "argv": script_args,
                       "sort": args.sort, "total_tottime": round(total, 6),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json_out}")
    return rows


if __name__ == "__main__":
    main()
