"""``python -m repro.trace <script.py> ...`` — observability CLI.

Runs each script under *forced tracing*: every ``IORuntime`` the script
constructs gets a :class:`repro.obs.TraceRecorder` wired into all event
sites (same hijack pattern as ``repro.lint``'s forced capture — but the
script runs for real; tracing is pure reads, so behaviour is
bit-identical to an untraced run). For every traced runtime it prints a
summary table (event counts, wait-state attribution); ``--perfetto``
exports Chrome trace-event JSON loadable at https://ui.perfetto.dev,
``--jsonl`` dumps the raw typed event stream, ``--json`` emits one
machine-readable summary document.

Multiple runtimes in one script get ``-1``, ``-2``, ... suffixes on the
export paths. Exit status: 0 on success, 2 on harness errors (missing
file, script crash, no runtime constructed).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import obs
from .obs import perfetto
from .obs.report import format_summary


def _run_script(path: str) -> tuple[list, list[str]]:
    """Execute ``path`` with obs.FORCE on; returns (registered runs,
    notes)."""
    import runpy

    obs.RUNS.clear()
    obs.FORCE = True
    notes: list[str] = []
    old_argv = sys.argv
    sys.argv = [path]
    try:
        runpy.run_path(path, run_name="__main__")
    except SystemExit as e:
        if e.code not in (0, None):
            notes.append(f"{path}: exited with status {e.code}")
    except BaseException as e:  # noqa: BLE001 — trace what ran anyway
        notes.append(f"{path}: raised {type(e).__name__} ({e})")
    finally:
        sys.argv = old_argv
        obs.FORCE = False
    runs = list(obs.RUNS)
    obs.RUNS.clear()
    return runs, notes


def _out_path(base: str, index: int, n_runs: int) -> str:
    if n_runs == 1:
        return base
    root, ext = os.path.splitext(base)
    return f"{root}-{index}{ext or '.json'}"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="Run scripts with I/O tracing forced on and report "
                    "event-stream summaries, wait-state attribution, and "
                    "Perfetto/JSONL exports (see docs/observability.md).")
    parser.add_argument("scripts", nargs="+", metavar="script.py",
                        help="Python scripts to run under forced tracing")
    parser.add_argument("--perfetto", metavar="OUT.json",
                        help="export Chrome trace-event JSON (per runtime; "
                             "multiple runtimes get -1, -2, ... suffixes)")
    parser.add_argument("--jsonl", metavar="OUT.jsonl",
                        help="dump the typed event stream as JSON lines")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable summaries (one JSON doc)")
    args = parser.parse_args(argv)

    status = 0
    doc = []
    for path in args.scripts:
        if not os.path.isfile(path):
            print(f"repro.trace: no such file: {path}", file=sys.stderr)
            return 2
        runs, notes = _run_script(path)
        for note in notes:
            print(f"note: {note}", file=sys.stderr)
            status = 2
        if not runs:
            print(f"repro.trace: {path}: no IORuntime constructed — "
                  f"nothing traced", file=sys.stderr)
            status = 2
            continue
        for i, (label, rt) in enumerate(runs, start=1):
            rec = rt.recorder
            if rec is None:
                continue
            tag = f"{path} {label}"
            if args.as_json:
                doc.append({"script": path, "runtime": label,
                            **rec.summary()})
            else:
                print(format_summary(rec, label=tag))
                print()
            if args.perfetto:
                out = _out_path(args.perfetto, i, len(runs))
                with open(out, "w") as f:
                    f.write(perfetto.dumps(rec))
                print(f"perfetto trace written: {out}", file=sys.stderr)
            if args.jsonl:
                out = _out_path(args.jsonl, i, len(runs))
                with open(out, "w") as f:
                    f.write(rec.to_jsonl() + "\n")
                print(f"event stream written: {out}", file=sys.stderr)
    if args.as_json:
        print(json.dumps(doc, indent=2))
    return status


if __name__ == "__main__":
    raise SystemExit(main())
