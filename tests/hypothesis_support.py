"""Optional-hypothesis shim (tier-1 must collect without hypothesis).

``from hypothesis import given, settings, strategies as st`` at module scope
used to abort collection of six test modules when hypothesis wasn't
installed (the ``pytest.importorskip`` idiom can't help there either — it
skips the *whole* module, losing the deterministic tests that live next to
the properties). Importing from this shim instead keeps every module
collectable: with hypothesis installed the real objects pass through; without
it the property tests become individually-skipped placeholders while the
plain pytest tests (including each module's deterministic fallback case)
still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute access or
        call returns itself, so strategy expressions evaluated at decoration
        time (``st.lists(st.integers(0, 9), ...)``) are inert no-ops."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def wrap(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = getattr(fn, "__name__", "skipped_property")
            skipped.__doc__ = fn.__doc__
            return skipped
        return wrap

    def settings(*_args, **_kwargs):
        def wrap(fn):
            return fn
        return wrap
