import math

from hypothesis_support import given, st

from repro.core import AutoSpec
from repro.core.autotune import AutoTuner, Phase


def feed_epochs(tuner, time_of):
    """Drive epochs to completion with avg time = time_of(constraint, k)."""
    guard = 0
    while tuner.learning() and guard < 50:
        guard += 1
        e = tuner.epoch
        k = e.target_k
        for _ in range(k):
            assert tuner.admit()
        for _ in range(k):
            tuner.on_task_complete(time_of(e.constraint, k))


def fair_share_time(mb=290.0, bw=450.0, cap=8.0, knee=56, a=0.004, b=1e-5):
    def t(c, k):
        ramp = min(k * cap, bw)
        over = max(0, k - knee)
        agg = ramp / (1 + a * over + b * over * over)
        return mb * k / agg
    return t


def test_unbounded_walk_matches_paper():
    tuner = AutoTuner("ck", AutoSpec(bounded=False), 450.0, 225)
    feed_epochs(tuner, fair_share_time())
    assert [c for c, _ in tuner.history] == [2.0, 4.0, 8.0, 16.0]
    assert sorted(tuner.registry) == [2.0, 4.0, 8.0]
    assert tuner.choose(2000) == 8.0


def test_bounded_walk_matches_paper():
    tuner = AutoTuner("ck", AutoSpec(bounded=True, min=2, max=256, delta=2),
                      450.0, 225)
    feed_epochs(tuner, fair_share_time())
    assert len(tuner.history) == 8
    assert tuner.choose(2000) == 8.0


def test_tie_goes_to_highest_constraint():
    tuner = AutoTuner("ck", AutoSpec(bounded=False), 450.0, 225)
    tuner.registry = {8.0: 10.0, 16.0: 10.0}
    tuner.phase = Phase.DONE
    # T(1, 8)=10 == T(1, 16)=10 -> highest wins (paper §4.2.3C)
    assert tuner.choose(1) == 16.0


def test_end_of_stream_closes_partial_epoch():
    tuner = AutoTuner("ck", AutoSpec(bounded=False), 450.0, 225)
    for _ in range(10):
        assert tuner.admit()
    for _ in range(10):
        tuner.on_task_complete(5.0)
    tuner.end_of_stream()
    assert not tuner.learning()
    assert tuner.registry  # partial epoch still registered


@given(st.dictionaries(st.sampled_from([2.0, 4.0, 8.0, 16.0, 32.0]),
                       st.floats(1.0, 1e4), min_size=1),
       st.integers(1, 5000))
def test_choose_is_argmin_of_objective(registry, n):
    tuner = AutoTuner("ck", AutoSpec(bounded=False), 450.0, 225)
    tuner.registry = dict(registry)
    tuner.phase = Phase.DONE
    c = tuner.choose(n)
    best = min(tuner.objective_time(n, cc) for cc in registry)
    assert math.isclose(tuner.objective_time(n, c), best, rel_tol=1e-9)
    # tie rule: no strictly-higher constraint achieves the same objective
    for cc in registry:
        if cc > c:
            assert tuner.objective_time(n, cc) > best + -1e-12


@given(st.integers(1, 10000), st.sampled_from([2.0, 4.0, 8.0, 32.0]))
def test_objective_ceil_groups(n, c):
    tuner = AutoTuner("ck", AutoSpec(bounded=False), 450.0, 225)
    tuner.registry = {c: 7.0}
    k = tuner._k_for(c)
    assert tuner.objective_time(n, c) == math.ceil(n / k) * 7.0


def test_choose_argmin_deterministic():
    """Pure-pytest fallback for the argmin property (runs w/o hypothesis)."""
    tuner = AutoTuner("ck", AutoSpec(bounded=False), 450.0, 225)
    tuner.registry = {2.0: 40.0, 8.0: 10.0, 16.0: 9.0, 32.0: 9.0}
    tuner.phase = Phase.DONE
    for n in (1, 56, 57, 500, 5000):
        c = tuner.choose(n)
        best = min(tuner.objective_time(n, cc) for cc in tuner.registry)
        assert math.isclose(tuner.objective_time(n, c), best, rel_tol=1e-9)
        for cc in tuner.registry:  # tie rule: highest constraint wins
            if cc > c:
                assert tuner.objective_time(n, cc) > best - 1e-12
    # peek_choice is pure; record_choice does the bookkeeping
    counts_before = dict(tuner._choice_counts)
    assert tuner.peek_choice(500) in tuner.registry
    assert tuner._choice_counts == counts_before
