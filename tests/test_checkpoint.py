"""Checkpoint substrate: roundtrip, atomic commit, async via runtime,
elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import Cluster, IORuntime, RealBackend, StorageDevice, WorkerNode


def tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16),
            "opt": {"count": jnp.zeros((), jnp.int32),
                    "m": jnp.full((2, 2), 0.5)}}


def assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x, np.float32), np.asarray(y, np.float32)), a, b)


def test_sync_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, n_shards=3)
    t = tree()
    mgr.save(5, t, sync=True)
    restored, step = mgr.restore(t)
    assert step == 5
    assert_tree_equal(t, restored)


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, n_shards=2, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree(), sync=True)
    assert mgr.latest_step() == 4
    assert mgr.steps() == [3, 4]  # gc keeps 2


def test_torn_manifest_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, n_shards=2)
    mgr.save(1, tree(), sync=True)
    mgr.save(2, tree(), sync=True)
    # simulate a torn step-3: shards written, manifest garbage
    d = tmp_path / "step_00000003"
    d.mkdir()
    (d / "MANIFEST.json").write_text("{not json")
    assert mgr.latest_step() == 2


def test_truncated_shard_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, n_shards=1)
    t = tree()
    mgr.save(1, t, sync=True)
    shard = next((tmp_path / "step_00000001").glob("shard_*.bin"))
    shard.write_bytes(shard.read_bytes()[:-4])
    with pytest.raises(IOError, match="truncated"):
        mgr.restore(t)


def test_async_save_through_runtime(tmp_path):
    dev = StorageDevice(name="fs", bandwidth=2000, per_stream_cap=500)
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                          storage=dev)])
    mgr = CheckpointManager(tmp_path, n_shards=4)
    t = tree()
    with IORuntime(cluster, backend=RealBackend()):
        assert mgr.save(7, t)
        mgr.wait()
    restored, step = mgr.restore(t)
    assert step == 7
    assert_tree_equal(t, restored)


def test_restore_with_new_shardings(tmp_path):
    # elastic restart: restore onto explicit (here: single-device) shardings
    mgr = CheckpointManager(tmp_path, n_shards=2)
    t = tree()
    mgr.save(1, t, sync=True)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    restored, _ = mgr.restore(t, shardings=sh)
    assert_tree_equal(t, restored)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf, jax.Array)
