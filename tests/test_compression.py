"""Int8 gradient compression: quantisation error bounds + shard_map DP step
numerics vs the exact path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_support import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.optim.compression import (compressed_grads, dequantize_int8,
                                     quantize_int8)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
def test_quant_roundtrip_error_bound(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    # absmax quantisation: error <= scale/2 = absmax/254 per element
    bound = float(jnp.max(jnp.abs(g))) / 254.0 + 1e-9
    assert float(jnp.max(jnp.abs(back - g))) <= bound * 1.01


def test_quant_roundtrip_error_bound_deterministic():
    """Pure-pytest fallback for the roundtrip property."""
    for seed, scale in ((0, 1.0), (1, 1e-3), (2, 1e3)):
        g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
        q, s = quantize_int8(g)
        back = dequantize_int8(q, s)
        bound = float(jnp.max(jnp.abs(g))) / 254.0 + 1e-9
        assert float(jnp.max(jnp.abs(back - g))) <= bound * 1.01


def test_compressed_psum_matches_mean():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 32))

    def f(g):
        return compressed_grads({"w": g}, "data")["w"]

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data")))(g)
    # single-host mean == identity up to quantisation error
    rel = float(jnp.max(jnp.abs(out - g)) / jnp.max(jnp.abs(g)))
    assert rel < 1e-2


@pytest.mark.slow  # full model + optimizer step: jax e2e tier
def test_dp_step_with_compression_close_to_exact():
    """A tiny DP train step with compressed grads stays within quantisation
    tolerance of the exact step (same params, same batch)."""
    from repro.launch.train import PRESETS
    from repro.models import Model
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = PRESETS["5m"]
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    acfg = AdamWConfig()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                           cfg.vocab_size)}
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    p_exact, _, _ = adamw_update(grads, params, opt, acfg)

    def reduce_fn(g):
        return compressed_grads(g, "data")
    gq = jax.jit(shard_map(reduce_fn, mesh=mesh,
                           in_specs=P(), out_specs=P()))(grads)
    p_comp, _, _ = adamw_update(gq, params, opt, acfg)
    deltas = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        p_exact, p_comp)
    assert max(jax.tree.leaves(deltas)) < 5e-3
