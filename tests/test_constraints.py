import pytest
from hypothesis_support import given, st

from repro.core import AutoSpec, StaticSpec, parse_storage_bw


def test_parse_static():
    assert parse_storage_bw(20) == StaticSpec(20.0)
    assert parse_storage_bw("12.5") == StaticSpec(12.5)


def test_parse_auto_unbounded():
    spec = parse_storage_bw("auto")
    assert isinstance(spec, AutoSpec) and not spec.bounded


def test_parse_auto_bounded():
    spec = parse_storage_bw("auto(2,256,2)")
    assert spec == AutoSpec(bounded=True, min=2, max=256, delta=2)
    assert parse_storage_bw("auto( 10 , 50 , 4 )").max == 50


@pytest.mark.parametrize("bad", ["auto(5)", "auto(0,10,2)", "auto(10,5,2)",
                                 "auto(2,256,1)", "nope", -3, 0])
def test_parse_rejects(bad):
    with pytest.raises(ValueError):
        parse_storage_bw(bad)


@given(st.floats(min_value=0.1, max_value=1e6, allow_nan=False))
def test_parse_static_roundtrip(x):
    assert parse_storage_bw(x).value == pytest.approx(x)


@given(st.integers(1, 100), st.integers(0, 10), st.integers(2, 8))
def test_parse_bounded_roundtrip(lo, span, delta):
    hi = lo + span
    spec = parse_storage_bw(f"auto({lo},{hi},{delta})")
    assert (spec.min, spec.max, spec.delta) == (lo, hi, delta)


def test_roundtrips_deterministic():
    """Pure-pytest fallback for the roundtrip properties."""
    for x in (0.1, 1.0, 12.5, 450.0, 1e6):
        assert parse_storage_bw(x).value == pytest.approx(x)
    for lo, hi, delta in ((1, 1, 2), (2, 256, 2), (100, 110, 8)):
        spec = parse_storage_bw(f"auto({lo},{hi},{delta})")
        assert (spec.min, spec.max, spec.delta) == (lo, hi, delta)
