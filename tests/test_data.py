import numpy as np

from repro.core import Cluster, IORuntime, RealBackend
from repro.data import PrefetchLoader, SyntheticCorpus


def test_corpus_deterministic_and_restart_safe():
    c1 = SyntheticCorpus(1000, 16, 4, seed=7)
    c2 = SyntheticCorpus(1000, 16, 4, seed=7)
    b1, b2 = c1.batch(5), c2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(c1.batch(5)["tokens"], c1.batch(6)["tokens"])
    # targets are next-token shifted
    full1 = c1.batch(3)
    np.testing.assert_array_equal(full1["tokens"][:, 1:],
                                  full1["targets"][:, :-1])
    # structured mode: most transitions follow the affine map
    c3 = SyntheticCorpus(1000, 64, 4, seed=2, structured=True, noise=0.1)
    b = c3.batch(0)
    pred = (b["tokens"] * 31 + 7) % 1000
    frac = (pred == b["targets"]).mean()
    assert frac > 0.7


def test_host_sharding_partitions_batch():
    full = SyntheticCorpus(1000, 8, 8, seed=1)
    parts = [SyntheticCorpus(1000, 8, 8, seed=1, n_hosts=4, host_index=i)
             for i in range(4)]
    assert all(p.local_batch == 2 for p in parts)


def test_prefetch_matches_direct():
    corpus = SyntheticCorpus(500, 8, 2, seed=3)
    loader = PrefetchLoader(corpus, depth=2)
    with IORuntime(Cluster.make(n_workers=1, cpus=2, io_executors=4),
                   backend=RealBackend()):
        for step in range(5):
            got = loader.get(step)
            np.testing.assert_array_equal(got["tokens"],
                                          corpus.batch(step)["tokens"])
