"""Data lifecycle subsystem: catalog, capacity accounting, eviction,
auto-prefetch, no-op movement, construction-time validation (ISSUE 3
tentpole)."""
import itertools

import pytest

from repro.core import (Cluster, IORuntime, LifecycleConfig, LRUEviction,
                        SimBackend, StorageDevice, TierCapacity,
                        WorkerNode, constraint, io, task)
from repro.core.task import TaskInstance


def _fresh_tids():
    TaskInstance._ids = itertools.count()


def two_tier(ssd_capacity_gb=None, ssd_bw=1000.0, ssd_cap=400.0,
             fs_bw=200.0, fs_cap=100.0, n_workers=1):
    fs = StorageDevice(name="shared-fs", bandwidth=fs_bw,
                       per_stream_cap=fs_cap, tier="fs")
    workers = []
    for i in range(n_workers):
        ssd = StorageDevice(name=f"w{i}-ssd", bandwidth=ssd_bw,
                            per_stream_cap=ssd_cap, tier="ssd",
                            capacity_gb=ssd_capacity_gb)
        workers.append(WorkerNode(name=f"w{i}", cpus=4, io_executors=8,
                                  tiers=[ssd, fs]))
    return Cluster(workers=workers)


# ------------------------------------------------------------- validation
def test_capacity_gb_validated_at_construction():
    with pytest.raises(ValueError, match="capacity_gb must be positive"):
        StorageDevice(name="bad", capacity_gb=0)
    with pytest.raises(ValueError, match="capacity_gb must be positive"):
        StorageDevice(name="bad", capacity_gb=-1.5)
    with pytest.raises(ValueError, match="bandwidth must be positive"):
        StorageDevice(name="bad", bandwidth=0)


def test_tier_capacity_watermarks_validated():
    with pytest.raises(ValueError, match="high_watermark"):
        TierCapacity("ssd", high_watermark=0.0)
    with pytest.raises(ValueError, match="low_watermark"):
        TierCapacity("ssd", low_watermark=1.5)
    with pytest.raises(ValueError, match="must not exceed"):
        TierCapacity("ssd", high_watermark=0.5, low_watermark=0.8)
    with pytest.raises(ValueError, match="capacity_gb must be positive"):
        TierCapacity("ssd", capacity_gb=-1)
    with pytest.raises(ValueError, match="high_watermark"):
        LifecycleConfig(high_watermark=2.0)


def test_negative_io_mb_and_duration_rejected_at_call():
    with IORuntime(two_tier(), backend=SimBackend()) as rt:
        @io
        @task()
        def wr(i):
            pass

        @task()
        def comp(i):
            pass
        with pytest.raises(ValueError, match="io_mb must be non-negative"):
            wr(0, io_mb=-5)
        with pytest.raises(ValueError, match="duration must be non-negative"):
            comp(0, duration=-1.0)
        rt.barrier(final=True)
    assert rt.graph.unfinished == 0


# ------------------------------------------------------ device accounting
def test_device_capacity_accounting():
    d = StorageDevice(name="d", capacity_gb=1.0)  # 1024 MB
    assert d.capacity_mb == 1024.0
    d.reserve_capacity(600.0)
    assert d.reserved_mb == 600.0 and d.free_capacity_mb() == 424.0
    assert not d.can_reserve_capacity(500.0)
    with pytest.raises(RuntimeError, match="over-filling"):
        d.reserve_capacity(500.0)
    d.commit_capacity(600.0)
    assert d.used_mb == 600.0 and d.reserved_mb == 0.0
    d.reserve_capacity(100.0)
    d.cancel_reservation(100.0)  # failed writer
    assert d.occupancy_mb == 600.0
    d.free_capacity(600.0)  # eviction
    assert d.used_mb == 0.0
    assert d.peak_occupancy_mb == 700.0
    d.reset()
    assert d.peak_occupancy_mb == 0.0


def test_unlimited_device_is_inert():
    d = StorageDevice(name="d")
    assert d.capacity_mb is None and d.free_capacity_mb() == float("inf")
    d.reserve_capacity(1e9)  # no-ops, never raises
    d.commit_capacity(1e9)
    assert d.used_mb == 0.0


# ------------------------------------------------- enable/disable plumbing
def test_catalog_disabled_without_capacity():
    _fresh_tids()
    with IORuntime(two_tier(), backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        wr(0, io_mb=10)
        rt.barrier(final=True)
        st = rt.stats()
    assert not rt.catalog.enabled
    assert "lifecycle" not in st
    assert rt.scheduler.catalog is None
    assert len(rt.catalog.objects) == 0


def test_explicit_enable_without_capacity():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True)
    with IORuntime(two_tier(), backend=SimBackend(), lifecycle=cfg) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=10)
        rt.barrier(final=True)
    obj = rt.catalog.lookup_future(f)
    assert obj is not None and obj.residency.keys() == {"ssd"}


def test_tier_capacity_config_applies_to_devices():
    cluster = two_tier()
    cfg = LifecycleConfig(tiers={"ssd": TierCapacity("ssd",
                                                     capacity_gb=0.5)})
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        rt.barrier(final=True)
    assert rt.catalog.enabled
    assert cluster.workers[0].storage.capacity_gb == 0.5


# -------------------------------------------- reserve/commit/spill behavior
def test_reserve_at_grant_commit_at_finish():
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=300)
        @io
        @task(returns=1)
        def wr(i):
            pass
        wr(0, io_mb=100)
        rt.barrier(final=True)
    ssd = cluster.workers[0].storage
    assert ssd.used_mb == 100.0 and ssd.reserved_mb == 0.0
    assert ssd.peak_occupancy_mb == 100.0


def test_failed_writer_reservation_cancelled():
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        wr(0, io_mb=100, sim_fail=True)
        rt.barrier(final=True)
    ssd = cluster.workers[0].storage
    assert ssd.used_mb == 0.0 and ssd.reserved_mb == 0.0
    assert len(rt.catalog.objects) == 0  # failed write is not resident data


def test_full_tier_spills_down_hierarchy():
    """naive-overflow placement: with eviction off, a full SSD sends
    tier-agnostic writes to the next tier instead of queueing."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=200 / 1024.0)  # fits exactly 2x100
    cfg = LifecycleConfig(auto_evict=False)
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        @constraint(storageBW=50)
        @io
        @task(returns=1)
        def wr(i):
            pass
        for i in range(4):
            wr(i, io_mb=100)
        rt.barrier(final=True)
    tiers = sorted(t.device.tier for t in rt.scheduler.completed)
    assert tiers == ["fs", "fs", "ssd", "ssd"]
    ssd = cluster.workers[0].storage
    assert ssd.used_mb == 200.0
    assert ssd.peak_occupancy_mb <= ssd.capacity_mb


# ----------------------------------------------------------------- eviction
def _eviction_run(pin_first=False, n=8, ssd_gb=0.375):
    """Write n 100MB objects through a small SSD with generous step gaps so
    watermark eviction has shadow time."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=ssd_gb)  # 384 MB default
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @task(returns=1)
        def step(prev, gate, i):
            pass

        @constraint(storageBW=300)
        @io
        @task(returns=1)
        def wr(x, i):
            pass
        prev, gate, futs = None, None, []
        for i in range(n):
            prev = step(prev, gate, i, duration=2.0)
            f = wr(prev, i, io_mb=100)
            if pin_first and i == 0:
                rt.pin(f)
            futs.append(f)
            gate = f
        rt.barrier(final=True)
    return rt, cluster, futs


def test_watermark_eviction_drains_cold_objects():
    rt, cluster, futs = _eviction_run()
    cat = rt.catalog
    assert cat.n_evictions > 0
    ssd = cluster.workers[0].storage
    assert ssd.peak_occupancy_mb <= ssd.capacity_mb + 1e-6
    # drain-then-delete: every evicted object still has a durable fs copy
    for ev in cat.events:
        assert ev["durable"], ev
        assert ev["readers"] == 0, ev
    # all writes stayed on the fast tier (the point of evicting)
    wr_tiers = {t.device.tier for t in rt.scheduler.completed
                if t.defn.name == "wr"}
    assert wr_tiers == {"ssd"}


def test_lru_eviction_order():
    rt, _, futs = _eviction_run()
    evicted_oids = [e["oid"] for e in rt.catalog.events]
    # LRU by last reader: eviction order follows object age order
    assert evicted_oids == sorted(evicted_oids)


def test_pinned_objects_exempt_from_eviction():
    rt, _, futs = _eviction_run(pin_first=True)
    pinned = rt.catalog.lookup_future(futs[0])
    assert pinned.pinned
    assert all(e["oid"] != pinned.oid for e in rt.catalog.events)
    assert "ssd" in pinned.residency  # still resident at the end


def test_no_eviction_while_scheduled_reader_outstanding():
    """An object whose consumer is submitted (even long before it runs) is
    never selected for eviction."""
    rt, _, futs = _eviction_run()
    cat = rt.catalog
    assert cat.events, "scenario must evict"
    for ev in cat.events:
        obj = cat.objects[ev["oid"]]
        t_sel = ev["selected_at"]
        for tid, t0, t1 in obj.reader_log:
            assert not (t0 <= t_sel and (t1 is None or t1 > t_sel)), \
                (ev, obj.reader_log)


def test_demand_eviction_unblocks_pinned_tier_writes():
    """A tier-pinned writer that cannot fit triggers demand-driven eviction
    below the watermark instead of deadlocking."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=0.25)  # 256 MB: one 200MB at a time
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=300, tier="ssd")
        @io
        @task(returns=1)
        def wrs(i):
            pass
        for i in range(4):
            wrs(i, io_mb=200)
        rt.barrier(final=True)
    assert rt.catalog.n_evictions >= 3
    done = [t for t in rt.scheduler.completed if t.defn.name == "wrs"]
    assert len(done) == 4 and all(t.device.tier == "ssd" for t in done)


def test_lru_policy_select_unit():
    a = _mk_obj("a", 10, last_use=5.0)
    b = _mk_obj("b", 10, last_use=1.0)
    c = _mk_obj("c", 10, last_use=3.0)
    chosen = LRUEviction().select([a, b, c], need_mb=15)
    assert [o.name for o in chosen] == ["b", "c"]


def _mk_obj(name, size, last_use):
    from repro.core import DataObject
    o = DataObject(name, size)
    o.last_use = last_use
    return o


# ------------------------------------------------------------ auto-prefetch
def _prefetch_run(auto_prefetch, n=6):
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=8.0, fs_bw=200.0)
    cfg = LifecycleConfig(auto_prefetch=auto_prefetch)
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        shards = [rt.external_data(f"s{i}", 200.0, "fs") for i in range(n)]

        @task(returns=1)
        def train(prev, shard, i):
            pass
        prev = None
        for i, s in enumerate(shards):
            prev = train(prev, s, i, duration=1.0)
        rt.barrier(final=True)
    return rt


def test_auto_prefetch_stages_slow_tier_inputs():
    rt = _prefetch_run(True)
    assert rt.catalog.n_prefetches == 6
    movers = [t for t in rt.scheduler.completed
              if t.defn.name == "tier_prefetch"]
    assert len(movers) == 6
    assert all(t.device.tier == "ssd" for t in movers)
    # consumers read from the staged fast copy: penalties reflect ssd
    pens = [t.read_penalty for t in rt.scheduler.completed
            if t.defn.name == "train"]
    assert all(p == 200.0 / 1000.0 for p in pens)


def test_auto_prefetch_off_pays_fs_reads_inline():
    rt = _prefetch_run(False)
    assert rt.catalog.n_prefetches == 0
    pens = [t.read_penalty for t in rt.scheduler.completed
            if t.defn.name == "train"]
    assert all(p == 200.0 / 200.0 for p in pens)


def test_auto_prefetch_hides_read_time():
    slow = _prefetch_run(False).stats()["makespan"]
    fast = _prefetch_run(True).stats()["makespan"]
    assert fast < slow


def test_one_staging_serves_many_readers():
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=8.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        shard = rt.external_data("s", 100.0, "fs")

        @task(returns=1)
        def train(shard, i):
            pass
        for i in range(5):
            train(shard, i, duration=0.5)
        rt.barrier(final=True)
    assert rt.catalog.n_prefetches == 1


def test_external_data_validation():
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        with pytest.raises(ValueError, match="tape"):
            rt.external_data("x", 10.0, "tape")
        with pytest.raises(ValueError, match="size_mb"):
            rt.external_data("x", -1.0, "fs")
        rt.barrier(final=True)
    with IORuntime(two_tier(), backend=SimBackend()) as rt:  # disabled
        with pytest.raises(RuntimeError, match="lifecycle"):
            rt.external_data("x", 10.0, "fs")
        rt.barrier(final=True)


# ------------------------------------------------------------- no-op moves
def test_same_tier_move_resolves_immediately():
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=10)
        d = rt.drain(f, to_tier="ssd", from_tier="ssd", io_mb=10)
        assert d is f  # the producer future itself: no movement task
        p = rt.prefetch("plainvalue", to_tier="fs", from_tier="fs")
        assert p.resolved() and p.value() == "plainvalue"
        rt.barrier(final=True)
    names = [t.defn.name for t in rt.scheduler.completed]
    assert "tier_drain" not in names and "tier_prefetch" not in names


def test_move_to_tier_already_resident_is_noop():
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=10)  # lands on ssd
        rt.wait_on(f)
        p = rt.prefetch(f, to_tier="ssd", from_tier="fs", io_mb=10)
        assert p is f  # catalog knows it's already on ssd
        d = rt.drain(f, to_tier="fs", io_mb=10)  # NOT resident on fs: moves
        rt.wait_on(d)
        rt.barrier(final=True)
    names = [t.defn.name for t in rt.scheduler.completed]
    assert "tier_prefetch" not in names and names.count("tier_drain") == 1
    obj = rt.catalog.lookup_future(f)
    assert set(obj.residency) == {"ssd", "fs"}


def test_user_move_with_wrong_io_mb_stays_consistent():
    """A user-issued move of a tracked object charges the object's true
    footprint, not the caller's io_mb guess — otherwise used_mb desyncs
    from the resident-object sum and a later eviction underflows."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=128, storage_tier="fs")
        rt.wait_on(f)
        rt.wait_on(rt.prefetch(f, to_tier="ssd", io_mb=50))  # wrong hint
        rt.barrier(final=True)
    ssd = cluster.workers[0].storage
    obj = rt.catalog.lookup_future(f)
    assert ssd.used_mb == obj.size_mb == 128.0
    assert set(obj.residency) == {"fs", "ssd"}


def test_io_mb_larger_than_tier_capacity_rejected_at_submission():
    """An output footprint no eligible device can EVER hold (even empty)
    raises at the call site instead of wedging its placement class until a
    generic scheduler-stuck error at the barrier."""
    from repro.core import SchedulerError
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=10 / 1024.0)  # 10 MB ssd
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=100, tier="ssd")
        @io
        @task(returns=1)
        def wrs(i):
            pass
        with pytest.raises(SchedulerError, match="total capacity"):
            wrs(0, io_mb=100)
        wrs(1, io_mb=5)  # a fittable same-class task is unaffected
        # tier-agnostic stays fine: the unlimited fs tier can hold it
        @io
        @task(returns=1)
        def wr_any(i):
            pass
        wr_any(2, io_mb=100)
        rt.barrier(final=True)
    done = [t.defn.name for t in rt.scheduler.completed]
    assert done.count("wrs") == 1 and done.count("wr_any") == 1
    assert not any(t.defn.name == "wrs" and t.args[0] == 0
                   for t in rt.scheduler.completed)


def test_object_too_big_for_fast_tier_read_in_place_not_staged():
    """Auto-prefetch must not stage an object larger than the fast tier's
    total capacity — the consumer reads it from the slow tier instead of
    crashing its submission with the staging's SchedulerError."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=64 / 1024.0)  # 64 MB ssd
    with IORuntime(cluster, backend=SimBackend()) as rt:
        big = rt.external_data("big", 100.0, "fs")  # cannot ever fit ssd

        @task(returns=1)
        def train(shard, i):
            pass
        train(big, 0, duration=0.5)  # must not raise
        rt.barrier(final=True)
    assert rt.catalog.n_prefetches == 0
    pens = [t.read_penalty for t in rt.scheduler.completed
            if t.defn.name == "train"]
    assert pens == [100.0 / 200.0]  # read from fs, in place


def test_drain_of_pending_producer_keeps_accounting_consistent():
    """A drain submitted before its producer registered carries the
    caller's io_mb guess; the catalog must not record the true-size object
    against that commit (used_mb == resident sum stays an invariant)."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=1.0)
    cat_cluster = cluster
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=30, storage_tier="fs")
        rt.drain(f, to_tier="ssd", io_mb=5)  # wrong guess, producer pending
        rt.barrier(final=True)
    cat = rt.catalog
    for d in cat_cluster.devices:
        resident = cat._resident.get(id(d), set())
        if d.capacity_mb is not None:
            assert abs(d.used_mb - sum(o.size_mb for o in resident)) < 1e-6


def test_finite_durable_tier_rejected_with_auto_evict():
    from repro.core import Cluster
    cluster = Cluster.make_tiered(n_workers=1, ssd_capacity_gb=0.0625,
                                  fs_capacity_gb=0.125)
    with pytest.raises(ValueError, match="durable tier"):
        IORuntime(cluster, backend=SimBackend())
    # allowed when eviction is off (naive-overflow modelling)
    rt = IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_evict=False))
    assert rt.catalog.enabled


def test_tier_capacity_config_reaches_scheduler_feasibility():
    """TierCapacity budgets are applied by the catalog after scheduler
    construction; the submission-time feasibility map must see them."""
    from repro.core import SchedulerError
    _fresh_tids()
    cluster = two_tier()
    cfg = LifecycleConfig(tiers={"ssd": TierCapacity(
        "ssd", capacity_gb=10 / 1024.0)})
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        @io
        @task(returns=1)
        def wrs(i):
            pass
        with pytest.raises(SchedulerError, match="total capacity"):
            wrs(0, io_mb=100, storage_tier="ssd")
        rt.barrier(final=True)


def test_explicit_disable_makes_finite_capacity_inert():
    """LifecycleConfig(enabled=False) must disable capacity ENFORCEMENT
    too: nothing would ever free occupancy, so pinned-tier workloads would
    otherwise wedge behind a full budget."""
    _fresh_tids()
    cluster = two_tier(ssd_capacity_gb=100 / 1024.0)  # 100 MB
    cfg = LifecycleConfig(enabled=False)
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        @constraint(storageBW=100, tier="ssd")
        @io
        @task(returns=1)
        def wrs(i):
            pass
        for i in range(6):
            wrs(i, io_mb=60)  # 360 MB through the "100 MB" tier
        rt.barrier(final=True)  # must not get stuck
    assert len(rt.scheduler.completed) == 6
    assert cluster.workers[0].storage.used_mb == 0.0  # nothing accounted


def test_mover_negative_io_mb_rejected():
    _fresh_tids()
    with IORuntime(two_tier(ssd_capacity_gb=1.0),
                   backend=SimBackend()) as rt:
        with pytest.raises(ValueError, match="io_mb must be non-negative"):
            rt.drain(None, to_tier="fs", from_tier="ssd", io_mb=-50)
        rt.barrier(final=True)


def test_path_move_not_short_circuited_by_model_residency(tmp_path):
    """Catalog residency is modelled state; a path= drain must still copy
    the real file even if the object is already 'resident' at the
    destination per the model."""
    from repro.core import RealBackend
    ssd_dir, fs_dir = tmp_path / "ssd", tmp_path / "fs"
    ssd_dir.mkdir(), fs_dir.mkdir()
    (ssd_dir / "blob.bin").write_bytes(b"x" * 1024)
    fs = StorageDevice(name="pfs", bandwidth=400, per_stream_cap=80,
                       tier="fs")
    ssd = StorageDevice(name="d", bandwidth=1000, per_stream_cap=500,
                        capacity_gb=1.0)
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                          tiers=[ssd, fs])])
    backend = RealBackend(tier_dirs={"ssd": ssd_dir, "fs": fs_dir})
    with IORuntime(cluster, backend=backend) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=0.001)
        rt.barrier()  # full completion bookkeeping, not just the future
        # model the object as already fs-resident, then move the real file
        obj = rt.catalog.lookup_future(f)
        rt.catalog._add_residency(obj, fs)
        fut = rt.drain(f, to_tier="fs", from_tier="ssd",
                       io_mb=obj.size_mb, path="blob.bin")
        assert fut is not f  # a real mover ran, not the short-circuit
        rt.wait_on(fut)
        rt.barrier(final=True)
    assert (fs_dir / "blob.bin").read_bytes() == b"x" * 1024


# ----------------------------------------------- checkpoint fast_keep (GC)
def test_checkpoint_fast_keep_default_and_validation(tmp_path):
    from repro.checkpoint import CheckpointManager
    m = CheckpointManager(tmp_path / "fs", keep=3, fast_dir=tmp_path / "bb")
    assert m.fast_keep == 1
    m2 = CheckpointManager(tmp_path / "fs2", keep=0,
                           fast_dir=tmp_path / "bb2")
    assert m2.fast_keep == 0
    with pytest.raises(ValueError, match="fast_keep"):
        CheckpointManager(tmp_path / "fs3", fast_dir=tmp_path / "bb3",
                          fast_keep=-1)


def test_checkpoint_fast_tier_trimmed_more_aggressively(tmp_path):
    import numpy as np
    from repro.checkpoint import CheckpointManager
    fs_dir, bb_dir = tmp_path / "fs", tmp_path / "bb"
    dev = StorageDevice(name="d", bandwidth=1000, per_stream_cap=500)
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                          storage=dev)])
    mgr = CheckpointManager(fs_dir, n_shards=2, keep=3, fast_dir=bb_dir,
                            overrun_policy="wait")
    tree = {"w": np.zeros((64, 64))}
    from repro.core import RealBackend
    with IORuntime(cluster, backend=RealBackend()) as rt:
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        mgr.wait()
    durable = sorted(d.name for d in fs_dir.glob("step_*"))
    fast = sorted(d.name for d in bb_dir.glob("step_*"))
    assert len(durable) == 3  # keep=3 durable checkpoints
    assert fast == ["step_00000004"]  # fast tier holds only the newest


def test_checkpoint_failed_save_shards_trimmed_from_fast_tier(tmp_path):
    """A save that never committed its manifest (failed drain) must not
    leak its shards on the finite fast tier once superseded."""
    from repro.checkpoint import CheckpointManager
    fs_dir, bb_dir = tmp_path / "fs", tmp_path / "bb"
    mgr = CheckpointManager(fs_dir, n_shards=2, keep=3, fast_dir=bb_dir)
    # simulate a failed save: fast shards exist, no durable manifest
    dead = bb_dir / "step_00000001"
    dead.mkdir(parents=True)
    (dead / "shard_0000.bin").write_bytes(b"orphan")
    # a later durable checkpoint supersedes it
    ok_fast = bb_dir / "step_00000002"
    ok_fast.mkdir()
    ok_durable = fs_dir / "step_00000002"
    ok_durable.mkdir(parents=True)
    (ok_durable / "MANIFEST.json").write_text('{"step": 2, "shards": []}')
    mgr._gc()
    assert not dead.exists()      # orphan trimmed
    assert ok_fast.exists()       # newest durable kept (fast_keep=1)
