"""Distributed EXECUTION tests (not just lowering): run in subprocesses with
XLA_FLAGS forcing 8 host devices, so the sharded program actually executes.

1. tp_fsdp-sharded train step == single-device train step (numerics).
2. Elastic restart: checkpoint written under a (4,2) mesh restores onto a
   (2,4) mesh and training continues (DESIGN.md §7).
"""
import pytest
import subprocess
import sys
from pathlib import Path

pytestmark = pytest.mark.slow  # jax model / e2e tier (CI runs -m "not slow")


ROOT = Path(__file__).resolve().parents[1]

PROG_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.distributed import mesh_context
from repro.distributed.sharding import STRATEGIES
from repro.launch.specs import build_cell, model_shapes_and_axes, tree_shardings, with_shardings
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.configs.base import ShapeCell

cfg = get_smoke_config("tinyllama-1.1b").replace(dtype=jnp.float32)
model = Model(cfg)
params, axes = model.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
acfg = AdamWConfig()
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
         "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size)}

def step(p, o, b):
    loss, g = jax.value_and_grad(model.loss)(p, b)
    np_, no, gn = adamw_update(g, p, o, acfg)
    return loss, np_

# single device
loss1, p1 = jax.jit(step)(params, opt, batch)

# 4x2 mesh, tp_fsdp rules, actually executed
mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh_context(mesh, rules=STRATEGIES["tp_fsdp"]):
    sh = tree_shardings(jax.eval_shape(lambda: params), axes, mesh)
    p_sharded = jax.tree.map(jax.device_put, params, sh)
    loss8, p8 = jax.jit(step)(p_sharded, opt, batch)

assert abs(float(loss1) - float(loss8)) < 1e-4, (float(loss1), float(loss8))
d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)))), p1, p8)
md = max(jax.tree.leaves(d))
assert md < 1e-3, md
print("EQUIV_OK", float(loss1), float(loss8), md)
"""

PROG_ELASTIC = r"""
import os, sys, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.distributed import mesh_context
from repro.distributed.sharding import STRATEGIES
from repro.launch.specs import tree_shardings
from repro.models import Model

ckdir = sys.argv[1]
cfg = get_smoke_config("smollm-360m").replace(dtype=jnp.float32)
model = Model(cfg)
params, axes = model.init(jax.random.PRNGKey(0))

mesh_a = jax.make_mesh((4, 2), ("data", "model"))
with mesh_context(mesh_a, rules=STRATEGIES["tp_fsdp"]):
    sh_a = tree_shardings(jax.eval_shape(lambda: params), axes, mesh_a)
    p_a = jax.tree.map(jax.device_put, params, sh_a)
    mgr = CheckpointManager(ckdir, n_shards=4)
    mgr.save(3, p_a, sync=True)

# relaunch onto a DIFFERENT mesh shape: (2, 4)
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
with mesh_context(mesh_b, rules=STRATEGIES["tp_fsdp"]):
    sh_b = tree_shardings(jax.eval_shape(lambda: params), axes, mesh_b)
    p_b, step = mgr.restore(params, shardings=sh_b)
    assert step == 3
    # values survive the re-sharding
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)))), p_a, p_b)
    assert max(jax.tree.leaves(d)) == 0.0
    # and the restored params run a step under the new mesh
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size)}
    loss = jax.jit(model.loss)(p_b, batch)
    assert bool(jnp.isfinite(loss))
print("ELASTIC_OK", float(loss))
"""


def _run(prog, *args):
    return subprocess.run(
        [sys.executable, "-c", prog, *args], capture_output=True, text=True,
        timeout=420, env={"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root",
                          # force the CPU backend: without this, boxes with
                          # TPU-capable jax burn ~8 min on TPU metadata
                          # retries before falling back (and hit the timeout)
                          "JAX_PLATFORMS": "cpu"})


def test_sharded_train_step_matches_single_device():
    r = _run(PROG_EQUIV)
    assert "EQUIV_OK" in r.stdout, r.stdout + r.stderr[-2000:]


def test_elastic_restore_onto_different_mesh(tmp_path):
    r = _run(PROG_ELASTIC, str(tmp_path / "ck"))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr[-2000:]
