"""Tier failure domains (ISSUE 7): schedule/engine unit semantics, device
health accounting, sim/real retry parity, reroute + re-drain + lineage
recovery end-to-end, IO501/IOSan integration, and crash-consistent
checkpointing (atomic manifest fsync, kill-point fuzz, restore fallback,
fast-tier-offline reroute)."""
import itertools
import json
import os

import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import _write_manifest_atomic
from repro.core import (Cluster, FailureEngine, FailureEvent,
                        FailureSchedule, IORuntime, LifecycleConfig,
                        RealBackend, SimBackend, StorageDevice, TaskState,
                        WorkerNode, constraint, io, task)
from repro.core.task import TaskInstance


def _fresh_tids():
    TaskInstance._ids = itertools.count()


def two_tier(bb_bw=800.0, bb_cap=200.0, fs_bw=200.0, fs_cap=100.0,
             bb_capacity_gb=None):
    """One worker over a burst buffer + shared-FS hierarchy; the canonical
    failure-domain topology (kill bb, survive on fs)."""
    fs = StorageDevice(name="shared-fs", bandwidth=fs_bw,
                       per_stream_cap=fs_cap, tier="fs")
    bb = StorageDevice(name="w0-bb", bandwidth=bb_bw, per_stream_cap=bb_cap,
                       tier="bb", capacity_gb=bb_capacity_gb)
    w = WorkerNode(name="w0", cpus=4, io_executors=8, tiers=[bb, fs])
    return Cluster(workers=[w])


def device(cluster, tier):
    return next(d for d in cluster.devices if d.tier == tier)


def obj_of(cat, fut):
    return cat._by_fut[id(fut)][1]


# ---------------------------------------------------------- event/schedule
def test_failure_event_validation():
    with pytest.raises(ValueError, match="t must be >= 0"):
        FailureEvent(-1.0, "bb", "offline")
    with pytest.raises(ValueError, match="state must be one of"):
        FailureEvent(1.0, "bb", "down")
    with pytest.raises(ValueError, match=r"bw_factor must be in \(0, 1\]"):
        FailureEvent(1.0, "bb", "degraded", 0.0)
    with pytest.raises(ValueError, match=r"bw_factor must be in \(0, 1\]"):
        FailureEvent(1.0, "bb", "degraded", 1.5)
    # bw_factor is ignored (valid) for non-degraded states
    FailureEvent(1.0, "bb", "offline", 0.0)


def test_schedule_coerces_tuples_and_stable_sorts():
    sched = FailureSchedule([
        (5.0, "fs", "healthy"),
        (1.0, "bb", "degraded", 0.5),
        FailureEvent(5.0, "fs", "offline"),  # same t, listed second
    ])
    assert [e.t for e in sched] == [1.0, 5.0, 5.0]
    assert sched.events[0].bw_factor == 0.5
    # stable: the two t=5 events keep their given order
    assert [e.state for e in sched.events[1:]] == ["healthy", "offline"]
    assert len(sched) == 3


def test_seeded_schedule_reproducible():
    a = FailureSchedule.seeded(42, targets=("bb", "fs"), horizon=10.0)
    b = FailureSchedule.seeded(42, targets=("bb", "fs"), horizon=10.0)
    c = FailureSchedule.seeded(43, targets=("bb", "fs"), horizon=10.0)
    assert a.events == b.events
    assert a.events != c.events
    # recover=True pairs every injection with a later healthy event
    states = [e.state for e in a]
    assert states.count("healthy") == 3 and len(a) == 6
    assert all(0.0 <= e.t < 10.0 for e in a)
    with pytest.raises(ValueError, match=">= 1 target"):
        FailureSchedule.seeded(1, targets=(), horizon=5.0)


def test_engine_rejects_unknown_target():
    with pytest.raises(ValueError, match="'nvme' matches no tier"):
        FailureEngine(FailureSchedule([(1.0, "nvme", "offline")]), two_tier())


def test_engine_transitions_and_final_state():
    cluster = two_tier()
    bb = device(cluster, "bb")
    eng = FailureEngine(FailureSchedule([
        (1.0, "bb", "degraded", 0.5),
        (2.0, "bb", "healthy"),
        (3.0, "w0-bb", "offline"),  # device-name targeting
    ]), cluster)
    assert eng.active and eng.next_time() == 1.0
    trans = eng.apply_due(1.0)
    assert trans == [(bb, "healthy", "degraded")]
    assert bb.health == "degraded" and bb.effective_bandwidth == 400.0
    trans = eng.apply_due(10.0)
    assert [(p, n) for _, p, n in trans] == [("degraded", "healthy"),
                                             ("healthy", "offline")]
    assert bb.health == "offline" and eng.next_time() == float("inf")
    assert eng.final_state(bb) == "offline"
    assert eng.final_state(device(cluster, "fs")) is None
    s = eng.summary()
    assert s["transitions"] == 3 and s["pending"] == 0
    assert s["log"][0] == (1.0, "w0-bb", "healthy", "degraded")
    assert not FailureEngine(FailureSchedule([]), cluster).active


# ------------------------------------------------------ device health state
def test_device_health_accounting():
    d = StorageDevice(name="d", bandwidth=1000.0, per_stream_cap=200.0)
    epoch = d.rate_epoch
    d.set_health("degraded", 0.4)
    assert d.effective_bandwidth == 400.0
    assert d.rate_epoch > epoch  # cached finish times must be re-derived
    # the lost fraction of the nameplate budget is not allocatable
    assert d.can_allocate(400.0) and not d.can_allocate(401.0)
    d.set_health("offline")
    assert d.effective_bandwidth == 0.0
    assert not d.can_allocate(1.0)
    assert d.add_background(4, 100.0) == 0.0  # co-tenants get nothing
    d.set_health("healthy")
    assert d.effective_bandwidth == 1000.0 and d.can_allocate(1000.0)
    with pytest.raises(ValueError, match="unknown health state"):
        d.set_health("broken")
    assert d.check_invariants() == []


# ------------------------------------------------------- sim retry semantics
def _run_sim_write(sim_fail, max_retries, failures=None, n_extra=0):
    _fresh_tids()
    with IORuntime(two_tier(), backend=SimBackend(),
                   failures=failures) as rt:
        @constraint(maxRetries=max_retries)
        @io
        @task(returns=1)
        def wr(i):
            pass
        wr(0, io_mb=100.0, sim_fail=sim_fail)
        for i in range(n_extra):
            wr(1 + i, io_mb=100.0)
        rt.barrier(final=True)
    return rt


def test_sim_fail_count_retries_then_succeeds():
    rt = _run_sim_write(sim_fail=2, max_retries=3)
    t = rt.scheduler.completed[0]
    assert t.state == TaskState.DONE and t.retries == 2
    # each retry is a fresh grant with its own launch-log entry
    assert sum(1 for tid, _, _ in rt.scheduler.launch_log
               if tid == t.tid) == 3


def test_sim_fail_true_exhausts_retry_budget():
    rt = _run_sim_write(sim_fail=True, max_retries=2)
    t = next(iter(rt.graph.tasks.values()))
    assert t.state == TaskState.FAILED
    assert t.retries == 3  # maxRetries + 1 attempts, all failed


def test_sim_real_retry_parity():
    # the simulator's attempt accounting must match RealBackend's
    # in-worker loop: N injected failures under maxRetries >= N leaves
    # retries == N and the task DONE on both backends
    sim_rt = _run_sim_write(sim_fail=2, max_retries=3)
    sim_task = sim_rt.scheduler.completed[0]
    calls = {"n": 0}
    _fresh_tids()
    with IORuntime(two_tier(), backend=RealBackend()) as rt:
        @constraint(maxRetries=3)
        @io
        @task()
        def flaky():
            calls["n"] += 1
            if calls["n"] <= 2:
                raise IOError("transient")
        flaky()
        rt.barrier(final=True)
    real_task = rt.scheduler.completed[0]
    assert calls["n"] == 3
    assert (sim_task.state, sim_task.retries) == \
        (real_task.state, real_task.retries) == (TaskState.DONE, 2)


# --------------------------------------------------- end-to-end injection
def _write_burst(rt, n=6, io_mb=200.0, bw=50.0, max_retries=3, tier=None):
    @constraint(storageBW=bw, maxRetries=max_retries)
    @io
    @task(returns=1)
    def wr(i):
        pass
    return [wr(i, io_mb=io_mb, storage_tier=tier) for i in range(n)]


def _launch_log_of(failures):
    _fresh_tids()
    with IORuntime(two_tier(), backend=SimBackend(),
                   failures=failures) as rt:
        _write_burst(rt)
        rt.barrier(final=True)
    return list(rt.scheduler.launch_log), rt


def test_empty_schedule_is_bit_identical_to_no_wiring():
    log_plain, rt_plain = _launch_log_of(None)
    log_empty, rt_empty = _launch_log_of(FailureSchedule([]))
    assert log_plain == log_empty and log_plain
    # an inert engine is dropped entirely: no summary, no attached state
    assert rt_empty.failures is None
    assert "failures" not in rt_empty.stats()


def test_offline_midrun_fails_inflight_into_retry_path():
    _fresh_tids()
    # writes run at 50 MB/s x 200 MB = 4 s each; bb dies at t=1 with every
    # first-wave write in flight there
    with IORuntime(two_tier(), backend=SimBackend(),
                   failures=FailureSchedule([(1.0, "bb", "offline")])) as rt:
        _write_burst(rt)
        rt.barrier(final=True)
    done = rt.scheduler.completed
    assert all(t.state == TaskState.DONE for t in done)
    retried = [t for t in done if t.retries > 0]
    assert retried, "the failure must hit in-flight work"
    # nothing finishes on (or is granted to) the dead device afterwards
    for t in done:
        if t.device is not None and t.device.tier == "bb":
            assert t.start_time <= 1.0 + 1e-9
        if t.retries:
            assert t.device.tier == "fs"
    assert rt.stats()["failures"]["transitions"] == 1


def test_pinned_tier_rerouted_when_tier_dies():
    _fresh_tids()
    with IORuntime(two_tier(), backend=SimBackend(),
                   failures=FailureSchedule([(0.5, "bb", "offline")])) as rt:
        futs = _write_burst(rt, n=2, max_retries=2, tier="bb")
        rt.barrier(final=True)
        del futs
    done = rt.scheduler.completed
    assert all(t.state == TaskState.DONE for t in done)
    # the pin is dropped at retry — there is no healthy bb device left
    assert {t.device.tier for t in done if t.retries} == {"fs"}


def test_pinned_tier_write_waits_out_recovery():
    _fresh_tids()
    # bb is down from t=0 and recovers at t=5: the pinned write (no retry
    # budget) must queue — not fail — and land on bb once it heals
    sched = FailureSchedule([(0.0, "bb", "offline"), (5.0, "bb", "healthy")])
    with IORuntime(two_tier(), backend=SimBackend(), failures=sched) as rt:
        @constraint(maxRetries=0)
        @io
        @task(returns=1)
        def wr():
            pass
        wr(io_mb=100.0, storage_tier="bb")
        rt.barrier(final=True)
    t = rt.scheduler.completed[0]
    assert t.state == TaskState.DONE and t.retries == 0
    assert t.device.tier == "bb" and t.start_time >= 5.0


def test_degraded_tier_slows_io_without_failing_it():
    _, rt_healthy = _launch_log_of(None)
    log, rt_deg = _launch_log_of(
        FailureSchedule([(0.0, "bb", "degraded", 0.25)]))
    assert all(t.state == TaskState.DONE
               for t in rt_deg.scheduler.completed)
    assert all(t.retries == 0 for t in rt_deg.scheduler.completed)
    assert rt_deg.stats()["makespan"] > rt_healthy.stats()["makespan"]


def test_offline_without_retry_budget_fails_task():
    _fresh_tids()
    with IORuntime(two_tier(), backend=SimBackend(),
                   failures=FailureSchedule([(0.5, "bb", "offline")])) as rt:
        @constraint(storageBW=50.0, maxRetries=0)
        @io
        @task(returns=1)
        def wr(i):
            pass

        @task(returns=1)
        def consume(x):
            pass
        futs = [wr(i, io_mb=200.0, storage_tier="bb") for i in range(2)]
        deps = [consume(f) for f in futs]
        rt.barrier(final=True)
        del futs, deps
    by_name = {}
    for t in rt.graph.tasks.values():
        by_name.setdefault(t.defn.name, []).append(t)
    assert all(t.state == TaskState.FAILED for t in by_name["wr"])
    # data-descendants of the dead writes are cancelled, not left stuck
    assert all(t.state == TaskState.FAILED for t in by_name["consume"])
    assert rt.graph.unfinished == 0


# ----------------------------------------------- catalog recovery ladder
def _sentinel(rt, duration):
    @task(returns=1)
    def keep_alive():
        pass
    return keep_alive(duration=duration)


def test_offline_drops_residency_and_reruns_lineage():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True, auto_prefetch=False)
    sched = FailureSchedule([(3.0, "bb", "offline")])
    with IORuntime(two_tier(), backend=SimBackend(), lifecycle=cfg,
                   failures=sched) as rt:
        @constraint(maxRetries=2)
        @io
        @task(returns=1)
        def wr(i):
            pass
        futs = [wr(i, io_mb=64.0, storage_tier="bb") for i in range(2)]
        _sentinel(rt, duration=8.0)  # keep the sim alive past t=3
        rt.barrier(final=True)
        cat = rt.catalog
        objs = [obj_of(cat, f) for f in futs]
    bb = device(rt.cluster, "bb")
    # every residency on the dead device was dropped at the transition...
    assert not cat._resident.get(id(bb))
    # ...and lineage re-runs reproduced each orphan on a healthy device
    assert cat.lost_objects == []
    for obj in objs:
        assert obj.residency, f"{obj.name} not recovered"
        assert all(d.health != "offline" for d in obj.residency.values())
        assert not obj.recovering
    recov = [t for t in rt.scheduler.completed
             if t.defn.name == "lineage_recover"]
    assert len(recov) == 2
    assert rt.stats()["lifecycle"]["n_lost_objects"] == 0


def test_redrain_restores_durable_copy_after_fs_outage():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True, auto_prefetch=False)
    # the durable FS dies at t=3 and heals at t=6; the shard's fast-tier
    # copy survives, so recovery is an emergency re-drain, not a re-run
    sched = FailureSchedule([(3.0, "fs", "offline"), (6.0, "fs", "healthy")])
    with IORuntime(two_tier(), backend=SimBackend(), lifecycle=cfg,
                   failures=sched) as rt:
        @constraint(maxRetries=2)
        @io
        @task(returns=1)
        def wr():
            pass
        fut = wr(io_mb=64.0, storage_tier="bb")
        rt.drain(fut, "fs", io_mb=64.0)  # durable copy, alongside bb's
        _sentinel(rt, duration=10.0)
        rt.barrier(final=True)
        cat = rt.catalog
        obj = obj_of(cat, fut)
    assert cat.lost_objects == []
    assert set(obj.residency) >= {"bb", "fs"}, obj.residency
    assert all(d.health == "healthy" for d in obj.residency.values())
    # no lineage re-run happened: the surviving copy fed the re-drain
    assert not any(t.defn.name == "lineage_recover"
                   for t in rt.scheduler.completed)


def test_external_object_with_no_lineage_is_lost():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True, auto_prefetch=False,
                          durable_tier="fs")
    sched = FailureSchedule([(2.0, "bb", "offline")])
    with IORuntime(two_tier(), backend=SimBackend(), lifecycle=cfg,
                   failures=sched) as rt:
        ext = rt.external_data("inputs.h5", 128.0, "bb")
        _sentinel(rt, duration=5.0)
        rt.barrier(final=True)
        cat = rt.catalog
        obj = obj_of(cat, ext)
    # no producer recorded -> unrecoverable, and reported as such
    assert obj in cat.lost_objects and not obj.residency
    assert rt.stats()["lifecycle"]["n_lost_objects"] == 1


def test_discarded_ephemeral_dropped_without_recovery():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True, auto_prefetch=False)
    sched = FailureSchedule([(3.0, "bb", "offline")])
    with IORuntime(two_tier(), backend=SimBackend(), lifecycle=cfg,
                   failures=sched) as rt:
        @io
        @task(returns=1)
        def wr():
            pass
        fut = wr(io_mb=64.0, storage_tier="bb")
        rt.discard(fut)  # never read again: losing it is not a loss
        _sentinel(rt, duration=6.0)
        rt.barrier(final=True)
        cat = rt.catalog
    assert cat.lost_objects == []
    assert not any(t.defn.name == "lineage_recover"
                   for t in rt.graph.tasks.values())


def test_sanitizer_on_failure_run_is_clean_and_identical():
    def run(sanitize):
        _fresh_tids()
        cfg = LifecycleConfig(enabled=True, auto_prefetch=False)
        sched = FailureSchedule([(1.0, "bb", "offline")])
        with IORuntime(two_tier(), backend=SimBackend(sanitize=sanitize),
                       lifecycle=cfg, failures=sched) as rt:
            _write_burst(rt, n=4)
            _sentinel(rt, duration=6.0)
            rt.barrier(final=True)
        return list(rt.scheduler.launch_log)
    # IOSan's offline-residency invariant holds through the transition,
    # and a sanitizer-on run stays bit-identical to sanitizer-off
    assert run(True) == run(False)


# ------------------------------------------------------------- lint IO501
def test_io501_flags_schedule_that_kills_durable_tier_forever():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True, durable_tier="fs")
    with IORuntime(two_tier(), backend="capture", lifecycle=cfg,
                   failures=FailureSchedule([(1.0, "fs", "offline")])) as rt:
        pass
    diags = [d for d in rt.lint() if d.code == "IO501"]
    assert len(diags) == 1
    assert "durable tier" in diags[0].message


def test_io501_quiet_when_durable_tier_recovers():
    _fresh_tids()
    cfg = LifecycleConfig(enabled=True, durable_tier="fs")
    sched = FailureSchedule([(1.0, "fs", "offline"), (4.0, "fs", "healthy")])
    with IORuntime(two_tier(), backend="capture", lifecycle=cfg,
                   failures=sched) as rt:
        pass
    assert not [d for d in rt.lint() if d.code == "IO501"]


# ----------------------------------------- checkpoint crash consistency
def _np_tree():
    import numpy as np
    return {"w": np.arange(8, dtype=np.float32).reshape(2, 4),
            "b": np.ones((3,), np.float32)}


def _tree_equal(a, b):
    import numpy as np
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def test_manifest_commit_fsyncs_file_and_directory(tmp_path, monkeypatch):
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (synced.append(fd),
                                                 real_fsync(fd))[1])
    path = tmp_path / "MANIFEST.json"
    _write_manifest_atomic(path, {"step": 1, "shards": []})
    # one fsync for the manifest bytes, one for the directory entry —
    # without both, "manifest-last" is not crash-consistent
    assert len(synced) == 2
    assert json.loads(path.read_text())["step"] == 1
    assert not (tmp_path / "MANIFEST.json.tmp").exists()


def test_restore_falls_back_when_newest_step_is_torn(tmp_path):
    mgr = CheckpointManager(tmp_path, n_shards=2)
    t = _np_tree()
    mgr.save(1, t, sync=True)
    mgr.save(2, t, sync=True)
    # fast-tier loss after a partial drain: one shard of step 2 vanishes
    gone = next((tmp_path / "step_00000002").glob("shard_*.bin"))
    gone.unlink()
    with pytest.warns(RuntimeWarning, match="falling back to older"):
        restored, step = mgr.restore(t)
    assert step == 1 and _tree_equal(t, restored)
    # an explicitly requested torn step still raises — no silent swap
    with pytest.raises(IOError, match="missing|truncated|No such file"):
        mgr.restore(t, step=2)


def test_restore_falls_back_on_truncated_shard(tmp_path):
    mgr = CheckpointManager(tmp_path, n_shards=1)
    t = _np_tree()
    mgr.save(1, t, sync=True)
    mgr.save(2, t, sync=True)
    shard = next((tmp_path / "step_00000002").glob("shard_*.bin"))
    shard.write_bytes(shard.read_bytes()[:-4])
    with pytest.warns(RuntimeWarning, match="torn"):
        restored, step = mgr.restore(t)
    assert step == 1 and _tree_equal(t, restored)


@pytest.mark.parametrize("kill_point,expect_step,expect_warn", [
    ("before_shards", 3, False),   # step dir created, nothing written
    ("before_manifest", 3, False),  # shards durable, manifest never began
    ("manifest_tmp", 3, False),    # crashed between tmp write and rename
    ("manifest_torn", 3, False),   # garbage manifest bytes
    ("after_manifest_shard_lost", 3, True),  # committed, then shard died
    ("committed", 4, False),       # clean commit
])
def test_restore_kill_point_fuzz(tmp_path, kill_point, expect_step,
                                 expect_warn):
    # every torn on-disk state a crash mid-save can leave behind must
    # restore to the newest *durable* step — never an error, never a
    # half-written tree
    mgr = CheckpointManager(tmp_path, n_shards=2, keep=10)
    t = _np_tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t, sync=True)
    d = tmp_path / "step_00000004"
    if kill_point == "before_shards":
        for p in d.iterdir():
            p.unlink()
    elif kill_point == "before_manifest":
        (d / "MANIFEST.json").unlink()
    elif kill_point == "manifest_tmp":
        (d / "MANIFEST.json").rename(d / "MANIFEST.json.tmp")
    elif kill_point == "manifest_torn":
        (d / "MANIFEST.json").write_text('{"step": 4, "shards":')
    elif kill_point == "after_manifest_shard_lost":
        next(d.glob("shard_*.bin")).unlink()
    if expect_warn:
        with pytest.warns(RuntimeWarning):
            restored, step = mgr.restore(t)
    else:
        restored, step = mgr.restore(t)
    assert step == expect_step and _tree_equal(t, restored)


def test_save_reroutes_to_shared_fs_when_fast_tier_offline(tmp_path):
    fs = StorageDevice(name="fs", bandwidth=2000, per_stream_cap=500,
                       tier="fs")
    bb = StorageDevice(name="bb", bandwidth=4000, per_stream_cap=1000,
                       tier="bb")
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                          tiers=[bb, fs])])
    fast = tmp_path / "fast"
    mgr = CheckpointManager(tmp_path / "ckpt", n_shards=2, fast_dir=fast)
    t = _np_tree()
    bb.set_health("offline")
    with IORuntime(cluster, backend=RealBackend()):
        assert mgr.save(3, t)
        mgr.wait()
    # the burst skipped the dead fast tier entirely: shards landed
    # directly in the durable directory, nothing staged under fast_dir
    assert not list(fast.glob("step_*/shard_*.bin"))
    restored, step = mgr.restore(t)
    assert step == 3 and _tree_equal(t, restored)
