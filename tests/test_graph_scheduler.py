"""Dependency-graph + scheduler invariants (unit + hypothesis properties)."""
import pytest
from hypothesis_support import given, settings, st

from repro.core import (Cluster, DataHandle, INOUT, IORuntime, SchedulerError,
                        SimBackend, constraint, io, task)


def small_cluster(**kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("cpus", 4)
    kw.setdefault("io_executors", 8)
    return Cluster.make(**kw)


def test_future_dependency_ordering():
    order = []
    with IORuntime(small_cluster(), backend=SimBackend()) as rt:
        @task(returns=1)
        def a(x):
            pass

        @task()
        def b(x):
            pass
        f = a(1, duration=5)
        b(f, duration=1)
        rt.barrier(final=True)
        done = rt.scheduler.completed
    assert done[0].defn.name == "a" and done[1].defn.name == "b"
    assert done[1].start_time >= done[0].end_time


def test_inout_serializes_writers():
    with IORuntime(small_cluster(), backend=SimBackend()) as rt:
        @task(value=INOUT)
        def bump(value):
            pass
        h = DataHandle(0)
        for _ in range(4):
            bump(h, duration=3)
        rt.barrier(final=True)
        done = sorted(rt.scheduler.completed, key=lambda t: t.start_time)
    for prev, nxt in zip(done, done[1:]):
        assert nxt.start_time >= prev.end_time - 1e-9  # strict serialization


def test_readers_block_next_writer():
    with IORuntime(small_cluster(), backend=SimBackend()) as rt:
        @task(value=INOUT)
        def write(value):
            pass

        @task()
        def read(value):
            pass
        h = DataHandle(0)
        write(h, duration=1)
        r1 = read(h, duration=10)
        write(h, duration=1)  # write-after-read: must wait for the reader
        rt.barrier(final=True)
        done = rt.scheduler.completed
    writes = [t for t in done if t.defn.name == "write"]
    reads = [t for t in done if t.defn.name == "read"]
    assert writes[1].start_time >= reads[0].end_time - 1e-9


def test_io_overlaps_compute():
    with IORuntime(small_cluster(), backend=SimBackend()) as rt:
        @task(returns=1)
        def work(i):
            pass

        @io
        @task()
        def dump(x):
            pass
        for i in range(24):
            dump(work(i, duration=10), io_mb=40)
        rt.barrier(final=True)
        st_ = rt.stats()
    assert st_["overlap_time"] > 0, "I/O tasks must overlap compute"


def test_bandwidth_never_overallocated():
    cluster = small_cluster(io_executors=50, device_bw=100)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=30)
        @io
        @task()
        def wr(i):
            pass
        for i in range(20):
            wr(i, io_mb=10)
        # at most floor(100/30)=3 concurrent per device
        be = rt.backend
        max_seen = 0
        import repro.core.backends as B

        orig = be._advance_to

        def spy(t):
            nonlocal max_seen
            for w in cluster.workers:
                max_seen = max(max_seen, w.storage.active_io)
                assert w.storage.available_bw >= -1e-9
            orig(t)
        be._advance_to = spy
        rt.barrier(final=True)
    assert max_seen <= 3


def test_unsatisfiable_constraint_raises():
    with pytest.raises(SchedulerError):
        with IORuntime(small_cluster(device_bw=100), backend=SimBackend()) as rt:
            @constraint(storageBW=500)
            @io
            @task()
            def wr(i):
                pass
            wr(0, io_mb=1)
            rt.barrier(final=True)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)), max_size=20))
def test_random_chain_graph_respects_deps(edges):
    """Random two-stage graphs: every consumer starts after its producer."""
    with IORuntime(small_cluster(), backend=SimBackend()) as rt:
        @task(returns=1)
        def prod(i):
            pass

        @task()
        def cons(x, y):
            pass
        outs = [prod(i, duration=1 + i % 3) for i in range(10)]
        for a, b in edges:
            cons(outs[a], outs[b], duration=1)
        rt.barrier(final=True)
        done = {t.tid: t for t in rt.scheduler.completed}
        for t in done.values():
            for dep_tid in t.deps:
                assert t.start_time >= done[dep_tid].end_time - 1e-9


def test_chain_graph_respects_deps_deterministic():
    """Pure-pytest fallback for the random-chain property: a fixed two-stage
    graph (fan-in, fan-out, diamond, self-pair) respects every dependency."""
    edges = [(0, 1), (0, 2), (1, 2), (3, 3), (4, 0), (2, 4), (9, 0), (5, 6)]
    with IORuntime(small_cluster(), backend=SimBackend()) as rt:
        @task(returns=1)
        def prod(i):
            pass

        @task()
        def cons(x, y):
            pass
        outs = [prod(i, duration=1 + i % 3) for i in range(10)]
        for a, b in edges:
            cons(outs[a], outs[b], duration=1)
        rt.barrier(final=True)
        done = {t.tid: t for t in rt.scheduler.completed}
        assert len(done) == 10 + len(edges)
        for t in done.values():
            for dep_tid in t.deps:
                assert t.start_time >= done[dep_tid].end_time - 1e-9
