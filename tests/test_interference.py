"""Interference subsystem tests (ISSUE 4): co-tenant traffic models,
drift-adaptive autotuning, the measured tier-choice objective, ephemeral
objects and pipelined conditional prefetch."""
import itertools

import pytest

from repro.core import (Burst, BurstyTraffic, Cluster, ConstantTraffic,
                        DriftConfig, InterferenceEngine, IORuntime,
                        LifecycleConfig, RealBackend, SimBackend,
                        StorageDevice, TraceTraffic, WorkerNode, constraint,
                        io, task)
from repro.core.autotune import AutoTuner, Phase
from repro.core.constraints import parse_storage_bw
from repro.core.task import TaskInstance


def _fresh_tids():
    TaskInstance._ids = itertools.count()


# ------------------------------------------------------------ traffic models
def test_burst_validation():
    with pytest.raises(ValueError):
        Burst(start=-1.0, duration=1.0)
    with pytest.raises(ValueError):
        Burst(start=0.0, duration=0.0)
    with pytest.raises(ValueError):
        Burst(start=0.0, duration=1.0, bw=-5.0)


def test_bursty_traffic_is_seed_deterministic():
    a = list(itertools.islice(
        BurstyTraffic(seed=11, on_mean=2.0, off_mean=3.0, bw=50.0).bursts(),
        20))
    b = list(itertools.islice(
        BurstyTraffic(seed=11, on_mean=2.0, off_mean=3.0, bw=50.0).bursts(),
        20))
    c = list(itertools.islice(
        BurstyTraffic(seed=12, on_mean=2.0, off_mean=3.0, bw=50.0).bursts(),
        20))
    assert a == b
    assert a != c
    for burst in a:
        assert burst.duration > 0 and burst.start >= 0


def test_bursty_traffic_until_truncates():
    bursts = list(BurstyTraffic(seed=3, on_mean=5.0, off_mean=1.0,
                                until=20.0).bursts())
    assert bursts, "a 20s horizon with 1s mean gaps must produce bursts"
    for b in bursts:
        assert b.start < 20.0
        assert b.start + b.duration <= 20.0 + 1e-9


def test_trace_traffic_jsonl_roundtrip():
    lines = [
        '# co-tenant checkpoint wave',
        '{"t": 10.0, "dur": 5.0, "streams": 32, "bw": 400.0}',
        '{"t": 2.0, "dur": 1.0, "capacity_mb": 64.0}',
        '',
    ]
    tm = TraceTraffic.from_jsonl(lines)
    bursts = list(tm.bursts())
    assert [b.start for b in bursts] == [2.0, 10.0]  # replay by start time
    assert bursts[1].streams == 32 and bursts[1].bw == 400.0
    assert bursts[0].capacity_mb == 64.0


def test_trace_traffic_rejects_bad_lines():
    with pytest.raises(ValueError, match="invalid JSON"):
        TraceTraffic.from_jsonl(['not json'])
    with pytest.raises(ValueError, match="'t' and 'dur'"):
        TraceTraffic.from_jsonl(['{"dur": 1.0}'])


def test_engine_applies_end_before_start_at_equal_time():
    """Back-to-back bursts across models hand the budget over cleanly: the
    end of one burst applies before a start at the same timestamp, so the
    incoming burst is not clamped against budget the outgoing one held."""
    cluster = Cluster.make_tiered(n_workers=1, fs_bw=120.0)
    fs = [d for d in cluster.devices if d.tier == "fs"][0]
    eng = InterferenceEngine(
        [("fs", ConstantTraffic(streams=2, bw=120.0, start=0.0, until=10.0)),
         ("fs", ConstantTraffic(streams=3, bw=100.0, start=10.0,
                                until=20.0))], cluster)
    eng.apply_due(0.0)
    assert fs.background_bw == pytest.approx(120.0)
    eng.apply_due(10.0)
    assert fs.background_streams == 3
    assert fs.background_bw == pytest.approx(100.0), \
        "the t=10 start must see the t=10 end's freed budget"
    eng.apply_due(20.0)
    assert fs.background_bw == 0.0 and fs.background_streams == 0


def test_engine_rejects_unknown_target_and_bad_model():
    cluster = Cluster.make_tiered(n_workers=1)
    with pytest.raises(ValueError, match="matches no tier or device"):
        InterferenceEngine([("nvram", ConstantTraffic(bw=1.0))], cluster)
    with pytest.raises(TypeError, match="TrafficModel"):
        InterferenceEngine([("bb", object())], cluster)


def test_real_backend_refuses_interference():
    cluster = Cluster.make_tiered(n_workers=1)
    with pytest.raises(ValueError, match="simulator"):
        IORuntime(cluster, backend=RealBackend(),
                  interference=[("bb", ConstantTraffic(streams=1))])


# --------------------------------------------------- clamping (device level)
def test_background_bandwidth_clamped_to_free_budget():
    dev = StorageDevice(name="d", bandwidth=100.0)
    dev.allocate(80.0)
    taken = dev.add_background(4, 50.0)  # only 20 free
    assert taken == pytest.approx(20.0)
    assert dev.available_bw == pytest.approx(0.0)
    assert dev.background_streams == 4
    dev.remove_background(4, taken)
    assert dev.available_bw == pytest.approx(20.0)
    assert dev.background_streams == 0
    dev.release(80.0)
    assert dev.available_bw == pytest.approx(dev.bandwidth)


def test_background_capacity_clamped_to_free_space():
    dev = StorageDevice(name="d", bandwidth=100.0, capacity_gb=1.0)  # 1024 MB
    dev.reserve_capacity(1000.0)
    taken = dev.add_background_capacity(500.0)
    assert taken == pytest.approx(24.0)
    assert dev.occupancy_mb <= dev.capacity_mb + 1e-9
    dev.remove_background_capacity(taken)
    assert dev.background_mb == 0.0
    # unlimited devices never hold background capacity
    d2 = StorageDevice(name="u", bandwidth=100.0)
    assert d2.add_background_capacity(500.0) == 0.0


# ------------------------------------------------------- simulator semantics
def _tiny_cluster():
    return Cluster.make_tiered(n_workers=2, cpus=4, io_executors=8,
                               fs_bw=120.0, fs_stream_cap=8.0)


def _run_static(interf, n=6):
    _fresh_tids()
    cluster = _tiny_cluster()
    with IORuntime(cluster, backend=SimBackend(),
                   interference=interf) as rt:
        @io
        @task()
        def wr(i):
            pass
        for i in range(n):
            wr(i, io_mb=40.0, storage_bw=16.0, storage_tier="fs")
        rt.barrier(final=True)
        return rt.stats()["makespan"], list(rt.scheduler.launch_log)


def test_empty_engine_is_bit_identical():
    m0, log0 = _run_static(None)
    m1, log1 = _run_static([])
    assert m0 == m1 and log0 == log1


def test_interference_slows_the_interfered_tier():
    m0, _ = _run_static(None)
    m1, _ = _run_static([("fs", ConstantTraffic(streams=20, bw=60.0))])
    assert m1 > m0


def test_same_seed_same_trace_bit_identical():
    mk = lambda: [("fs", BurstyTraffic(seed=7, on_mean=2.0, off_mean=2.0,
                                       streams=30, bw=80.0))]
    m1, log1 = _run_static(mk())
    m2, log2 = _run_static(mk())
    assert m1 == m2 and log1 == log2


def test_background_bw_claim_blocks_then_releases_grant():
    """A task whose constraint exceeds the co-tenant-free budget waits for
    the burst to end instead of being declared stuck."""
    _fresh_tids()
    cluster = _tiny_cluster()
    burst = ConstantTraffic(streams=1, bw=110.0, until=5.0)  # fs has 120
    with IORuntime(cluster, backend=SimBackend(),
                   interference=[("fs", burst)]) as rt:
        @io
        @task()
        def wr(i):
            pass
        wr(0, io_mb=10.0, storage_bw=100.0, storage_tier="fs")
        rt.barrier(final=True)
        launched_at = [t.start_time for t in rt.scheduler.completed]
    assert launched_at and launched_at[0] >= 5.0


def test_capacity_interference_triggers_eviction():
    _fresh_tids()
    fs = StorageDevice(name="fs", bandwidth=300.0, per_stream_cap=50.0,
                       tier="fs")
    bb = StorageDevice(name="bb", bandwidth=2000.0, per_stream_cap=400.0,
                       tier="bb", capacity_gb=1.0)
    cluster = Cluster(workers=[WorkerNode(
        name="w0", cpus=8, io_executors=32, tiers=[bb, fs])])
    interf = [("bb", ConstantTraffic(capacity_mb=700.0, until=5.0))]
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=False),
                   interference=interf) as rt:
        @io
        @task(returns=1)
        def wshard(prev, i):
            pass
        prev = None
        for i in range(6):
            prev = wshard(prev, i, io_mb=128.0)
        rt.barrier(final=True)
        lc = rt.stats()["lifecycle"]
    assert lc["n_evictions"] > 0
    assert bb.peak_occupancy_mb <= bb.capacity_mb + 1e-6
    assert bb.background_mb == 0.0  # burst fully returned


# --------------------------------------------------------- drift adaptation
def test_drift_config_validation():
    with pytest.raises(ValueError):
        DriftConfig(window=0)
    with pytest.raises(ValueError):
        DriftConfig(min_observations=20, window=10)
    with pytest.raises(ValueError):
        DriftConfig(threshold=1.0)
    with pytest.raises(ValueError):
        DriftConfig(prior_weight=1.0)
    with pytest.raises(ValueError):
        DriftConfig(recal_scope="some")
    with pytest.raises(ValueError):
        DriftConfig(probe_every=1)


def _learned_tuner(drift=None):
    tuner = AutoTuner("sig", parse_storage_bw("auto(8,16,2)"),
                      device_bw=160.0, io_executors=8, drift=drift)
    while tuner.learning():
        assert tuner.admit()
        tuner.epoch.closed_admission = True
        tuner.on_task_complete(1.0)
    return tuner


def test_observe_reenters_calibration_and_blends_prior():
    drift = DriftConfig(window=6, min_observations=3, threshold=1.5,
                        prior_weight=0.5, recal_scope="all")
    tuner = _learned_tuner(drift)
    assert tuner.registry[8.0] == pytest.approx(1.0)
    for _ in range(3):
        tuner.observe(8.0, 4.0)  # 4x slower than learned
    assert tuner.learning(), "drift must re-enter calibration"
    assert tuner.n_recalibrations == 1
    # recal_scope="all" walks every registered constraint and blends each
    # with the decayed prior: re-measured 3.0 blended 50/50 with stale 1.0
    while tuner.learning():
        assert tuner.admit()
        tuner.epoch.closed_admission = True
        tuner.on_task_complete(3.0)
    assert tuner.phase == Phase.DONE
    assert tuner.registry[8.0] == pytest.approx(0.5 * 3.0 + 0.5 * 1.0)
    assert tuner.registry[16.0] == pytest.approx(0.5 * 3.0 + 0.5 * 1.0)


def test_active_recal_scope_remeasures_only_drifted_constraint():
    drift = DriftConfig(window=6, min_observations=3, threshold=1.5,
                        prior_weight=0.5, recal_scope="active")
    tuner = _learned_tuner(drift)
    for _ in range(3):
        tuner.observe(8.0, 4.0)
    assert tuner.learning() and tuner.current_constraint() == 8.0
    assert tuner.admit()
    tuner.epoch.closed_admission = True
    tuner.on_task_complete(3.0)  # one epoch and done
    assert tuner.phase == Phase.DONE
    assert tuner.registry[8.0] == pytest.approx(2.0)   # blended
    assert tuner.registry[16.0] == pytest.approx(1.0)  # untouched


def test_observe_ignores_in_band_ratios():
    tuner = _learned_tuner(DriftConfig(window=6, min_observations=3,
                                       threshold=1.6))
    for _ in range(6):
        tuner.observe(8.0, 1.2)  # within band
    assert not tuner.learning() and tuner.n_recalibrations == 0


def test_observe_detects_speedup_too():
    tuner = _learned_tuner(DriftConfig(window=6, min_observations=3,
                                       threshold=1.5))
    for _ in range(3):
        tuner.observe(8.0, 0.2)  # 5x faster: congestion went away
    assert tuner.learning() and tuner.n_recalibrations == 1


def test_observe_noop_without_drift_config():
    tuner = _learned_tuner(None)
    for _ in range(10):
        tuner.observe(8.0, 100.0)
    assert not tuner.learning() and tuner.n_recalibrations == 0


def test_drift_recalibration_end_to_end():
    """A co-tenant arriving mid-run makes the isolated fit stale; the tuner
    re-enters calibration on the interfered device and the registry moves."""
    _fresh_tids()
    cluster = Cluster.make(n_workers=2, cpus=4, io_executors=16,
                           device_bw=200.0, per_stream_cap=20.0,
                           shared_storage=True)
    interf = [("fs", ConstantTraffic(streams=40, start=8.0))]
    with IORuntime(cluster, backend=SimBackend(), interference=interf,
                   drift=DriftConfig(window=8, min_observations=4,
                                     threshold=1.5)) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck(i):
            pass
        for i in range(200):
            ck(i, io_mb=30.0)
        rt.barrier(final=True)
        tuner = rt.scheduler.tuners["ck"]
        assert tuner.n_recalibrations > 0
        assert tuner.phase == Phase.DONE
        assert rt.stats()["tuners"]["ck"]["n_recalibrations"] > 0


# --------------------------------------------------- measured tier objective
def _shared_two_tier():
    bb = StorageDevice(name="bb", bandwidth=800.0, per_stream_cap=80.0,
                       tier="bb")
    fs = StorageDevice(name="fs", bandwidth=300.0, per_stream_cap=30.0,
                       tier="fs")
    return Cluster(workers=[
        WorkerNode(name=f"w{i}", cpus=4, io_executors=16, tiers=[bb, fs])
        for i in range(2)])


def _run_auto(tier_objective, drift, interf, n=200):
    _fresh_tids()
    cluster = _shared_two_tier()
    with IORuntime(cluster, backend=SimBackend(), interference=interf,
                   drift=drift, tier_objective=tier_objective) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck(i):
            pass
        for i in range(n):
            ck(i, io_mb=40.0)
        rt.barrier(final=True)
        by_tier = {d.tier: d.bytes_written for d in cluster.devices}
        return rt.stats()["makespan"], by_tier, rt.scheduler.tuners


def test_tier_objective_learns_every_tier():
    makespan, by_tier, tuners = _run_auto(True, None, None)
    assert set(tuners) == {"ck@bb", "ck@fs"}
    for t in tuners.values():
        assert t.phase == Phase.DONE
    # uncontended: the nominally faster bb tier carries the bulk
    assert by_tier["bb"] > by_tier["fs"]


def test_tier_objective_reroutes_under_interference():
    """Under a heavy co-tenant on the nominally fastest tier, the measured
    objective + drift adaptation route the bulk of the bytes to the
    effectively faster tier and beat the nameplate walk."""
    mk = lambda: [("bb", ConstantTraffic(streams=120, bw=600.0, start=3.0))]
    m_base, bt_base, _ = _run_auto(False, None, mk())
    m_adapt, bt_adapt, tuners = _run_auto(
        True, DriftConfig(window=8, min_observations=4, threshold=1.5),
        mk())
    assert bt_base["fs"] == 0.0, "nameplate walk never leaves tier 0"
    assert bt_adapt["fs"] > bt_adapt["bb"], "measured walk must reroute"
    assert m_adapt < m_base
    assert tuners["ck@bb"].n_recalibrations > 0


# ------------------------------------------------ ephemeral objects (discard)
def _two_tier(ssd_cap_gb):
    fs = StorageDevice(name="fs", bandwidth=300.0, per_stream_cap=50.0,
                       tier="fs")
    ssd = StorageDevice(name="ssd", bandwidth=2000.0, per_stream_cap=400.0,
                        tier="ssd", capacity_gb=ssd_cap_gb)
    return Cluster(workers=[WorkerNode(name="w0", cpus=8, io_executors=32,
                                       tiers=[ssd, fs])])


def test_discard_requires_lifecycle():
    _fresh_tids()
    with IORuntime(Cluster.make(n_workers=1), backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def w(i):
            pass
        f = w(0, io_mb=1.0)
        with pytest.raises(RuntimeError, match="lifecycle"):
            rt.discard(f)


def test_discarded_objects_evict_without_drain():
    _fresh_tids()
    cluster = _two_tier(0.25)
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=False)) as rt:
        @io
        @task(returns=1)
        def wtmp(prev, i):
            pass
        prev = None
        for i in range(5):
            prev = wtmp(prev, i, io_mb=100.0)
            rt.discard(prev)
        rt.barrier(final=True)
        lc = rt.stats()["lifecycle"]
        drains = [t for t in rt.scheduler.completed
                  if t.defn.name == "tier_drain"]
        assert lc["n_discards"] > 0
        assert not drains, "ephemeral eviction must skip the durable drain"
        assert all(e["mode"] == "discard" for e in rt.catalog.events)


def test_non_discarded_objects_still_drain():
    _fresh_tids()
    cluster = _two_tier(0.25)
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=False)) as rt:
        @io
        @task(returns=1)
        def wtmp(prev, i):
            pass
        prev = None
        for i in range(5):
            prev = wtmp(prev, i, io_mb=100.0)
        rt.barrier(final=True)
        assert any(t.defn.name == "tier_drain"
                   for t in rt.graph.tasks.values()), \
            "durable objects keep the drain-then-delete path"


def test_discard_before_produced_defers_like_pin():
    _fresh_tids()
    cluster = _two_tier(8.0)
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=False)) as rt:
        @io
        @task(returns=1)
        def w(i):
            pass
        f = w(0, io_mb=10.0)
        rt.discard(f)  # producer may not have registered yet
        rt.barrier(final=True)
        obj = rt.catalog.lookup_future(f)
        assert obj is not None and obj.ephemeral


# ---------------------------------------- prefetch under producer pipelining
def _run_pipeline(pipeline, n=4):
    _fresh_tids()
    cluster = _two_tier(8.0)
    cfg = LifecycleConfig(auto_prefetch=True, pipeline_prefetch=pipeline)
    with IORuntime(cluster, backend=SimBackend(), lifecycle=cfg) as rt:
        @constraint(tier="fs")
        @io
        @task(returns=1)
        def produce(i):
            pass

        @task(returns=1)
        def consume(x, i):
            pass
        for i in range(n):
            p = produce(i, io_mb=200.0)  # lands on fs
            consume(p, i, duration=2.0)  # submitted while p is pending
        rt.barrier(final=True)
        lc = rt.stats()["lifecycle"]
        pen = sum(t.read_penalty for t in rt.scheduler.completed
                  if t.defn.name == "consume")
        return lc, pen, rt


def test_pipelined_consumer_gets_conditional_staging():
    lc_off, pen_off, _ = _run_pipeline(False)
    lc_on, pen_on, _ = _run_pipeline(True)
    assert lc_off["n_deferred_stages"] == 0
    assert lc_on["n_deferred_stages"] > 0
    assert lc_on["n_prefetches"] > 0, "useful movers become real stagings"
    assert pen_on < pen_off, "staged consumers read from the fast tier"


def test_useless_deferred_stage_is_neutralized():
    """Producer lands on the target tier itself: the conditional mover must
    become a zero-cost pass-through, not a copy."""
    _fresh_tids()
    cluster = _two_tier(8.0)
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=True)) as rt:
        @io
        @task(returns=1)
        def produce(i):
            pass  # tier-agnostic: lands on the fast ssd

        @task(returns=1)
        def consume(x, i):
            pass
        p = produce(0, io_mb=50.0)
        c = consume(p, 0, duration=0.5)
        rt.barrier(final=True)
        lc = rt.stats()["lifecycle"]
        movers = [t for t in rt.graph.tasks.values()
                  if t.defn.name == "tier_prefetch"]
        assert lc["n_deferred_stages"] == 1
        assert lc["n_prefetches"] == 0, "no staging needed"
        assert len(movers) == 1 and movers[0].sim.io_bytes == 0.0


def test_pipelined_stage_shared_by_sibling_readers():
    _fresh_tids()
    cluster = _two_tier(8.0)
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=True)) as rt:
        @constraint(tier="fs")
        @io
        @task(returns=1)
        def produce(i):
            pass

        @task(returns=1)
        def consume(x, i):
            pass
        p = produce(0, io_mb=100.0)
        for i in range(3):
            consume(p, i, duration=0.5)
        rt.barrier(final=True)
        lc = rt.stats()["lifecycle"]
        assert lc["n_deferred_stages"] == 1, "siblings ride one mover"
        assert lc["n_prefetches"] == 1


def test_pipelined_stage_cancelled_with_failed_producer():
    _fresh_tids()
    cluster = _two_tier(8.0)
    with IORuntime(cluster, backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=True)) as rt:
        @constraint(tier="fs")
        @io
        @task(returns=1)
        def produce(i):
            pass

        @task(returns=1)
        def consume(x, i):
            pass
        p = produce(0, io_mb=100.0, sim_fail=True)
        c = consume(p, 0, duration=0.5)
        rt.barrier(final=True)
        from repro.core import TaskState
        states = {t.defn.name: t.state for t in rt.graph.tasks.values()}
        assert states["produce"] == TaskState.FAILED
        assert states["tier_prefetch"] == TaskState.FAILED  # cancelled
        assert states["consume"] == TaskState.FAILED
        assert not rt.catalog._deferred_stage, "failed decisions cleaned up"
