"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracle
(interpret mode on CPU) + gradients through the custom_vjp wrappers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref


pytestmark = pytest.mark.slow  # jax model / e2e tier (CI runs -m "not slow")


def rnd(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


FLASH_CASES = [
    # (B, S, H, KV, hd, causal, window, bq, bk)
    (1, 128, 4, 4, 64, True, 0, 64, 64),
    (2, 256, 8, 2, 64, True, 0, 128, 64),
    (1, 256, 4, 4, 32, False, 0, 128, 128),
    (2, 128, 4, 2, 64, True, 32, 64, 64),
    (1, 512, 2, 1, 128, True, 128, 128, 128),
    (1, 128, 4, 4, 64, True, 0, 128, 32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_matches_ref(case, dtype, tol):
    B, S, H, KV, hd, causal, win, bq, bk = case
    q = rnd(1, (B, S, H, hd), dtype)
    k = rnd(2, (B, S, KV, hd), dtype)
    v = rnd(3, (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal, win, bq, bk)
    ref = attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_grads_match_ref():
    q = rnd(4, (1, 128, 4, 32), jnp.float32)
    k = rnd(5, (1, 128, 2, 32), jnp.float32)
    v = rnd(6, (1, 128, 2, 32), jnp.float32)
    for argnum in range(3):
        g1 = jax.grad(lambda *a: flash_attention(*a, True, 0, 64, 64).sum(),
                      argnums=argnum)(q, k, v)
        g2 = jax.grad(lambda *a: attention_ref(*a, causal=True).sum(),
                      argnums=argnum)(q, k, v)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-5, rtol=1e-5)


SSD_CASES = [
    # (b, nc, Q, H, P, N)
    (1, 4, 32, 8, 32, 16),
    (2, 2, 64, 4, 16, 32),
    (1, 8, 16, 16, 64, 128),
    (1, 2, 128, 8, 64, 64),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
def test_ssd_matches_ref(case, dtype, tol):
    b, nc, Q, H, P, N = case
    x = rnd(7, (b, nc, Q, H, P), dtype) * 0.5
    dt = jax.nn.softplus(rnd(8, (b, nc, Q, H), jnp.float32))
    Bm, Cm = rnd(9, (b, nc, Q, N), jnp.float32), rnd(10, (b, nc, Q, N), jnp.float32)
    la = dt * (-jnp.exp(rnd(11, (H,), jnp.float32) * 0.2))
    D = jnp.ones((H,))
    y1, h1 = ssd_scan(x, dt, Bm, Cm, la, D)
    y2, h2 = ssd_scan_ref(x, dt, Bm, Cm, la, D)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=tol, rtol=tol)


def test_ssd_state_continuity():
    """Final state from one call seeds sequential decode equivalence: the
    chunked scan must equal a plain step-by-step recurrence."""
    b, nc, Q, H, P, N = 1, 2, 16, 4, 8, 8
    x = rnd(12, (b, nc, Q, H, P), jnp.float32) * 0.3
    dt = jax.nn.softplus(rnd(13, (b, nc, Q, H), jnp.float32))
    Bm, Cm = rnd(14, (b, nc, Q, N), jnp.float32), rnd(15, (b, nc, Q, N), jnp.float32)
    A = -jnp.exp(rnd(16, (H,), jnp.float32) * 0.1)
    la = dt * A
    D = jnp.zeros((H,))
    _, h_last = ssd_scan_ref(x, dt, Bm, Cm, la, D)
    # naive per-step recurrence
    h = jnp.zeros((b, H, N, P))
    S = nc * Q
    xf = x.reshape(b, S, H, P)
    dtf = dt.reshape(b, S, H)
    Bf, Cf = Bm.reshape(b, S, N), Cm.reshape(b, S, N)
    for t in range(S):
        dec = jnp.exp(dtf[:, t] * A)
        h = h * dec[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bf[:, t], xf[:, t] * dtf[:, t][..., None])
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last),
                               atol=1e-4, rtol=1e-4)


def test_model_level_kernel_equivalence():
    from repro.configs import get_smoke_config
    from repro.models import Model
    for arch, flag in [("tinyllama-1.1b", "use_flash"),
                       ("mamba2-2.7b", "use_ssd_kernel"),
                       ("zamba2-1.2b", "use_ssd_kernel")]:
        cfg0 = get_smoke_config(arch).replace(dtype=jnp.float32)
        cfg1 = cfg0.replace(**{flag: True})
        m0, m1 = Model(cfg0), Model(cfg1)
        p, _ = m0.init(jax.random.PRNGKey(1))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 64),
                                              0, cfg0.vocab_size),
                 "targets": jax.random.randint(jax.random.PRNGKey(3), (2, 64),
                                               0, cfg0.vocab_size)}
        l0, l1 = m0.loss(p, batch), m1.loss(p, batch)
        assert abs(float(l0) - float(l1)) < 1e-3, arch
