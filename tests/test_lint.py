"""iolint: table-driven coverage of every diagnostic code (one minimal
failing program per code), capture-mode guarantees (no task body ever
executes), the golden zero-diagnostics check over examples/quickstart.py,
the IOSan inline sanitizer (bit-identical launch logs with the checks on,
violations reported with a trace), and the early-validation satellites
(RealBackend tier_dirs keys, TraceTraffic.from_jsonl line numbers).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import (BurstyTraffic, Cluster, IORuntime, LifecycleConfig,
                        RealBackend, SimBackend, StorageDevice, TraceTraffic,
                        WorkerNode, constraint, io, task)
from repro.analysis import Diagnostic, SanitizerError
from repro.analysis.lint import lint_script

REPO = Path(__file__).resolve().parents[1]


def tiered(**kw):
    return Cluster.make_tiered(n_workers=2, **kw)


# --------------------------------------------------------------------------
# one minimal failing program per diagnostic code
# --------------------------------------------------------------------------
# each builder returns (runtime-after-run, expected offending task signature
# or None for config-level diagnostics); registered as
# (code, message substring, builder)
CASES = []


def case(code, substr):
    def deco(fn):
        CASES.append(pytest.param(code, substr, fn, id=code))
        return fn
    return deco


@case("IO101", "exceeds every eligible device's bandwidth")
def _io101():
    @constraint(storageBW=10**6)
    @io
    @task(returns=1)
    def over_bw(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        over_bw(1, io_mb=8)
    return rt, "over_bw"


@case("IO102", "not present on any worker")
def _io102():
    @constraint(tier="nvram")
    @io
    @task(returns=1)
    def bad_tier(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        bad_tier(1, io_mb=8)
    return rt, "bad_tier"


@case("IO103", "exceeds every worker's cpus")
def _io103():
    @constraint(computingUnits=10**4)
    @task(returns=1)
    def big_cu(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        big_cu(1)
    return rt, "big_cu"


@case("IO104", "lower bound")
def _io104():
    @constraint(storageBW="auto(50000,90000,1000)")
    @io
    @task(returns=1)
    def auto_min(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        auto_min(1, io_mb=8)
    return rt, "auto_min"


@case("IO201", "exceeds every eligible device's total capacity")
def _io201():
    @io
    @task(returns=1)
    def fat_write(x):
        pass

    with IORuntime(tiered(ssd_capacity_gb=0.001),
                   backend="capture") as rt:
        fat_write(1, io_mb=500.0, storage_tier="ssd")
    return rt, "fat_write"


@case("IO202", "the run will wedge capacity-blocked")
def _io202():
    @io
    @task(returns=1)
    def hot_write(x):
        pass

    with IORuntime(tiered(ssd_capacity_gb=0.004),
                   backend="capture") as rt:
        # each write fits a 4 MB SSD, but pinning all three (12 MB) exceeds
        # the tier's total (2 workers x 4 MB): nothing is evictable
        for i in range(3):
            rt.pin(hot_write(i, io_mb=3.0, storage_tier="ssd"))
    return rt, "hot_write"


@case("IO203", "pin without a matching unpin")
def _io203():
    @io
    @task(returns=1)
    def pinned_write(x):
        pass

    with IORuntime(tiered(ssd_capacity_gb=1.0), backend="capture") as rt:
        rt.pin(pinned_write(1, io_mb=8.0))
    return rt, "pinned_write"


@case("IO204", "durable tier")
def _io204():
    # finite fs (the default durable tier) + auto_evict: a live runtime
    # refuses this config; capture records it as a diagnostic instead
    with IORuntime(tiered(ssd_capacity_gb=1.0, fs_capacity_gb=1.0),
                   backend="capture",
                   lifecycle=LifecycleConfig(auto_evict=True)) as rt:
        pass
    return rt, None


@case("IO301", "race on path")
def _io301():
    @io
    @task(returns=1)
    def appender(path):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        appender(path="/scratch/shared.log", io_mb=4)
        appender(path="/scratch/shared.log", io_mb=4)
    return rt, "appender"


@case("IO302", "after rt.discard()")
def _io302():
    @io
    @task(returns=1)
    def temp_write(x):
        pass

    @task(returns=1)
    def late_read(x):
        pass

    with IORuntime(tiered(ssd_capacity_gb=1.0), backend="capture") as rt:
        f = temp_write(1, io_mb=4.0)
        rt.discard(f)
        late_read(f)
    return rt, "late_read"


@case("IO303", "no dependency on a producer")
def _io303():
    with IORuntime(tiered(), backend="capture") as rt:
        rt.drain(None, to_tier="fs", from_tier="ssd", io_mb=64.0)
    return rt, "tier_drain"


@case("IO304", "no ordering after shard task")
def _io304():
    @io
    @task(returns=1)
    def ckpt_shard(i):
        pass

    @io
    @task(returns=1)
    def ckpt_commit(m):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        for i in range(2):
            ckpt_shard(i, io_mb=8.0)
        ckpt_commit("manifest", io_mb=1.0)  # no shard futures passed
    return rt, "ckpt_commit"


@case("IO401", "has no seed")
def _io401():
    traffic = [("fs", BurstyTraffic(None, on_mean=2.0, off_mean=8.0,
                                    bw=100.0))]
    with IORuntime(tiered(), backend="capture", interference=traffic) as rt:
        pass
    return rt, None


@case("IO402", "unseeded RNG source")
def _io402():
    @task(returns=1)
    def entropy(x):
        import random
        return random.random()

    with IORuntime(tiered(), backend="capture") as rt:
        entropy(1)
    return rt, "entropy"


@case("IO601", "ping-pongs across shards")
def _io601():
    @task(returns=1)
    def hop(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        r = hop(0, shard_key=0)
        hop(r, shard_key=1)  # alternating anchors: every edge cross-shard
    return rt, "hop"


@case("IO602", "distinct workers")
def _io602():
    @constraint(tier="bb", storageBW=100)
    @io
    @task(returns=1)
    def publish(i):
        pass

    @task(returns=1)
    def read(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        m = publish(0, io_mb=8.0)
        read(m, shard_key=0)
        read(m, shard_key=1)  # shared-tier output fanned across anchors
    return rt, "publish"


@pytest.mark.parametrize("code,substr,builder", CASES)
def test_code_fires(code, substr, builder):
    rt, offender = builder()
    diags = rt.lint()
    hits = [d for d in diags if d.code == code]
    assert hits, f"{code} not emitted; got {[str(d) for d in diags]}"
    d = hits[0]
    assert substr in d.message, str(d)
    assert d.task == offender
    if offender is None:
        assert d.tid is None


def test_lint_categories_covered():
    cats = {p.values[0][2:3] for p in CASES}
    # category "5" (failure-domains) is exercised end-to-end in
    # test_failures.py against live fault-injection runs
    assert cats == {"1", "2", "3", "4", "6"}
    assert len(CASES) >= 12  # distinct codes, each with a dedicated case


def test_diagnostic_str_and_category():
    d = Diagnostic("IO301", "boom", task="wr", tid=7)
    assert d.category == "race/ordering"
    assert str(d) == "IO301 (race/ordering) [wr#7]: boom"
    assert Diagnostic("IO204", "cfg").category == "capacity"


# --------------------------------------------------------------------------
# capture-mode guarantees
# --------------------------------------------------------------------------
def test_capture_never_executes_task_bodies():
    ran = []

    @io
    @task(returns=1)
    def effectful(x):
        ran.append(x)
        return x * 2

    with IORuntime(tiered(), backend="capture") as rt:
        f = effectful(21, io_mb=4)
        g = effectful(f, io_mb=4)
        assert rt.wait_on(g) is None  # capture resolves futures to None
    assert ran == []
    assert rt.capture_mode


def test_capture_records_full_edges_and_zero_clock():
    @task(returns=1)
    def a():
        pass

    @task(returns=1)
    def b(x):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        f = a()
        rt.wait_on(f)      # producer resolves before the consumer submits
        g = b(f)
        rt.wait_on(g)
    cap = rt.backend.capture
    tids = [t.tid for t in cap.tasks]
    assert len(tids) == 2
    # the DONE-producer edge survives (TaskGraph.add would elide it)
    assert cap.edges[tids[1]] == {tids[0]: True}
    assert rt.stats()["makespan"] == 0.0


def test_capture_leaves_live_devices_untouched():
    cluster = tiered(ssd_capacity_gb=1.0)

    @io
    @task(returns=1)
    def wr(x):
        pass

    with IORuntime(cluster, backend="capture") as rt:
        rt.external_data("init", 200.0, "fs", pinned=True)
        rt.pin(wr(1, io_mb=64.0))
        rt.lint()
    for d in cluster.devices:
        assert d.used_mb == 0.0
        assert d.available_bw == d.bandwidth


def test_plan_context_on_live_runtime():
    @io
    @task(returns=1)
    def wr(path):
        pass

    with IORuntime(tiered(), backend=SimBackend()) as rt:
        with rt.plan() as p:
            wr(path="/x.log", io_mb=4)
            wr(path="/x.log", io_mb=4)
        assert [d.code for d in p.lint()] == ["IO301"]
        # the ambient runtime is restored: new submissions go to rt
        f = wr(path="/y.log", io_mb=4)
        assert rt.wait_on(f) is None or True
        assert len(rt.graph.tasks) == 1
        assert rt.lint() == []


def test_clean_program_zero_diagnostics():
    @task(returns=1)
    def gen(i):
        pass

    @constraint(storageBW="auto")
    @io
    @task(returns=1)
    def ck(b, i):
        pass

    with IORuntime(tiered(), backend="capture") as rt:
        for i in range(4):
            ck(gen(i), i, io_mb=8)
    assert rt.lint() == []


# --------------------------------------------------------------------------
# golden check + CLI
# --------------------------------------------------------------------------
def test_quickstart_example_lints_clean():
    diags, notes = lint_script(str(REPO / "examples" / "quickstart.py"))
    assert diags == [], [str(d) for d in diags]
    assert notes == [], notes  # runs end-to-end under capture, no guards hit


def test_cli_exit_codes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    dirty = tmp_path / "dirty.py"
    dirty.write_text(
        "from repro.core import Cluster, IORuntime, constraint, io, task\n"
        "@constraint(tier='tape')\n"
        "@io\n"
        "@task(returns=1)\n"
        "def wr(x): pass\n"
        "with IORuntime(Cluster.make_tiered(n_workers=2)) as rt:\n"
        "    wr(1, io_mb=4)\n")
    clean = tmp_path / "clean.py"
    clean.write_text(
        "from repro.core import Cluster, IORuntime, task\n"
        "@task(returns=1)\n"
        "def f(x): pass\n"
        "with IORuntime(Cluster.make_tiered(n_workers=2)) as rt:\n"
        "    f(1)\n")
    r = subprocess.run([sys.executable, "-m", "repro.lint", str(dirty)],
                       capture_output=True, text=True, env=env, cwd=REPO)
    assert r.returncode == 1
    assert "IO102" in r.stdout
    r = subprocess.run([sys.executable, "-m", "repro.lint", str(clean)],
                       capture_output=True, text=True, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout
    r = subprocess.run([sys.executable, "-m", "repro.lint",
                        str(tmp_path / "missing.py")],
                       capture_output=True, text=True, env=env, cwd=REPO)
    assert r.returncode == 2


# --------------------------------------------------------------------------
# IOSan inline sanitizer
# --------------------------------------------------------------------------
def _small_workload(sanitize):
    @task(returns=1)
    def gen(i):
        pass

    @io
    @task(returns=1)
    def ck(b, i):
        pass

    cluster = tiered(ssd_capacity_gb=0.01)
    with IORuntime(cluster, backend=SimBackend(sanitize=sanitize)) as rt:
        for i in range(12):
            ck(gen(i), i, io_mb=3.0, storage_tier="ssd")
        rt.barrier(final=True)
        return list(rt.scheduler.launch_log), rt.stats()["makespan"]


def test_sanitizer_parity_bit_identical():
    from repro.core.task import TaskInstance
    import itertools
    TaskInstance._ids = itertools.count()
    log_off, mk_off = _small_workload(False)
    TaskInstance._ids = itertools.count()
    log_on, mk_on = _small_workload(True)
    assert log_on == log_off
    assert mk_on == mk_off


def test_sanitizer_catches_occupancy_corruption():
    @io
    @task(returns=1)
    def wr(i):
        pass

    be = SimBackend(sanitize=True)
    with IORuntime(tiered(ssd_capacity_gb=0.1), backend=be) as rt:
        f = wr(0, io_mb=4.0, storage_tier="ssd")
        rt.wait_on(f)
        dev = rt.cluster.workers[0].storage
        before = dev.used_mb
        dev.used_mb = dev.capacity_mb + 64.0  # corrupt: occupancy > capacity
        with pytest.raises(SanitizerError, match="occupancy"):
            be.sanitizer.check(be)
        dev.used_mb = before  # restore for the exit barrier's check


def test_sanitizer_catches_clock_regression():
    be = SimBackend(sanitize=True)
    with IORuntime(tiered(), backend=be) as rt:
        @task(returns=1)
        def f(i):
            pass
        rt.wait_on(f(0, duration=5.0))
        be.clock -= 1.0
        with pytest.raises(SanitizerError, match="went backwards"):
            be.sanitizer.check(be)
        be.clock += 1.0  # restore for the exit barrier's check


def test_sanitizer_error_carries_trace():
    be = SimBackend(sanitize=True)
    with IORuntime(tiered(), backend=be) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass
        rt.wait_on(wr(0, io_mb=2.0))
        dev = rt.cluster.workers[0].storage
        dev.active_io = -3
        with pytest.raises(SanitizerError) as exc:
            be.sanitizer.check(be)
        dev.active_io = 0  # restore for the exit barrier's check
        assert "launch" in str(exc.value)  # event trace in the report


# --------------------------------------------------------------------------
# early-validation satellites
# --------------------------------------------------------------------------
def test_real_backend_rejects_unknown_tier_dirs_key(tmp_path):
    be = RealBackend(tier_dirs={"ssd": tmp_path, "bogus": tmp_path})
    with pytest.raises(ValueError, match=r"bogus.*name no storage tier"):
        IORuntime(tiered(), backend=be)


def test_real_backend_single_tier_cluster_keys_unchecked(tmp_path):
    # on a single-tier cluster tier_dirs labels are plain directory names
    # for tier-agnostic path= movement (see test_real_backend_drain_moves_
    # file) — validation only applies when the cluster models a hierarchy
    dev = StorageDevice(name="d", bandwidth=100, per_stream_cap=50)
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=2,
                                          storage=dev)])
    be = RealBackend(tier_dirs={"ssd": tmp_path, "fs": tmp_path})
    with IORuntime(cluster, backend=be):
        pass


def test_from_jsonl_reports_line_numbers():
    with pytest.raises(ValueError, match="trace line 2: invalid JSON"):
        TraceTraffic.from_jsonl(['{"t": 0, "dur": 1}', "{not json"])
    with pytest.raises(ValueError, match="trace line 1: expected a JSON "
                                         "object"):
        TraceTraffic.from_jsonl(["[1, 2, 3]"])
    with pytest.raises(ValueError, match="trace line 3: needs 't' and "
                                         "'dur'"):
        TraceTraffic.from_jsonl(['{"t": 0, "dur": 1}', "# comment",
                                 '{"t": 4}'])
    with pytest.raises(ValueError, match="trace line 1: invalid record"):
        TraceTraffic.from_jsonl(['{"t": "zero", "dur": 1}'])
