"""Per-architecture smoke tests (deliverable f): reduced config, one
forward + one real train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.models.layers import pad_vocab

B, S = 2, 32


pytestmark = pytest.mark.slow  # jax model / e2e tier (CI runs -m "not slow")


def make_batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.input_mode == "tokens":
        return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
                "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeds":
        return {"embeds": jax.random.normal(k1, (B, S, cfg.d_model)),
                "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    return {"vision_embeds": jax.random.normal(k1, (B, cfg.vision_seq,
                                                    cfg.d_model)),
            "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(k3, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        jax.tree.map(lambda *_: 0, params, axes)), "axes tree must match"
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorms = [jnp.linalg.norm(g.astype(jnp.float32)) for g in
              jax.tree.leaves(grads)]
    assert all(jnp.isfinite(g) for g in gnorms), f"{arch}: non-finite grads"
    opt = adamw_init(params)
    new_p, new_opt, gn = adamw_update(grads, params, opt, AdamWConfig())
    assert jnp.isfinite(gn)
    assert all(jnp.isfinite(l.astype(jnp.float32)).all()
               for l in jax.tree.leaves(new_p))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_smoke_config(a).supports_decode
                                  and get_smoke_config(a).input_mode == "tokens"])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill produces logits of the right shape and
    valid (finite) values; cache pos advances."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits, state = jax.jit(lambda p, b: model.prefill(p, b, 64))(
        params, {"tokens": toks})
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits).all())
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    for _ in range(3):
        logits, state = jax.jit(model.decode_step)(params, state, nxt)
        assert bool(jnp.isfinite(logits).all())
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    from repro.configs import get_config
    spec = {
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    }
    for arch, (L, D, H, KV, F, V) in spec.items():
        c = get_config(arch)
        got = (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
               c.moe_d_ff if c.name == "qwen2-moe-a2.7b" else c.d_ff,
               c.vocab_size)
        assert got == (L, D, H, KV, F, V), f"{arch}: {got}"
    assert get_config("mixtral-8x22b").n_experts == 8
    assert get_config("qwen2-moe-a2.7b").n_experts == 60
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
