"""Observability subsystem tests (ISSUE 8): typed event stream, metrics
timelines, wait-state attribution, Perfetto export, the ``rt.stats()``
schema freeze, inertness/determinism guarantees, and the ``repro.trace``
CLI."""
import itertools
import json
import subprocess
import sys

import pytest

from repro.core import (BurstyTraffic, Cluster, FailureSchedule, IORuntime,
                        LifecycleConfig, SimBackend, StorageDevice,
                        WorkerNode, constraint, io, task)
from repro.core.datalife import DataObject
from repro.core.task import TaskInstance
from repro.obs import (EVENT_SCHEMA, WAIT_STATES, MetricsTimeline,
                       TraceRecorder, perfetto)
from repro.obs.report import attribution, percentile, span_latencies

from benchmarks.failures import export_perfetto
from benchmarks.interference import run_variant as interference_variant
from benchmarks.sched_scale import run_workload


def _fresh_ids():
    TaskInstance._ids = itertools.count()
    DataObject._ids = itertools.count()


def _tiered_cluster(bb_capacity_gb=0.25):
    bb = StorageDevice(name="bb0", bandwidth=800, per_stream_cap=80,
                       tier="bb", capacity_gb=bb_capacity_gb)
    fs = StorageDevice(name="fs0", bandwidth=300, per_stream_cap=30,
                       tier="fs")
    return Cluster(workers=[WorkerNode(name="w0", cpus=4, io_executors=8,
                                       tiers=[bb, fs])])


def _loaded_run(trace=True, n_steps=6):
    """A run exercising every event site: interference bursts, a failure
    transition, lifecycle evictions, auto + static constraints."""
    _fresh_ids()
    cotenant = [("bb", BurstyTraffic(seed=3, on_mean=2.0, off_mean=1.0,
                                     streams=20, bw=300.0))]
    # t=5.0 lands mid-way through step 1's shard burst (bb writes run
    # 4.52-5.52 on the healthy timeline), so the bb death catches I/O in
    # flight (-> retry events) with step 0's shards still resident on the
    # dying tier (-> "lost" evict events)
    sched = FailureSchedule([(5.0, "bb", "offline")])
    with IORuntime(_tiered_cluster(), backend=SimBackend(),
                   lifecycle=LifecycleConfig(auto_prefetch=False),
                   interference=cotenant, failures=sched,
                   trace=trace) as rt:
        @task(returns=1)
        def step(prev, gate, i):
            pass

        @constraint(storageBW=60, maxRetries=3)
        @io
        @task(returns=1)
        def shard(x, i, j):
            pass

        prev, gate = None, None
        for i in range(n_steps):
            prev = step(prev, gate, i, duration=1.5)
            gate = [shard(prev, i, j, io_mb=64.0) for j in range(3)]
        rt.barrier(final=True)
        return rt, rt.stats()


# ----------------------------------------------------- stats schema freeze
# the frozen rt.stats() contract (satellite: schema freeze). Every key
# here must be present with the given type; "wait_states" must be present
# exactly when the run was traced.
STATS_BASE_SCHEMA = {
    "makespan": float,
    "n_tasks": int,
    "n_io_tasks": int,
    "avg_io_task_time": float,
    "tuners": dict,
    "devices": dict,
}
STATS_SIM_SCHEMA = {
    "io_busy_time": float,
    "compute_busy_time": float,
    "overlap_time": float,
    "total_io_mb": float,
    "io_throughput_mbs": float,
    "peak_io_mbs": float,
}
STATS_DEVICE_SCHEMA = {
    "tier": str,
    "bytes_written": float,
    "capacity_mb": (float, type(None)),
    "used_mb": float,
    "peak_occupancy_mb": float,
}
WAIT_SUMMARY_SCHEMA = {
    "states": dict,
    "by_signature": dict,
    "n_tasks": int,
    "total_latency": float,
    "residual": float,
    "min_task_coverage": float,
}


def _check_schema(d, schema, where):
    for key, typ in schema.items():
        assert key in d, f"{where}: missing {key!r}"
        assert isinstance(d[key], typ), \
            f"{where}[{key!r}] is {type(d[key]).__name__}, want {typ}"


def test_stats_schema_plain_run():
    _fresh_ids()
    with IORuntime(Cluster.make(n_workers=2, cpus=4, io_executors=4),
                   backend=SimBackend()) as rt:
        @io
        @task()
        def w(i):
            pass

        for i in range(4):
            w(i, io_mb=10.0)
        rt.barrier(final=True)
        stats = rt.stats()
    _check_schema(stats, STATS_BASE_SCHEMA, "stats")
    _check_schema(stats, STATS_SIM_SCHEMA, "stats")
    for name, dev in stats["devices"].items():
        _check_schema(dev, STATS_DEVICE_SCHEMA, f"devices[{name}]")
    # untraced -> no wait_states key: pre-obs consumers see an identical
    # schema (golden parity depends on this)
    assert "wait_states" not in stats
    assert rt.trace() is None


def test_stats_schema_loaded_traced_run():
    rt, stats = _loaded_run(trace=True)
    _check_schema(stats, STATS_BASE_SCHEMA, "stats")
    _check_schema(stats, STATS_SIM_SCHEMA, "stats")
    for sub in ("lifecycle", "interference", "failures"):
        assert sub in stats, f"loaded run must report {sub}"
    assert "wait_states" in stats
    ws = stats["wait_states"]
    _check_schema(ws, WAIT_SUMMARY_SCHEMA, "wait_states")
    assert set(ws["states"]) == set(WAIT_STATES)
    for sig, states in ws["by_signature"].items():
        assert set(states) == set(WAIT_STATES), sig


def test_stats_wait_states_present_iff_traced():
    _, traced = _loaded_run(trace=True)
    _, plain = _loaded_run(trace=False)
    assert "wait_states" in traced
    assert "wait_states" not in plain
    # and the rest of the schema is unperturbed by tracing
    t = {k: v for k, v in traced.items() if k != "wait_states"}
    assert t == plain


# ------------------------------------------------- determinism / inertness
def test_tracing_is_inert_on_launch_log():
    """Satellite: same seed workload, recorder on vs off -> bit-identical
    launch log and stats (tracing is pure reads)."""
    log_off, stats_off, _ = run_workload(300, trace=False)
    log_on, stats_on, _ = run_workload(300, trace=True)
    assert log_on == log_off
    assert stats_on.pop("wait_states") is not None
    assert stats_on == stats_off


def test_traced_run_is_byte_deterministic():
    """Same seed twice -> byte-identical exported trace (Sim only: the
    recorder's clock is the sim clock, so no wall time leaks in)."""
    rt1, _ = _loaded_run(trace=True)
    rt2, _ = _loaded_run(trace=True)
    assert perfetto.dumps(rt1.recorder) == perfetto.dumps(rt2.recorder)
    assert rt1.recorder.to_jsonl() == rt2.recorder.to_jsonl()


# ------------------------------------------------------- event stream shape
def test_event_stream_matches_frozen_schema():
    rt, _ = _loaded_run(trace=True)
    rec = rt.recorder
    assert rec.events, "loaded run must record events"
    seen_types = set()
    for ev in rec.events:
        et = ev["type"]
        assert et in EVENT_SCHEMA, f"unknown event type {et!r}"
        seen_types.add(et)
        fields = EVENT_SCHEMA[et]
        for f, types in fields.items():
            assert f in ev, f"{et} event missing field {f!r}: {ev}"
            assert isinstance(ev[f], types), \
                f"{et}.{f} is {type(ev[f]).__name__}: {ev}"
        extra = set(ev) - set(fields) - {"type"}
        assert not extra, f"{et} event has undeclared fields {extra}"
    # the loaded scenario exercises the full taxonomy
    for expected in ("submit", "ready", "launch", "complete", "retry",
                     "burst", "health", "evict"):
        assert expected in seen_types, f"no {expected} events recorded"


def test_jsonl_roundtrip():
    rt, _ = _loaded_run(trace=True)
    lines = rt.recorder.to_jsonl().splitlines()
    assert len(lines) == len(rt.recorder.events)
    for line in lines:
        assert json.loads(line)["type"] in EVENT_SCHEMA


def test_metrics_timeline_rows():
    rt, _ = _loaded_run(trace=True)
    tl = rt.recorder.timeline
    rows = tl.device_rows("bb0")
    assert rows, "bb0 must have been sampled"
    for row in rows:
        assert set(row) == set(MetricsTimeline.ROW_FIELDS)
    ts = [r["t"] for r in rows]
    assert ts == sorted(ts)
    assert len(ts) == len(set(ts)), "same-t samples must collapse"


# --------------------------------------------------- wait-state attribution
def test_wait_attribution_covers_every_task_on_interference_bench():
    """Acceptance bar: on the interference benchmark every task's
    end-to-end latency is >= 95% attributed, residual explicit."""
    out = interference_variant(True, 12, seed=12061, trace=True)
    ws = out["wait_states"]
    assert ws is not None
    assert ws["n_tasks"] > 0
    assert ws["min_task_coverage"] >= 0.95
    assert "residual" in ws


def test_wait_breakdown_sums_to_latency():
    rt, _ = _loaded_run(trace=True)
    rec = rt.recorder
    assert rec.waits, "tasks must have wait records"
    for tid, w in rec.waits.items():
        if w.end_t is None:
            continue
        b = rec.task_breakdown(tid)
        assert b["coverage"] >= 0.95, (tid, b)
        parts = sum(b[k] for k in WAIT_STATES)
        assert parts + b["residual"] == pytest.approx(b["total"])


def test_attribution_includes_critical_path():
    rt, _ = _loaded_run(trace=True)
    rep = attribution(rt.recorder, graph=rt.graph)
    assert set(rep) == {"wait_states", "critical_path"}
    cp = rep["critical_path"]
    assert len(cp["path"]) > 1, "chain workload must yield a multi-node path"
    assert cp["length"] > 0
    assert 0.0 <= cp["congestion_fraction"] <= 1.0


# ----------------------------------------------------------------- perfetto
def test_failures_bench_perfetto_export(tmp_path):
    """Acceptance: the failures-bench Perfetto export is structurally a
    Chrome trace with burst, health-transition, and eviction tracks."""
    out = tmp_path / "failures_trace.json"
    meta = export_perfetto(str(out), n_steps=4, t_fail=5.0)
    doc = json.loads(out.read_text())
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    assert meta["n_trace_events"] == len(evs)
    for ev in evs:
        assert {"ph", "pid", "name"} <= set(ev), ev
    phases = {(e["ph"], e.get("cat")) for e in evs}
    assert ("b", "burst") in phases and ("e", "burst") in phases
    assert ("i", "health") in phases
    assert ("i", "evict") in phases
    assert any(e["ph"] == "X" for e in evs), "task slices missing"
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert any(n.startswith("bandwidth") for n in counters)
    # device tracks are named via process metadata
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)


def test_perfetto_span_events():
    rec = TraceRecorder()
    rec.span("req-0", cat="request", t0=0.5, t1=1.25, n_tokens=4)
    evs = json.loads(perfetto.dumps(rec))["traceEvents"]
    b = [e for e in evs if e["ph"] == "b" and e["cat"] == "request"]
    e = [e for e in evs if e["ph"] == "e" and e["cat"] == "request"]
    assert len(b) == 1 and len(e) == 1
    assert e[0]["ts"] - b[0]["ts"] == pytest.approx(0.75e6)


# ------------------------------------------------------------------ rollups
def test_percentile():
    assert percentile([], 0.5) == 0.0
    assert percentile([3.0], 0.99) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.5) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.0) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 1.0) == 4.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 0.5) == pytest.approx(2.5)


def test_span_latencies():
    rec = TraceRecorder()
    rec.span("a", cat="request", t0=0.0, t1=2.0)
    rec.span("b", cat="request", t0=1.0, t1=1.5)
    rec.span("c", cat="other", t0=0.0, t1=9.0)
    assert span_latencies(rec, cat="request") == [2.0, 0.5]


# ---------------------------------------------------------------------- CLI
def test_trace_cli_smoke(tmp_path):
    script = tmp_path / "tiny.py"
    script.write_text(
        "from repro.core import Cluster, IORuntime, SimBackend, io, task\n"
        "with IORuntime(Cluster.make(n_workers=1, cpus=2, io_executors=2),\n"
        "               backend=SimBackend()) as rt:\n"
        "    @io\n"
        "    @task()\n"
        "    def w(i):\n"
        "        pass\n"
        "    for i in range(3):\n"
        "        w(i, io_mb=5.0)\n"
        "    rt.barrier(final=True)\n")
    pf = tmp_path / "trace.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.trace", str(script),
         "--json", "--perfetto", str(pf)],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(proc.stdout)
    assert doc and doc[0]["n_events"] > 0
    assert json.loads(pf.read_text())["traceEvents"]


def test_trace_cli_missing_file_exits_2():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.trace", "/no/such/script.py"],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 2
