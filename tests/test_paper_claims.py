"""The paper's quantitative claims, asserted against the runtime on the
calibrated storage model (small-but-faithful workloads for speed)."""
import pytest

from benchmarks.apps import run_hmmer, run_kmeans
from repro.core import max_concurrent_tasks


def test_unbounded_learning_walk():
    st = run_hmmer("constrained", bw="auto", n=1200, dur=30)
    t = st["tuners"]["checkpointFrag"]
    assert [c for c, _ in t["history"]] == [2.0, 4.0, 8.0, 16.0]
    assert sorted(t["registry"]) == [2.0, 4.0, 8.0]
    assert t["modal_choice"] == 8.0
    # Fig 12a: avg task time halves while the phase continues
    times = [x for _, x in t["history"]]
    assert times[1] <= times[0] / 2 and times[2] <= times[1] / 2
    assert times[3] > times[2] / 2  # violation ends the phase


def test_bounded_learning_walk():
    st = run_hmmer("constrained", bw="auto(2,256,2)", n=1500, dur=30)
    t = st["tuners"]["checkpointFrag"]
    assert [c for c, _ in t["history"]] == [2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
                                            128.0, 256.0]
    assert t["modal_choice"] == 8.0


def test_nonconstrained_congestion_hurts():
    base = run_hmmer("baseline", n=600, dur=30)["makespan"]
    nonc = run_hmmer("io", n=600, dur=30, io_executors=500)["makespan"]
    s8 = run_hmmer("constrained", bw=8, n=600, dur=30)["makespan"]
    assert nonc > base          # Fig 10: I/O tasks alone make things WORSE
    assert s8 < base            # constraints + overlap beat the baseline


def test_static_sweep_u_shape():
    times = {c: run_hmmer("constrained", bw=c, n=600, dur=30)["makespan"]
             for c in (2, 8, 256)}
    assert times[8] < times[2] and times[8] < times[256]
    assert times[256] > 3 * times[8]  # "drastically harms" (paper §5.2.1)


def test_kmeans_learning_task_counts():
    """Paper §5.2.3: bounded auto uses 446 tasks for learning (= sum of
    epoch sizes); unbounded uses 421 in our model (435 in the paper — their
    phase ran one epoch longer; deviation documented in EXPERIMENTS.md)."""
    st = run_kmeans("constrained", bw="auto(2,256,2)", iterations=1)
    t = st["tuners"]["checkpointCenters"]
    learned = sum(min(int(450 // c), 225) for c, _ in t["history"])
    assert learned == 446
    st = run_kmeans("constrained", bw="auto", iterations=1)
    t = st["tuners"]["checkpointCenters"]
    learned = sum(min(int(450 // c), 225) for c, _ in t["history"])
    assert learned == 421


def test_unbounded_start_matches_paper_arithmetic():
    # start = floor(device_bw / io_executors): 225 -> 2, 112 -> 4, 56 -> 8
    for execs, start in [(225, 2.0), (112, 4.0), (56, 8.0)]:
        st = run_hmmer("constrained", bw="auto", n=400, dur=30,
                       io_executors=execs)
        hist = st["tuners"]["checkpointFrag"]["history"]
        assert hist[0][0] == start, (execs, hist)
