"""Property-test harness for scheduler invariants (ISSUE 2 headline
satellite).

Random DAG *recipes* — mixed compute/static-I/O/auto-I/O tasks, random tier
hints, random per-call ``storage_bw`` overrides, injected failures — are run
through ``SimBackend`` on a tiered cluster, and the invariants from
``test_scheduler_invariants.py`` are asserted universally:

* no task lost or stuck (every submitted task ends DONE or FAILED, the
  graph fully drains, resource accounting returns to the budget);
* per-tier bandwidth never over-allocated at any instant (reconstructed
  from the launch/finish timeline, independent of the allocator's own
  underflow checks);
* failed tasks' data-descendants are cancelled, and nothing else is;
* launch order is bit-deterministic across two identical runs;
* makespan is monotonically non-increasing as a tier's bandwidth grows —
  asserted on the sound regime (independent same-class I/O tasks whose
  constraint is at least the per-stream cap): with dependencies or mixed
  classes, adding resources can legally lengthen a list schedule
  (Graham's timing anomalies), so the universal claim is restricted to
  where it is a theorem.

Every property has a deterministic fallback case so the module tests the
same invariants when hypothesis isn't installed (hypothesis_support shim).
"""
import itertools
import os

import pytest
from hypothesis_support import HAVE_HYPOTHESIS, given, settings, st

from repro.core import (Cluster, IORuntime, SimBackend, TaskState,
                        constraint, io, task)
from repro.core.task import TaskInstance

# ---------------------------------------------------------------- harness
TIERS = (None, "ssd", "bb", "fs")
BW_CHOICES = (None, 8.0, 24.0, 64.0, "auto")


def _fresh_tids():
    """Launch logs embed tids; identical recipes must mint identical tids."""
    TaskInstance._ids = itertools.count()


def _sim_backend():
    """REPRO_SANITIZE=1 (nightly CI) arms IOSan: every event boundary
    asserts occupancy/bandwidth/residency invariants in-line. Checks are
    pure reads, so the launch logs the determinism properties compare stay
    bit-identical with the flag on or off."""
    return SimBackend(sanitize=bool(os.environ.get("REPRO_SANITIZE")))


def make_cluster():
    return Cluster.make_tiered(n_workers=3, cpus=4, io_executors=8,
                               ssd_bw=240.0, ssd_stream_cap=16.0,
                               bb_bw=480.0, bb_stream_cap=48.0,
                               fs_bw=120.0, fs_stream_cap=8.0)


def make_capacity_cluster():
    """Same hierarchy with finite fast tiers (64 MB per-worker SSD, 128 MB
    shared burst buffer) so recipes exercise reserve/commit accounting,
    spill, and eviction; the fs tier stays the unlimited durable store."""
    return Cluster.make_tiered(n_workers=3, cpus=4, io_executors=8,
                               ssd_bw=240.0, ssd_stream_cap=16.0,
                               bb_bw=480.0, bb_stream_cap=48.0,
                               fs_bw=120.0, fs_stream_cap=8.0,
                               ssd_capacity_gb=0.0625,
                               bb_capacity_gb=0.125)


def normalize(recipe):
    """Make an arbitrary generated recipe safe/deterministic:
    node = (kind, n_deps, size, bw_idx, tier_idx, fail_flag)."""
    out = []
    for idx, (kind, n_deps, size, bw_idx, tier_idx, fail) in enumerate(recipe):
        bw = BW_CHOICES[bw_idx % len(BW_CHOICES)]
        tier = TIERS[tier_idx % len(TIERS)]
        # throttle injected failures so most DAGs stay mostly alive
        fail = bool(fail) and idx % 4 == 0
        out.append((kind, n_deps, max(1, size), bw, tier, fail))
    return out


def run_recipe(recipe, make=make_cluster, rt_kwargs=None):
    """Build and run the DAG a recipe describes; returns (runtime, cluster,
    expected-fail map by recipe index). ``rt_kwargs`` forwards extra
    IORuntime arguments (e.g. an interference engine)."""
    _fresh_tids()
    cluster = make()
    rt = IORuntime(cluster, backend=_sim_backend(), **(rt_kwargs or {}))
    expected_failed = {}
    with rt:
        @task(returns=1)
        def compute(deps, i):
            pass

        @io
        @task(returns=1)
        def wr(deps, i):
            pass

        @constraint(storageBW="auto")
        @io
        @task(returns=1)
        def ck_auto(deps, i):
            pass

        futs = []
        dep_lists = []
        for idx, (kind, n_deps, size, bw, tier, fail) in enumerate(recipe):
            deps = sorted({(idx * 7 + 3 * d) % idx for d in range(n_deps)}) \
                if idx else []
            dep_lists.append(deps)
            expected_failed[idx] = fail or any(expected_failed[p]
                                               for p in deps)
            dep_futs = [futs[p] for p in deps]
            if kind == "C":
                f = compute(dep_futs, idx, duration=size * 0.05,
                            sim_fail=fail)
            elif kind == "A":
                f = ck_auto(dep_futs, idx, io_mb=float(size),
                            storage_tier=tier, sim_fail=fail)
            else:
                f = wr(dep_futs, idx, io_mb=float(size), storage_bw=bw,
                       storage_tier=tier, sim_fail=fail)
            futs.append(f)
        rt.barrier(final=True)
    return rt, cluster, expected_failed


# ------------------------------------------------------------- invariants
def assert_invariants(rt, cluster, expected_failed):
    tasks = sorted(rt.graph.tasks.values(), key=lambda t: t.tid)
    # -- no task lost or stuck
    assert rt.graph.unfinished == 0
    for t in tasks:
        assert t.state in (TaskState.DONE, TaskState.FAILED), t
    # -- resource accounting returns to the budget on every tier
    for w in cluster.workers:
        assert w.free_cpus == w.cpus
        assert w.free_io_executors == w.io_executors
        assert w.learning_owner is None
    for d in cluster.devices:
        assert abs(d.available_bw - d.bandwidth) < 1e-6, d.name
        assert d.active_io == 0, d.name
    # -- per-tier bandwidth never over-allocated at any instant
    #    (timeline reconstruction from granted intervals)
    by_dev = {}
    for t in tasks:
        if t.device is not None and t.granted_bw > 0:
            by_dev.setdefault(id(t.device), (t.device, []))[1].append(t)
    for dev, members in by_dev.values():
        events = []
        for t in members:
            events.append((t.start_time, 1, t.granted_bw))
            events.append((t.end_time, 0, -t.granted_bw))
        events.sort()  # releases (0) before grants (1) at equal times
        level = 0.0
        for _, _, delta in events:
            level += delta
            assert level <= dev.bandwidth + 1e-6, \
                f"{dev.name} over-allocated: {level} > {dev.bandwidth}"
    # -- failure semantics: FAILED iff injected or a data-ancestor failed
    for idx, t in enumerate(tasks):
        want = expected_failed[idx]
        assert (t.state == TaskState.FAILED) == want, \
            f"task {idx}: state {t.state}, expected_failed={want}"
        if want and not t.sim.fail:
            assert "cancelled" in str(t.error) or "failure" in str(t.error)


# ------------------------------------------------------ deterministic cases
DET_RECIPES = [
    # straight compute chain feeding tiered checkpoints
    [("C", 0, 4, 0, 0, False), ("S", 1, 10, 1, 1, False),
     ("C", 1, 4, 0, 0, False), ("S", 1, 10, 2, 3, False),
     ("A", 1, 8, 0, 0, False), ("A", 1, 8, 0, 2, False)],
    # failure in the middle: data-descendants die, independent branch lives
    [("C", 0, 2, 0, 0, True), ("S", 1, 6, 1, 2, False),
     ("C", 0, 2, 0, 0, False), ("S", 1, 6, 1, 2, False),
     ("C", 2, 2, 0, 0, False)],
    # wide fan-out of mixed overrides on every tier
    [("C", 0, 3, 0, 0, False)] +
    [("S", 1, 5 + j, j, j, False) for j in range(8)] +
    [("A", 2, 6, 0, j, j == 2) for j in range(4)],
]


@pytest.mark.parametrize("recipe_idx", range(len(DET_RECIPES)))
def test_invariants_deterministic(recipe_idx):
    recipe = normalize(DET_RECIPES[recipe_idx])
    rt, cluster, expected = run_recipe(recipe)
    assert_invariants(rt, cluster, expected)


def test_launch_order_deterministic_fallback():
    recipe = normalize(DET_RECIPES[2])
    log1 = run_recipe(recipe)[0].scheduler.launch_log
    log2 = run_recipe(recipe)[0].scheduler.launch_log
    assert log1 == log2 and log1


def _monotone_makespan(sizes, bw_constraint, fs_bw, factor):
    """Independent same-class I/O tasks against the fs tier at two budgets."""
    def run(b):
        _fresh_tids()
        cluster = Cluster.make_tiered(n_workers=2, cpus=4, io_executors=6,
                                      fs_bw=b, fs_stream_cap=8.0)
        with IORuntime(cluster, backend=_sim_backend()) as rt:
            @io
            @task()
            def wr(i):
                pass
            for i, mb in enumerate(sizes):
                wr(i, io_mb=float(mb), storage_bw=bw_constraint,
                   storage_tier="fs")
            rt.barrier(final=True)
            return rt.stats()["makespan"]
    slow = run(fs_bw)
    fast = run(fs_bw * factor)
    assert fast <= slow + 1e-9, (slow, fast)


def test_makespan_monotone_in_tier_bandwidth_fallback():
    # constraint (16) >= per-stream cap (8): congestion-free regime where
    # growing the budget only adds concurrent slots
    _monotone_makespan([10, 30, 5, 25, 40, 12, 8, 33], 16.0, 64.0, 2.0)
    _monotone_makespan([7] * 12, 16.0, 48.0, 1.5)


# --------------------------------------------------- capacity invariants
def assert_capacity_invariants(rt, cluster):
    """Universal data-lifecycle invariants on a finite-capacity hierarchy
    (ISSUE 3): occupancy bounded, accounting drained, eviction safe."""
    cat = rt.catalog
    assert cat.enabled
    tasks = sorted(rt.graph.tasks.values(), key=lambda t: t.tid)
    # -- everything (including runtime-synthesized movers) drained
    assert rt.graph.unfinished == 0
    for t in tasks:
        assert t.state in (TaskState.DONE, TaskState.FAILED), t
    for d in cluster.devices:
        # -- bandwidth budget restored, no reservation leaked
        assert abs(d.available_bw - d.bandwidth) < 1e-6, d.name
        assert d.active_io == 0, d.name
        assert abs(d.reserved_mb) < 1e-6, d.name
        if d.capacity_mb is None:
            continue
        # -- per-tier occupancy never exceeded capacity_gb at any instant
        assert d.peak_occupancy_mb <= d.capacity_mb + 1e-6, \
            f"{d.name}: peak {d.peak_occupancy_mb} > {d.capacity_mb}"
        # -- committed occupancy equals the catalog's resident objects
        resident = cat._resident.get(id(d), set())
        assert abs(d.used_mb - sum(o.size_mb for o in resident)) < 1e-6, \
            (d.name, d.used_mb, sorted(o.name for o in resident))
    # -- eviction audit: durable copy survives, pinned exempt, and no
    #    scheduled reader existed when the victim was selected
    for ev in cat.events:
        assert ev["durable"], ev
        assert not ev["pinned"], ev
        obj = cat.objects[ev["oid"]]
        t_sel = ev["selected_at"]
        for tid, t0, t1 in obj.reader_log:
            assert not (t0 <= t_sel and (t1 is None or t1 > t_sel)), \
                (ev, (tid, t0, t1))


@pytest.mark.parametrize("recipe_idx", range(len(DET_RECIPES)))
def test_capacity_invariants_deterministic(recipe_idx):
    recipe = normalize(DET_RECIPES[recipe_idx])
    rt, cluster, _ = run_recipe(recipe, make=make_capacity_cluster)
    assert_capacity_invariants(rt, cluster)


def test_capacity_eviction_happens_under_pressure_fallback():
    """A write-heavy chain through the tiny SSD/bb must actually trigger
    the eviction path (so the invariants above are not vacuous)."""
    recipe = normalize(
        [("C", 0, 8, 0, 0, False)] +
        [("S", 1, 36, 1, 1, False) for _ in range(14)])
    rt, cluster, _ = run_recipe(recipe, make=make_capacity_cluster)
    assert_capacity_invariants(rt, cluster)
    assert rt.catalog.n_evictions > 0


# ------------------------------------------------------------ properties
NODE = st.tuples(st.sampled_from(["C", "S", "A"]),
                 st.integers(0, 3),      # dep count (resolved modulo idx)
                 st.integers(1, 40),     # duration/io_mb scale
                 st.integers(0, 4),      # bw choice index
                 st.integers(0, 3),      # tier choice index
                 st.booleans())          # failure flag (throttled)


@settings(max_examples=20, deadline=None)
@given(st.lists(NODE, min_size=1, max_size=24))
def test_invariants_random_dags(recipe):
    """Universal invariants over random tiered DAGs with injected faults."""
    recipe = normalize(recipe)
    rt, cluster, expected = run_recipe(recipe)
    assert_invariants(rt, cluster, expected)


@settings(max_examples=20, deadline=None)
@given(st.lists(NODE, min_size=1, max_size=24))
def test_capacity_invariants_random_dags(recipe):
    """Universal capacity/eviction invariants over random tiered DAGs with
    finite fast tiers and injected faults."""
    recipe = normalize(recipe)
    rt, cluster, _ = run_recipe(recipe, make=make_capacity_cluster)
    assert_capacity_invariants(rt, cluster)


@settings(max_examples=10, deadline=None)
@given(st.lists(NODE, min_size=2, max_size=16))
def test_capacity_launch_order_deterministic(recipe):
    """The lifecycle subsystem (evictions, auto-prefetch, penalties) keeps
    two identical runs bit-identical."""
    recipe = normalize(recipe)
    log1 = run_recipe(recipe, make=make_capacity_cluster)[0] \
        .scheduler.launch_log
    log2 = run_recipe(recipe, make=make_capacity_cluster)[0] \
        .scheduler.launch_log
    assert log1 == log2


@settings(max_examples=10, deadline=None)
@given(st.lists(NODE, min_size=2, max_size=16))
def test_launch_order_deterministic(recipe):
    """Two identical runs produce bit-identical launch logs."""
    recipe = normalize(recipe)
    log1 = run_recipe(recipe)[0].scheduler.launch_log
    log2 = run_recipe(recipe)[0].scheduler.launch_log
    assert log1 == log2


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(1, 60), min_size=1, max_size=20),
       st.sampled_from([16.0, 24.0, 40.0]),
       st.sampled_from([40.0, 64.0, 120.0]),
       st.sampled_from([1.25, 2.0, 4.0]))
def test_makespan_monotone_in_tier_bandwidth(sizes, c, fs_bw, factor):
    """Growing a tier's bandwidth never lengthens an independent
    same-class workload (the regime where this is a theorem; see module
    docstring for why dependent DAGs are excluded)."""
    _monotone_makespan(sizes, c, fs_bw, factor)


# ----------------------------------------------- interference invariants
from repro.core import BurstyTraffic, ConstantTraffic  # noqa: E402


def _bursty_interference(seed=97):
    """A heavy bursty co-tenant on both shared tiers (bandwidth + capacity
    pressure), deterministic for a given seed."""
    return [
        ("bb", BurstyTraffic(seed=seed, on_mean=1.5, off_mean=1.0,
                             streams=24, bw=200.0, capacity_mb=48.0)),
        ("fs", BurstyTraffic(seed=seed + 1, on_mean=2.0, off_mean=0.5,
                             streams=16, bw=60.0)),
    ]


def run_recipe_interfered(recipe, make=make_cluster, seed=97):
    """run_recipe with a bursty co-tenant injected on the shared tiers."""
    return run_recipe(recipe, make=make, rt_kwargs={
        "interference": _bursty_interference(seed)})


def assert_interference_invariants(rt, cluster):
    """Universal invariants under co-tenant traffic: everything drains, our
    accounting returns to the budget, and the background claims never
    pushed a device over its bandwidth or capacity (the clamp worked)."""
    tasks = sorted(rt.graph.tasks.values(), key=lambda t: t.tid)
    assert rt.graph.unfinished == 0
    for t in tasks:
        assert t.state in (TaskState.DONE, TaskState.FAILED), t
    for d in cluster.devices:
        # our grants all returned; what is still out is exactly what the
        # co-tenant holds right now (bursts may outlive the run)
        assert d.active_io == 0, d.name
        assert abs(d.available_bw + d.background_bw - d.bandwidth) < 1e-6, \
            (d.name, d.available_bw, d.background_bw)
        assert d.available_bw >= -1e-9 and d.background_bw >= -1e-9
        assert d.background_streams >= 0
        if d.capacity_mb is not None:
            assert d.peak_occupancy_mb <= d.capacity_mb + 1e-6, \
                f"{d.name}: background pushed occupancy over capacity"
            assert d.background_mb >= -1e-9
    # our own bandwidth grants alone never exceeded the budget either
    by_dev = {}
    for t in tasks:
        if t.device is not None and t.granted_bw > 0:
            by_dev.setdefault(id(t.device), (t.device, []))[1].append(t)
    for dev, members in by_dev.values():
        events = []
        for t in members:
            events.append((t.start_time, 1, t.granted_bw))
            events.append((t.end_time, 0, -t.granted_bw))
        events.sort()
        level = 0.0
        for _, _, delta in events:
            level += delta
            assert level <= dev.bandwidth + 1e-6, dev.name


@pytest.mark.parametrize("recipe_idx", range(len(DET_RECIPES)))
def test_interference_invariants_deterministic(recipe_idx):
    recipe = normalize(DET_RECIPES[recipe_idx])
    rt, cluster, _ = run_recipe_interfered(recipe)
    assert_interference_invariants(rt, cluster)


@pytest.mark.parametrize("recipe_idx", range(len(DET_RECIPES)))
def test_interference_capacity_invariants_deterministic(recipe_idx):
    """Bandwidth + capacity co-tenants on a finite-capacity hierarchy: the
    full capacity invariant suite still holds (background claims excluded
    from used_mb, which tracks only resident objects)."""
    recipe = normalize(DET_RECIPES[recipe_idx])
    rt, cluster, _ = run_recipe_interfered(recipe,
                                           make=make_capacity_cluster)
    assert_interference_invariants(rt, cluster)
    cat = rt.catalog
    for d in cluster.devices:
        if d.capacity_mb is None:
            continue
        resident = cat._resident.get(id(d), set())
        assert abs(d.used_mb - sum(o.size_mb for o in resident)) < 1e-6


def test_interference_same_seed_bit_identical_fallback():
    recipe = normalize(DET_RECIPES[2])
    log1 = run_recipe_interfered(recipe)[0].scheduler.launch_log
    log2 = run_recipe_interfered(recipe)[0].scheduler.launch_log
    assert log1 == log2 and log1


def test_zero_interference_config_is_golden_fallback():
    """An engine with every traffic model disabled (no bindings) leaves the
    launch log bit-identical to a run with no engine at all."""
    recipe = normalize(DET_RECIPES[0])
    plain = run_recipe(recipe)[0].scheduler.launch_log
    empty = run_recipe(recipe, rt_kwargs={"interference": []})[0] \
        .scheduler.launch_log
    assert empty == plain


@settings(max_examples=15, deadline=None)
@given(st.lists(NODE, min_size=1, max_size=24),
       st.integers(0, 1000))
def test_interference_invariants_random_dags(recipe, seed):
    """Universal interference invariants over random tiered DAGs with
    random co-tenant seeds and injected faults."""
    recipe = normalize(recipe)
    rt, cluster, _ = run_recipe_interfered(recipe, seed=seed)
    assert_interference_invariants(rt, cluster)


@settings(max_examples=10, deadline=None)
@given(st.lists(NODE, min_size=2, max_size=16), st.integers(0, 1000))
def test_interference_same_seed_same_trace_deterministic(recipe, seed):
    """Same DAG + same co-tenant seed => bit-identical launch logs."""
    recipe = normalize(recipe)
    log1 = run_recipe_interfered(recipe, seed=seed)[0].scheduler.launch_log
    log2 = run_recipe_interfered(recipe, seed=seed)[0].scheduler.launch_log
    assert log1 == log2


# --------------------------------------------- failure-domain invariants
from repro.core import FailureSchedule  # noqa: E402


def _failure_schedule(seed=2):
    """Seeded fault injection against the fast tiers only — the durable fs
    is never targeted, so drains and recoveries always have a home — and
    ``recover=True`` brings every tier back before the horizon, so pinned
    work queues for the recovery instead of wedging the run. Seed 2 makes
    the deterministic recipes hit the whole ladder: offline-induced
    retries, residency drops, and lineage re-runs."""
    return FailureSchedule.seeded(seed, targets=("ssd", "bb"), horizon=6.0)


def run_recipe_failed(recipe, make=make_cluster, seed=2):
    """run_recipe with seeded device/tier faults injected."""
    return run_recipe(recipe, make=make,
                      rt_kwargs={"failures": _failure_schedule(seed)})


def assert_failure_invariants(rt, cluster):
    """Universal invariants under fault injection: everything drains (DONE
    or FAILED, never stuck — device death is not a hang), accounting
    returns to the budget, no residency survives on an offline device, and
    every surviving residency points at a healthy copy."""
    tasks = sorted(rt.graph.tasks.values(), key=lambda t: t.tid)
    assert rt.graph.unfinished == 0
    for t in tasks:
        assert t.state in (TaskState.DONE, TaskState.FAILED), t
    for d in cluster.devices:
        assert d.active_io == 0, d.name
        assert abs(d.available_bw - d.bandwidth) < 1e-6, \
            (d.name, d.available_bw)
        assert abs(d.reserved_mb) < 1e-6, d.name
        if d.capacity_mb is not None:
            assert d.peak_occupancy_mb <= d.capacity_mb + 1e-6, d.name
    cat = rt.catalog
    if cat.enabled:
        for d in cluster.devices:
            resident = cat._resident.get(id(d), set())
            if d.health == "offline":
                assert not resident, \
                    f"{d.name} offline but still lists residents"
            if d.capacity_mb is not None:
                assert abs(d.used_mb - sum(o.size_mb for o in resident)) \
                    < 1e-6, d.name
        for obj in cat.objects.values():
            for dev in obj.residency.values():
                assert dev.health != "offline", obj.name


@pytest.mark.parametrize("recipe_idx", range(len(DET_RECIPES)))
def test_failure_invariants_deterministic(recipe_idx):
    recipe = normalize(DET_RECIPES[recipe_idx])
    rt, cluster, _ = run_recipe_failed(recipe)
    assert_failure_invariants(rt, cluster)


@pytest.mark.parametrize("recipe_idx", range(len(DET_RECIPES)))
def test_failure_capacity_invariants_deterministic(recipe_idx):
    """Faults on a finite-capacity hierarchy: the capacity suite (reserve/
    commit, residency/occupancy agreement) holds through device death and
    the recovery ladder (re-drains + lineage re-runs)."""
    recipe = normalize(DET_RECIPES[recipe_idx])
    rt, cluster, _ = run_recipe_failed(recipe, make=make_capacity_cluster)
    assert_failure_invariants(rt, cluster)


def test_failure_same_seed_bit_identical_fallback():
    recipe = normalize(DET_RECIPES[2])
    log1 = run_recipe_failed(recipe)[0].scheduler.launch_log
    log2 = run_recipe_failed(recipe)[0].scheduler.launch_log
    assert log1 == log2 and log1


def test_zero_failure_config_is_golden_fallback():
    """An empty FailureSchedule never attaches an engine: the launch log is
    bit-identical to a run with no failure wiring at all."""
    recipe = normalize(DET_RECIPES[0])
    plain = run_recipe(recipe)[0].scheduler.launch_log
    empty = run_recipe(recipe, rt_kwargs={
        "failures": FailureSchedule([])})[0].scheduler.launch_log
    assert empty == plain


@settings(max_examples=15, deadline=None)
@given(st.lists(NODE, min_size=1, max_size=24),
       st.integers(0, 1000))
def test_failure_invariants_random_dags(recipe, seed):
    """Universal failure invariants over random tiered DAGs with random
    fault schedules (and the recipes' own injected task faults)."""
    recipe = normalize(recipe)
    rt, cluster, _ = run_recipe_failed(recipe, make=make_capacity_cluster,
                                       seed=seed)
    assert_failure_invariants(rt, cluster)


@settings(max_examples=10, deadline=None)
@given(st.lists(NODE, min_size=2, max_size=16), st.integers(0, 1000))
def test_failure_same_seed_same_trace_deterministic(recipe, seed):
    """Same DAG + same fault seed => bit-identical launch logs."""
    recipe = normalize(recipe)
    log1 = run_recipe_failed(recipe, seed=seed)[0].scheduler.launch_log
    log2 = run_recipe_failed(recipe, seed=seed)[0].scheduler.launch_log
    assert log1 == log2


def test_hypothesis_mode_reported():
    """Self-describing: record which mode the module ran in (the shim skips
    the @given properties without hypothesis; fallbacks always run)."""
    assert HAVE_HYPOTHESIS in (True, False)
