"""Analytic roofline model validation (EXPERIMENTS.md §Roofline methodology):
XLA's compiled cost_analysis counts while-loop bodies once, so the roofline
uses an analytic FLOPs model — validated here against XLA on a small
UNROLLED config where XLA's count is complete."""
import jax
import jax.numpy as jnp
import pytest

from benchmarks.analytic import cell_cost, roofline_terms
from repro.compat import cost_analysis_dict
from repro.configs.base import ModelConfig, ShapeCell
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update

CFG = ModelConfig(name="probe", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
                  remat=True, unroll_layers=True)


def _train_flops():
    model = Model(CFG)
    params = jax.eval_shape(lambda r: model.init(r)[0], jax.random.PRNGKey(0))
    opt = jax.eval_shape(adamw_init, params)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
             "targets": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
    acfg = AdamWConfig()

    def step(p, o, b):
        loss, g = jax.value_and_grad(model.loss)(p, b)
        return adamw_update(g, p, o, acfg) + (loss,)
    c = jax.jit(step).lower(params, opt, batch).compile()
    return cost_analysis_dict(c)["flops"]


def test_analytic_train_flops_within_25pct_of_xla():
    xla = _train_flops()
    an = cell_cost(CFG, ShapeCell("t", 128, 4, "train")).flops
    assert 0.75 < an / xla < 1.25, (an, xla)


def test_analytic_prefill_flops_within_30pct_of_xla():
    model = Model(CFG)
    params = jax.eval_shape(lambda r: model.init(r)[0], jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
    c = jax.jit(lambda p, b: model.prefill(p, b, 128)).lower(
        params, batch).compile()
    xla = cost_analysis_dict(c)["flops"]
    an = cell_cost(CFG, ShapeCell("p", 128, 4, "prefill")).flops
    assert 0.7 < an / xla < 1.3, (an, xla)


def test_roofline_terms_structure():
    t = roofline_terms(CFG, ShapeCell("t", 128, 4, "train"), n_devices=256,
                       collective_bytes_per_dev=1e9)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert 0 < t["useful_ratio"] <= 1.0
    assert t["roofline_fraction"] <= 1.0


def test_model_flops_definition():
    # MODEL_FLOPS = 6*N*D for dense train, 6*N_active*D for MoE
    c = cell_cost(CFG, ShapeCell("t", 128, 4, "train"))
    assert c.model_flops == 6.0 * CFG.param_count() * 4 * 128
    moe = CFG.replace(n_experts=4, n_experts_per_tok=2, moe_d_ff=256, d_ff=0)
    cm = cell_cost(moe, ShapeCell("t", 128, 4, "train"))
    assert cm.model_flops == 6.0 * moe.active_param_count() * 4 * 128
    assert moe.active_param_count() < moe.param_count()
