"""RealBackend: threads, futures, retries, failure propagation, overlap."""
import os
import tempfile
import time

import pytest

from repro.core import (Cluster, IORuntime, RealBackend, StorageDevice,
                        WorkerNode, constraint, io, task)


def small_cluster():
    dev = StorageDevice(name="fs", bandwidth=1000, per_stream_cap=250)
    return Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                       storage=dev)])


def test_values_flow_through_futures():
    with IORuntime(small_cluster(), backend=RealBackend()) as rt:
        @task(returns=1)
        def double(x):
            return x * 2

        @task(returns=1)
        def add(a, b):
            return a + b
        out = add(double(3), double(4))
        assert rt.wait_on(out) == 14


def test_multi_returns():
    with IORuntime(small_cluster(), backend=RealBackend()) as rt:
        @task(returns=2)
        def divmod_(a, b):
            return a // b, a % b
        q, r = divmod_(17, 5)
        assert rt.wait_on(q, r) == [3, 2]


def test_io_task_writes_and_overlaps():
    tmp = tempfile.mkdtemp()
    with IORuntime(small_cluster(), backend=RealBackend()) as rt:
        @task(returns=1)
        def compute(i):
            time.sleep(0.05)
            return bytes(50_000)

        @io
        @task()
        def save(data, i):
            with open(os.path.join(tmp, f"{i}.bin"), "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
        for i in range(6):
            save(compute(i), i)
        rt.barrier(final=True)
    assert len(os.listdir(tmp)) == 6


def test_retry_then_success():
    calls = {"n": 0}
    with IORuntime(small_cluster(), backend=RealBackend()) as rt:
        @constraint(maxRetries=3)
        @io
        @task()
        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise IOError("transient")
        flaky()
        rt.barrier(final=True)
    assert calls["n"] == 3


def test_failure_raises_at_barrier():
    with pytest.raises(RuntimeError, match="failed"):
        with IORuntime(small_cluster(), backend=RealBackend()) as rt:
            @task()
            def boom():
                raise ValueError("nope")
            boom()
            rt.barrier(final=True)
