"""Golden equivalence of the rewritten scheduler/simulator hot path.

The frozen seed implementation (benchmarks/_seed_impl.py) and the rewrite
must produce bit-identical launch logs and stats on the same workload —
the scale speedup (BENCH_sched_scale.json) is only meaningful if the
behaviour is unchanged.
"""
from benchmarks.sched_scale import golden_compare, run_workload
from benchmarks._seed_impl import SeedScheduler, SeedSimBackend


def test_golden_1k_identical():
    report = golden_compare(1_000)  # raises AssertionError on any divergence
    assert report["identical_launch_log"] and report["identical_stats"]


def test_golden_small_odd_sizes():
    # off-by-one shapes: partial learning epochs, a final straggler wave
    for n in (3, 10, 137):
        seed_log, seed_stats, _ = run_workload(
            n, scheduler_cls=SeedScheduler, backend=SeedSimBackend())
        new_log, new_stats, _ = run_workload(n)
        assert seed_log == new_log
        assert seed_stats["makespan"] == new_stats["makespan"]
        assert seed_stats["total_io_mb"] == new_stats["total_io_mb"]
        assert seed_stats["overlap_time"] == new_stats["overlap_time"]
