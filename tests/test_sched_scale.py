"""Golden equivalence of the rewritten scheduler/simulator hot path.

The frozen seed implementation (benchmarks/_seed_impl.py) and the rewrite
must produce bit-identical launch logs and stats on the same workload —
the scale speedup (BENCH_sched_scale.json) is only meaningful if the
behaviour is unchanged.
"""
from benchmarks.sched_scale import golden_compare, run_workload
from benchmarks._seed_impl import SeedScheduler, SeedSimBackend


def test_golden_1k_identical():
    report = golden_compare(1_000)  # raises AssertionError on any divergence
    assert report["identical_launch_log"] and report["identical_stats"]


def test_golden_small_odd_sizes():
    # off-by-one shapes: partial learning epochs, a final straggler wave
    for n in (3, 10, 137):
        seed_log, seed_stats, _ = run_workload(
            n, scheduler_cls=SeedScheduler, backend=SeedSimBackend())
        new_log, new_stats, _ = run_workload(n)
        assert seed_log == new_log
        assert seed_stats["makespan"] == new_stats["makespan"]
        assert seed_stats["total_io_mb"] == new_stats["total_io_mb"]
        assert seed_stats["overlap_time"] == new_stats["overlap_time"]


def test_blocked_head_diagnosis_memoized_per_epoch(monkeypatch):
    """The traced blocked-head diagnosis is memoized per (class head,
    refusal epoch): within one epoch the expensive worker walk runs at
    most once per head, and the count is deterministic run to run."""
    from repro.core.scheduler import Scheduler

    calls = []
    orig = Scheduler._diagnose_block

    def counting(self, task):
        calls.append((task.tid, self._refusal_epoch))
        return orig(self, task)

    monkeypatch.setattr(Scheduler, "_diagnose_block", counting)
    log_a, _, _ = run_workload(400, trace=True)
    count_a = len(calls)
    # the contended workload does block classes -> memoization is exercised
    assert count_a > 0
    # memoized: never two diagnoses of the same head in the same epoch
    assert count_a == len(set(calls))
    calls.clear()
    log_b, _, _ = run_workload(400, trace=True)
    assert len(calls) == count_a and log_b == log_a
