"""Direct scheduler-invariant unit tests (no hypothesis) + the ISSUE 1
satellite regressions: heterogeneous-cluster tuner device, failed-task
descendant cancellation, reserved call-time kwargs.
"""
import time

import pytest

from repro.core import (Cluster, IORuntime, RealBackend, SchedulerError,
                        SimBackend, StorageDevice, TaskState, WorkerNode,
                        constraint, io, task)


def small_cluster(**kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("cpus", 4)
    kw.setdefault("io_executors", 8)
    return Cluster.make(**kw)


# ---------------------------------------------------------------- invariants
def test_bandwidth_conservation_after_drain():
    """available_bw returns exactly to the budget once everything drains."""
    cluster = small_cluster(io_executors=16, device_bw=120)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=30)
        @io
        @task()
        def wr(i):
            pass

        @io
        @task()
        def wr_free(i):  # bw=0 path: executor-only accounting
            pass
        for i in range(40):
            wr(i, io_mb=15)
            wr_free(i, io_mb=5)
        rt.barrier(final=True)
    for w in cluster.workers:
        assert w.storage.available_bw == w.storage.bandwidth
        assert w.storage.active_io == 0
        assert w.free_io_executors == w.io_executors
        assert w.free_cpus == w.cpus


def test_learning_node_isolation():
    """While a tuner is learning, no non-epoch I/O task may land on the
    active-learning node (paper §4.2.3B)."""
    cluster = small_cluster(n_workers=3, io_executors=8)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck_auto(i):
            pass

        @constraint(storageBW=20)
        @io
        @task()
        def ck_static(i):
            pass
        for i in range(120):
            ck_auto(i, io_mb=30)
            ck_static(i, io_mb=10)
        rt.barrier(final=True)
        done = rt.scheduler.completed
    learning_nodes = {t.worker.name for t in done if t.epoch is not None}
    assert learning_nodes, "auto tasks must have run learning epochs"
    for t in done:
        if t.defn.name == "ck_static" and t.worker.name in learning_nodes:
            # a static task on a sometime-learning node must not have
            # overlapped any epoch task running there
            for e in done:
                if e.epoch is not None and e.worker.name == t.worker.name:
                    assert t.start_time >= e.end_time - 1e-9 or \
                        t.end_time <= e.start_time + 1e-9


def test_assert_not_stuck_raises_on_unsatisfiable():
    """A ready task that can never be placed must raise, not spin."""
    cluster = small_cluster(n_workers=1, io_executors=0)  # no I/O platform
    with pytest.raises(SchedulerError):
        with IORuntime(cluster, backend=SimBackend()) as rt:
            @io
            @task()
            def wr(i):
                pass
            wr(0, io_mb=1)
            rt.barrier(final=True)


def test_ready_property_reports_readiness_order():
    cluster = small_cluster(n_workers=1, cpus=1)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @task(returns=1)
        def work(i):
            pass
        futures = [work(i, duration=1) for i in range(5)]
        sched = rt.scheduler
        # sim launches at drain time: the whole backlog is still ready,
        # reported in submission order
        assert [t.tid for t in sched.ready] == sorted(t.tid for t in sched.ready)
        assert sched.n_ready == len(sched.ready) == 5
        rt.barrier(final=True)
        assert sched.n_ready == 0 and not sched.ready
        del futures


def test_fast_device_tiny_ios_drain():
    """NVMe-like device (per-task rate > 1000 MB/s) with sub-millisecond
    transfers: the event-queue horizon (seconds) and the done-threshold (MB)
    are different units, so tiny residuals must not wedge the drain loop."""
    cluster = Cluster.make(n_workers=2, io_executors=4, device_bw=4000,
                           per_stream_cap=3500)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task()
        def wr(i):
            pass
        for i in range(200):
            wr(i, io_mb=0.001 + (i % 3) * 1e-6)
        rt.barrier(final=True)
        st = rt.stats()
    assert st["n_io_tasks"] == 200
    for w in cluster.workers:
        assert w.storage.available_bw == w.storage.bandwidth


# ------------------------------------------------------- satellite: tuner dev
def test_tuner_models_actual_learning_node_device():
    """Two workers with different device bandwidth: the tuner must model the
    device of the node its epochs actually run on, not workers[0]."""
    fast = WorkerNode(name="fast", cpus=4, io_executors=8,
                      storage=StorageDevice(name="fast-ssd", bandwidth=900.0))
    slow = WorkerNode(name="slow", cpus=4, io_executors=8,
                      storage=StorageDevice(name="slow-ssd", bandwidth=100.0))
    cluster = Cluster(workers=[fast, slow])
    # occupy the first worker with another signature's learning phase, so the
    # auto task under test acquires the *slow* node
    fast.learning_owner = "other-sig"
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck(i):
            pass
        for i in range(40):
            ck(i, io_mb=20)
        rt.barrier(final=True)
        tuner = rt.scheduler.tuners["ck"]
    assert tuner.device_bw == slow.storage.bandwidth, \
        "tuner must model the acquired learning node's device"
    # epoch sizing follows the slow device: k = floor(100 / c)
    first_c = tuner.history[0][0]
    assert first_c == float(max(1, int(100.0 // 8)))  # 100bw / 8 executors
    fast.learning_owner = None


# -------------------------------------------- satellite: descendant cancelling
def test_failed_task_cancels_descendants_no_hang():
    cluster = small_cluster()
    rt = IORuntime(cluster, backend=RealBackend(poll_interval=0.005))
    with pytest.raises(RuntimeError, match="failed after"):
        with rt:
            @task(returns=1)
            def boom():
                raise ValueError("kaput")

            @task(returns=1)
            def child(x):
                return x

            @task()
            def grandchild(x):
                pass
            f = boom()
            g = child(f)
            grandchild(g)
            rt.barrier(final=True)
    # the failure propagated: nothing left unfinished, descendants FAILED
    assert rt.graph.unfinished == 0
    states = {t.defn.name: t.state for t in rt.graph.tasks.values()}
    assert states["boom"] == TaskState.FAILED
    assert states["child"] == TaskState.FAILED
    assert states["grandchild"] == TaskState.FAILED
    errs = [t.error for t in rt.graph.tasks.values()
            if t.defn.name == "grandchild"]
    assert "cancelled" in str(errs[0])


def test_failure_cancels_only_descendants():
    cluster = small_cluster()
    rt = IORuntime(cluster, backend=RealBackend(poll_interval=0.005))
    with pytest.raises(RuntimeError):
        with rt:
            @task(returns=1)
            def boom():
                # fail after the independent chain has finished, so the
                # aborting barrier leaves only descendant bookkeeping behind
                time.sleep(0.3)
                raise ValueError("kaput")

            @task(returns=1)
            def fine():
                return 41

            @task()
            def dep(x):
                pass
            dep(boom())
            ok = fine()
            dep(ok)
            rt.barrier(final=True)
    assert rt.graph.unfinished == 0
    by_tid = sorted(rt.graph.tasks.values(), key=lambda t: t.tid)
    assert by_tid[0].state == TaskState.FAILED      # boom
    assert by_tid[1].state == TaskState.FAILED      # dep(boom)
    assert by_tid[2].state == TaskState.DONE        # fine
    assert by_tid[3].state == TaskState.DONE        # dep(fine)


def test_failure_does_not_cancel_anti_dependents():
    """A write-after-read edge is ordering-only: when the reader is cancelled
    (its data ancestor failed), the next writer of the handle must still run
    — it never consumed the failed task's output."""
    from repro.core import DataHandle, INOUT
    cluster = small_cluster()
    rt = IORuntime(cluster, backend=RealBackend(poll_interval=0.005))
    with pytest.raises(RuntimeError):
        with rt:
            @task(returns=1)
            def boom():
                time.sleep(0.2)
                raise ValueError("kaput")

            @task()
            def read(value, x):
                pass

            @task(value=INOUT)
            def write(value):
                pass
            h = DataHandle(0)
            f = boom()
            read(h, f)       # true descendant of boom
            write(h)         # only a WAR edge on the reader: independent
            rt.barrier(final=True)
    states = {t.defn.name: t.state for t in rt.graph.tasks.values()}
    assert states["boom"] == TaskState.FAILED
    assert states["read"] == TaskState.FAILED
    assert states["write"] == TaskState.DONE, \
        "anti-dependent writer must not be cancelled"
    assert rt.graph.unfinished == 0


# ------------------------- satellite: end_of_stream / assert_not_stuck edges
def test_partially_filled_epoch_concludes_at_final_barrier():
    """Fewer auto tasks than the first epoch's target_k: the final barrier's
    end_of_stream must close admission, register the partial measurement and
    finish the phase — no task may hang waiting for arrivals."""
    cluster = small_cluster(n_workers=2, io_executors=32, device_bw=128)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck(i):
            pass
        # first epoch: c = 128/32 = 4, target_k = 32 -> 3 tasks can't fill it
        for i in range(3):
            ck(i, io_mb=10)
        rt.barrier(final=True)
        tuner = rt.scheduler.tuners["ck"]
    assert not tuner.learning()
    assert len(rt.scheduler.completed) == 3
    assert tuner.registry, "the partial epoch must still register"
    assert all(t.epoch is not None for t in rt.scheduler.completed)
    # the learning node was released at conclusion
    assert all(w.learning_owner is None for w in cluster.workers)


def test_auto_waits_while_all_nodes_learn_other_signatures():
    """Every node is an active-learning node for some other signature and a
    third auto signature has ready tasks: nothing is running, so the drain
    loop goes through assert_not_stuck's legitimate-transient path —
    end_of_stream concludes the stalled epochs, frees their nodes, and the
    waiting signature must then run to completion (no SchedulerError)."""
    cluster = small_cluster(n_workers=2, io_executors=16, device_bw=64)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def sig_a(i):
            pass

        @constraint(storageBW="auto")
        @io
        @task()
        def sig_b(i):
            pass

        @constraint(storageBW="auto")
        @io
        @task()
        def sig_c(i):
            pass
        # one task each for a and b: each acquires one of the two nodes and
        # leaves its first epoch waiting for more arrivals forever
        sig_a(0, io_mb=8)
        sig_b(0, io_mb=8)
        # c's backlog can only run after a node frees up
        for i in range(4):
            sig_c(i, io_mb=8)
        rt.barrier(final=True)
        done = {t.defn.name for t in rt.scheduler.completed}
        counts = {}
        for t in rt.scheduler.completed:
            counts[t.defn.name] = counts.get(t.defn.name, 0) + 1
    assert done == {"sig_a", "sig_b", "sig_c"}
    assert counts["sig_c"] == 4
    assert all(w.learning_owner is None for w in cluster.workers)
    assert rt.graph.unfinished == 0


def test_static_io_blocked_by_learning_node_resolves_not_raises():
    """A static I/O task whose only possible node is busy learning: once the
    epoch task completes and nothing is running, the drain loop hits
    assert_not_stuck's legitimate transient — it must resolve it (conclude
    the epoch, free the node, place the static task), not raise."""
    cluster = small_cluster(n_workers=1, io_executors=8, device_bw=64)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck(i):
            pass
        ck(0, io_mb=4)

        @io
        @task()
        def plain(i):
            pass
        plain(0, io_mb=4)  # blocked: the only node is a learning node
        sched = rt.scheduler
        sched.schedule_pass()
        assert sched.n_ready == 1 and not any(
            t for t in sched.ready if t.defn.name == "ck")
        rt.barrier(final=True)
    assert len(rt.scheduler.completed) == 2


# ------------------------------------------------ satellite: reserved kwargs
def test_reserved_kwarg_rejected_at_decoration_time():
    with pytest.raises(TypeError, match="reserved parameter"):
        @task()
        def bad(x, duration):
            pass
    with pytest.raises(TypeError, match="io_mb"):
        @io
        @task()
        def bad_io(io_mb):
            pass
    with pytest.raises(TypeError, match="reserved"):
        @task()
        def bad_bw(storage_bw=None):
            pass


def test_reserved_kwargs_still_feed_the_sim():
    cluster = small_cluster()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task()
        def dump(x):
            pass
        dump(1, io_mb=40, duration=2)
        rt.barrier(final=True)
        done = rt.scheduler.completed
    assert done[0].sim.io_bytes == 40.0 and done[0].sim.duration == 2.0
