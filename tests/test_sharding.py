"""Divisibility-aware sharding rules (hypothesis properties)."""
import os

import jax
import pytest
from hypothesis_support import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import LOGICAL_RULES, spec_for


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: pure shape logic, no devices needed — lets these
    # properties exercise the production 16x16 shape on a 1-CPU box.
    # jax <= 0.4.x takes ((name, size), ...); newer takes (sizes, names).
    try:
        return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))


def test_divisible_dims_shard(mesh):
    n = mesh.shape["data"]
    spec = spec_for((4 * n, 128), ("embed", "mlp"), mesh, LOGICAL_RULES)
    assert spec[0] == "data"


def test_indivisible_dims_replicate(mesh):
    n = mesh.shape["data"]
    spec = spec_for((4 * n + 1, 7), ("embed", "mlp"), mesh, LOGICAL_RULES)
    assert spec == P() or all(s is None for s in spec)


def test_axis_never_reused(mesh):
    spec = spec_for((16, 16), ("embed", "embed"), mesh, LOGICAL_RULES)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used)) <= 1


def test_spec_valid_deterministic(mesh):
    """Pure-pytest fallback for the validity property: fixed shapes covering
    divisible, indivisible, duplicate-name and unnamed dims."""
    cases = [
        (("embed", "mlp"), (64, 32)),
        (("embed", "mlp"), (7, 5)),
        (("embed", "embed"), (16, 16)),
        ((None, "vocab"), (3, 48)),
        ((), ()),
    ]
    for names, shape in cases:
        spec = spec_for(shape, names, mesh, LOGICAL_RULES)
        used = []
        for dim, part in zip(shape, tuple(spec) + (None,) * len(shape)):
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            size = 1
            for a in parts:
                assert a not in used
                used.append(a)
                size *= mesh.shape[a]
            assert dim % size == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from([None, "embed", "mlp", "heads", "vocab", "batch", "layers"]),
    st.integers(1, 64)), min_size=0, max_size=4))
def test_spec_always_valid(mesh, dims):
    names = tuple(n for n, _ in dims)
    shape = tuple(s for _, s in dims)
    spec = spec_for(shape, names, mesh, LOGICAL_RULES)
    # a valid spec: no axis reuse, and every sharded dim divisible
    used = []
    for dim, part in zip(shape, tuple(spec) + (None,) * len(shape)):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in parts:
            assert a not in used
            used.append(a)
            size *= mesh.shape[a]
        assert dim % size == 0
