"""Sharded scheduler control plane (core.shardplane): routing algebra,
bus ordering, lease-broker quota accounting, and the headline properties —
shard-count invariance of the merged launch log and per-device occupancy
timelines on the symmetric lockstep workload, facade-at-one-shard bit
parity with the plain scheduler, and leases never over-committing at any
instant of a contended shared-tier run.
"""
import itertools

import pytest

from benchmarks.sched_scale import run_symmetric, run_workload
from repro.core import Cluster, IORuntime, constraint, io, task
from repro.core.resources import StorageDevice
from repro.core.scheduler import Scheduler
from repro.core.shardplane import (MESSAGE_KINDS, MSG_DEP_DONE,
                                   MSG_RESIDENCY_ADD, LeaseBroker, ShardBus,
                                   ShardedScheduler, anchor_worker,
                                   partition_cluster, shard_of_worker,
                                   shard_workers, shared_devices)
from repro.core.task import TaskInstance


def _reset_ids():
    TaskInstance._ids = itertools.count()


# --------------------------------------------------------------------------
# routing algebra
# --------------------------------------------------------------------------
def test_shard_of_worker_partitions_contiguously():
    for n_workers in (1, 3, 4, 7, 12):
        for n_shards in range(1, n_workers + 1):
            owners = [shard_of_worker(w, n_workers, n_shards)
                      for w in range(n_workers)]
            # contiguous, non-decreasing, covers every shard
            assert owners == sorted(owners)
            assert set(owners) == set(range(n_shards))
            # fair: block sizes differ by at most one
            sizes = [owners.count(s) for s in range(n_shards)]
            assert max(sizes) - min(sizes) <= 1
            # shard_workers is the exact inverse
            for s in range(n_shards):
                for w in shard_workers(s, n_workers, n_shards):
                    assert shard_of_worker(w, n_workers, n_shards) == s


def test_anchor_worker_is_shard_count_independent():
    n_workers = 8
    for key in range(32):
        w = anchor_worker(key, n_workers)
        assert 0 <= w < n_workers
        # two tasks sharing a key land on the same worker, hence the same
        # shard under EVERY shard count — the co-location guarantee
        for n_shards in (1, 2, 4, 8):
            assert (shard_of_worker(w, n_workers, n_shards)
                    == shard_of_worker(anchor_worker(key, n_workers),
                                       n_workers, n_shards))


def test_partition_cluster_views_share_worker_objects():
    cluster = Cluster.make(n_workers=4, cpus=8, io_executors=32)
    subs = partition_cluster(cluster, 2)
    assert [len(s.workers) for s in subs] == [2, 2]
    flat = [w for s in subs for w in s.workers]
    assert all(a is b for a, b in zip(flat, cluster.workers))
    assert all(s.shared_workdir == cluster.shared_workdir for s in subs)
    with pytest.raises(ValueError):
        partition_cluster(cluster, 0)
    with pytest.raises(ValueError):
        partition_cluster(cluster, 5)


def test_shared_devices_are_the_cross_shard_tiers():
    tiered = Cluster.make_tiered(n_workers=4)
    shared = shared_devices(tiered, 2)
    assert sorted(d.name for d in shared) == ["burst-buffer", "shared-fs"]
    # per-worker devices never qualify, at any shard count
    flat = Cluster.make(n_workers=4, cpus=8, io_executors=32)
    assert shared_devices(flat, 2) == []
    assert shared_devices(flat, 4) == []


# --------------------------------------------------------------------------
# bus: ordered delivery, counters, reentrancy
# --------------------------------------------------------------------------
def test_bus_delivers_in_sequence_order_with_counters():
    got = []
    bus = ShardBus(2, deliver=lambda m: got.append(m))
    s0 = bus.post(MSG_DEP_DONE, 0, 0, "a")          # local
    s1 = bus.post(MSG_RESIDENCY_ADD, 0, None, "b")  # broadcast, counted only
    s2 = bus.post(MSG_DEP_DONE, 0, 1, "c")          # cross
    assert (s0, s1, s2) == (0, 1, 2)
    assert bus.drain() == 3
    # only readiness kinds reach the deliver callback, in seq order
    assert [m[0] for m in got] == [0, 2]
    assert [m[4] for m in got] == ["a", "c"]
    s = bus.summary()
    assert s["kinds"][MSG_DEP_DONE] == 2
    assert s["kinds"][MSG_RESIDENCY_ADD] == 1
    assert s["local"] == 1 and s["cross"] == 2
    assert s["delivered"] == 3 and s["pending"] == 0
    assert set(s["kinds"]) == set(MESSAGE_KINDS)


def test_bus_drain_is_reentrancy_safe():
    got = []
    bus = ShardBus(2)

    def deliver(msg):
        got.append(msg[4])
        if msg[4] == "first":
            bus.post(MSG_DEP_DONE, 0, 1, "chained")

    bus._deliver = deliver
    bus.post(MSG_DEP_DONE, 0, 0, "first")
    assert bus.drain() == 2   # the chained message drains in the same call
    assert got == ["first", "chained"]


# --------------------------------------------------------------------------
# lease broker: quota accounts, rebalance, underflow
# --------------------------------------------------------------------------
def _dev(bw=100.0):
    return StorageDevice(name="bb", bandwidth=bw, per_stream_cap=bw,
                         congestion_alpha=0.0, tier="bb")


def test_lease_split_is_budget_exact_and_rebalances_in_shard_order():
    dev = _dev(100.0)
    broker = LeaseBroker([dev], 3)
    accounts = broker._accounts[id(dev)][1]
    assert sum(a.granted for a in accounts) == dev.bandwidth  # bit-exact
    assert broker.acquire(0, dev, 30.0)          # within own lease
    assert broker.rebalances == 0
    assert broker.acquire(0, dev, 50.0)          # needs a rebalance pull
    assert broker.rebalances >= 1
    assert broker.check_invariants() == []
    # shard order: the pull came from shard 1 first
    assert accounts[1].granted < accounts[2].granted
    # device fully committed elsewhere -> a real denial, counted
    assert broker.acquire(1, dev, 100.0) is False
    assert broker.denials == 1
    broker.release(0, dev, 80.0)
    assert broker.acquire(1, dev, 80.0)
    assert broker.check_invariants() == []


def test_lease_untracked_and_underflow():
    dev, other = _dev(), _dev()
    broker = LeaseBroker([dev], 2)
    assert broker.acquire(0, other, 1e9)     # untracked: trivially granted
    assert broker.acquire(0, dev, 0.0)       # zero-bw: trivially granted
    assert broker.grants == 0                # neither counts as a grant
    with pytest.raises(RuntimeError, match="underflow"):
        broker.release(0, dev, 5.0)


def test_lease_check_invariants_reports_violations():
    dev = _dev(100.0)
    broker = LeaseBroker([dev], 2)
    broker._accounts[id(dev)][1][0].used = 75.0     # over-commit by hand
    out = broker.check_invariants()
    assert any("over-committed" in v for v in out)


# --------------------------------------------------------------------------
# facade at one shard == plain scheduler, bit for bit
# --------------------------------------------------------------------------
def test_facade_single_shard_bit_identical_to_plain():
    log_plain, stats_plain, _ = run_workload(600)
    log_facade, stats_facade, _ = run_workload(
        600, scheduler_cls=lambda c, launch: ShardedScheduler(c, launch, 1))
    assert log_facade == log_plain
    assert stats_facade["makespan"] == stats_plain["makespan"]


# --------------------------------------------------------------------------
# routing on a live runtime: anchors, inheritance, round-robin
# --------------------------------------------------------------------------
def test_route_anchor_inheritance_round_robin():
    _reset_ids()
    cluster = Cluster.make(n_workers=4, cpus=8, io_executors=32)

    @task(returns=1)
    def stage(x):
        pass

    with IORuntime(cluster, shards=2) as rt:
        # round-robin over WORKERS 0..3 -> shards 0,0,1,1
        frees = [stage(i, duration=0.1) for i in range(4)]
        assert [f.task.shard for f in frees] == [0, 0, 1, 1]
        # a consumer inherits its first Future input's producer shard
        child = stage(frees[2], duration=0.1)
        assert child.task.shard == frees[2].task.shard == 1
        # an explicit shard_key beats inheritance; anchor = key % n_workers
        pinned = stage(frees[0], duration=0.1, shard_key=3)
        assert pinned.task.shard == shard_of_worker(3, 4, 2) == 1
        rt.barrier(final=True)
        # confinement: every launch happened on the owning shard's workers
        names = [[w.name for w in s.cluster.workers]
                 for s in rt.scheduler.shards]
        for t in rt.scheduler.completed:
            assert t.worker.name in names[t.shard]


def test_runtime_rejects_more_shards_than_workers():
    cluster = Cluster.make(n_workers=2, cpus=8, io_executors=32)
    with pytest.raises(ValueError, match="n_shards"):
        IORuntime(cluster, shards=3)


# --------------------------------------------------------------------------
# headline property: shard-count invariance on the symmetric workload
# --------------------------------------------------------------------------
def _symmetric_occupancy(shards):
    """run_symmetric's workload, returning (launch_log, occupancy, stats)
    where occupancy is the full per-device timeline: one (tid, start, end,
    worker, device, granted_bw) tuple per completed task."""
    _reset_ids()
    cluster = Cluster.make(n_workers=4, cpus=8, io_executors=32)
    cluster.shared_workdir = False

    @constraint(computingUnits=8)
    @task(returns=1)
    def stage(x, i):
        pass

    @constraint(storageBW=8)
    @io
    @task()
    def ck(x, i):
        pass

    with IORuntime(cluster, shards=shards) as rt:
        futs = [0] * 8
        for _ in range(3):
            for i in range(8):
                futs[i] = stage(futs[i], i, duration=1.0, shard_key=i)
                ck(futs[i], i, io_mb=40.0, shard_key=i)
        rt.barrier(final=True)
        occ = sorted(
            (t.tid, t.start_time, t.end_time, t.worker.name,
             t.device.name if t.device is not None else None, t.granted_bw)
            for t in rt.scheduler.completed)
        return list(rt.scheduler.launch_log), occ, rt.stats()


def test_shard_count_invariance_log_and_occupancy():
    log1, occ1, stats1 = _symmetric_occupancy(1)
    for n in (2, 4):
        logn, occn, statsn = _symmetric_occupancy(n)
        assert logn == log1, f"launch log diverged at shards={n}"
        assert occn == occ1, f"occupancy timeline diverged at shards={n}"
        assert statsn["makespan"] == stats1["makespan"]
        assert statsn["shards"]["lease_violations"] == []


def test_sharded_run_is_deterministic_across_repeats():
    log_a, stats_a, _ = run_symmetric(8, 3, shards=4)
    log_b, stats_b, _ = run_symmetric(8, 3, shards=4)
    assert log_a == log_b
    assert stats_a["makespan"] == stats_b["makespan"]


# --------------------------------------------------------------------------
# properties of a contended shared-tier run: leases, bus, edge counts
# --------------------------------------------------------------------------
def test_leases_never_overcommit_at_any_instant():
    _reset_ids()
    cluster = Cluster.make_tiered(n_workers=4)

    @constraint(tier="bb", storageBW=300)
    @io
    @task()
    def burst(i):
        pass

    with IORuntime(cluster, shards=2) as rt:
        broker = rt.scheduler.broker
        violations = []
        orig_acquire, orig_release = broker.acquire, broker.release

        def acquire(shard, dev, bw):
            ok = orig_acquire(shard, dev, bw)
            violations.extend(broker.check_invariants())
            return ok

        def release(shard, dev, bw):
            orig_release(shard, dev, bw)
            violations.extend(broker.check_invariants())

        broker.acquire, broker.release = acquire, release
        # 6 x 300 MB/s against a 1600 MB/s burst buffer, all anchored to
        # shard 0 whose lease is only half the budget: forces rebalancing
        # and device-level queueing in the same run
        for i in range(6):
            burst(i, io_mb=300.0, shard_key=0)
        rt.barrier(final=True)
        assert violations == []
        assert broker.grants >= 6
        assert broker.rebalances >= 1
        # leases change accounting, never placement: nothing was denied
        assert broker.denials == 0
        stats = rt.stats()
        assert stats["shards"]["lease_violations"] == []
        # steady state: everything released back
        per_shard = stats["shards"]["leases"]["devices"]["burst-buffer"]
        assert all(a["used"] == 0 for a in per_shard["per_shard"])


def test_cross_shard_edges_travel_as_bus_messages():
    _reset_ids()
    cluster = Cluster.make(n_workers=4, cpus=8, io_executors=32)

    @task(returns=1)
    def stage(x):
        pass

    with IORuntime(cluster, shards=2) as rt:
        # a chain that ping-pongs between anchor workers 0 (shard 0) and
        # 2 (shard 1): every edge is a cross-shard DEP_DONE
        fut = stage(0, duration=0.1, shard_key=0)
        for hop in range(1, 6):
            fut = stage(fut, duration=0.1, shard_key=(hop % 2) * 2)
        rt.barrier(final=True)
        stats = rt.stats()
    shards = stats["shards"]
    assert shards["n_shards"] == 2
    assert shards["cross_shard_edges"] == 5
    assert shards["local_edges"] == 0
    assert shards["bus"]["kinds"]["DEP_DONE"] >= 6
    assert shards["bus"]["cross"] >= 5
    assert shards["bus"]["pending"] == 0
    assert sum(p["n_launched"] for p in shards["per_shard"]) == 6


def test_residency_updates_broadcast_on_the_bus():
    _reset_ids()
    cluster = Cluster.make_tiered(n_workers=4, ssd_capacity_gb=1.0)

    @constraint(tier="bb", storageBW=100)
    @io
    @task(returns=1)
    def put(i):
        pass

    with IORuntime(cluster, shards=2) as rt:
        put(0, io_mb=64.0, shard_key=0)
        put(1, io_mb=64.0, shard_key=2)
        rt.barrier(final=True)
        kinds = rt.scheduler.bus.summary()["kinds"]
    assert kinds["RESIDENCY_ADD"] >= 2
