from hypothesis_support import given, st

from repro.core import (StorageDevice, aggregate_throughput,
                        max_concurrent_tasks, per_task_rate)


def dev():
    return StorageDevice(name="d")


def test_paper_calibration():
    d = dev()
    # DESIGN.md §4: the MareNostrum-4 numbers pin these down
    assert max_concurrent_tasks(d.bandwidth, 2) == 225
    assert max_concurrent_tasks(d.bandwidth, 8) == 56
    assert max_concurrent_tasks(d.bandwidth, 256) == 1
    assert aggregate_throughput(d, 56) == 448.0  # peak at the knee


@given(st.integers(1, 1000))
def test_aggregate_never_exceeds_device(k):
    assert aggregate_throughput(dev(), k) <= dev().bandwidth + 1e-9


@given(st.integers(1, 1000))
def test_per_task_rate_capped_by_stream(k):
    assert per_task_rate(dev(), k) <= dev().per_stream_cap + 1e-9


@given(st.integers(1, 56))
def test_linear_ramp_below_knee(k):
    d = dev()
    assert aggregate_throughput(d, k) == k * d.per_stream_cap


@given(st.integers(57, 2000))
def test_congestion_decreasing_past_knee(k):
    d = dev()
    assert aggregate_throughput(d, k + 1) < aggregate_throughput(d, k)


def test_allocation_accounting():
    d = dev()
    d.allocate(400)
    assert not d.can_allocate(100)
    d.release(400)
    assert d.can_allocate(450)


def test_model_shape_deterministic():
    """Pure-pytest fallback for the model properties: cap, ramp, congestion
    checked exhaustively over a representative range."""
    d = dev()
    prev = None
    for k in range(1, 300):
        agg = aggregate_throughput(d, k)
        assert agg <= d.bandwidth + 1e-9
        assert per_task_rate(d, k) <= d.per_stream_cap + 1e-9
        if k <= d.congestion_knee:
            assert agg == k * d.per_stream_cap
        elif k > d.congestion_knee + 1:
            assert agg < prev  # strictly degrading past the knee
        prev = agg


def test_rate_epoch_tracks_population():
    d = dev()
    e0 = d.rate_epoch
    d.allocate(8)
    assert d.rate_epoch == e0 + 1
    d.release(8)
    assert d.rate_epoch == e0 + 2
