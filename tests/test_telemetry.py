"""Measured-telemetry tests (ISSUE 9): the RealBackend's TelemetryHub,
the ``rt.stats()["telemetry"]`` gating, frozen-schema ``telemetry``
events, the scheduler's measured-duration feedback (bugfix: declared
``task.duration`` used to poison the tuner/drift signal on real runs),
the tier-fit calibration, the ``repro.compare`` CLI and the bench
trajectory regression checker."""
import itertools
import json
import os
import subprocess
import sys
import time

import pytest

from repro.core import (Cluster, DriftConfig, IORuntime, RealBackend,
                        SimBackend, StorageDevice, WorkerNode, constraint,
                        io, task)
from repro.core.datalife import DataObject
from repro.core.scheduler import Scheduler
from repro.core.task import TaskDef, TaskInstance, TaskType
from repro.obs import EVENT_SCHEMA, MetricsTimeline, perfetto
from repro.obs.telemetry import (TelemetryHub, apply_tier_config,
                                 fit_samples, fit_tiers)

from benchmarks._report import append_history, check_regress, read_history


def _fresh_ids():
    TaskInstance._ids = itertools.count()
    DataObject._ids = itertools.count()


def _two_tier_cluster(io_executors=8):
    ssd = StorageDevice(name="ssd0", tier="ssd")
    fs = StorageDevice(name="fs0", bandwidth=300, per_stream_cap=30,
                       tier="fs")
    return Cluster(workers=[WorkerNode(name="w0", cpus=2,
                                       io_executors=io_executors,
                                       tiers=[ssd, fs])])


@io
@task(returns=1)
def _put(dirpath, name, mb):
    """Real ~mb MB write (+fsync) when dirpath is set; pure model in sim."""
    if not dirpath:
        return name
    path = os.path.join(dirpath, name)
    with open(path, "wb") as f:
        f.write(b"\0" * int(mb * (1 << 20)))
        f.flush()
        os.fsync(f.fileno())
    return name


def _real_run(tmp_path, trace=True, n=4):
    _fresh_ids()
    cluster = _two_tier_cluster()
    tier_dirs = {}
    for tier in cluster.tier_names():
        d = tmp_path / tier
        d.mkdir(exist_ok=True)
        tier_dirs[tier] = str(d)
    rt = IORuntime(cluster, backend=RealBackend(tier_dirs=tier_dirs),
                   trace=trace)
    with rt:
        for i in range(n):
            tier = "ssd" if i % 2 == 0 else "fs"
            _put(tier_dirs[tier], f"f{i}.bin", 0.5,
                 io_mb=0.5, storage_tier=tier)
        rt.barrier(final=True)
    return rt


def _sim_run(trace=True, n=4):
    _fresh_ids()
    rt = IORuntime(_two_tier_cluster(), backend=SimBackend(), trace=trace)
    with rt:
        for i in range(n):
            _put("", f"f{i}.bin", 0.5, io_mb=0.5,
                 storage_tier="ssd" if i % 2 == 0 else "fs")
        rt.barrier(final=True)
    return rt


# ------------------------------------------------ stats gating + contents
def test_stats_telemetry_present_iff_real_and_traced(tmp_path):
    stats = _real_run(tmp_path, trace=True).stats()
    assert "telemetry" in stats
    tel = stats["telemetry"]
    assert tel["window_s"] > 0
    assert set(tel["devices"]) == {"ssd0", "fs0"}
    for name, d in tel["devices"].items():
        assert d["n_ops"] >= 1, name
        assert d["n_samples"] >= 1, name
        assert d["inflight"] == 0, name
        assert d["mbps"] > 0 and d["stream_mbps"] > 0, name
        assert d["total_mb"] == pytest.approx(0.5 * d["n_ops"])
    assert tel["devices"]["ssd0"]["tier"] == "ssd"
    assert tel["devices"]["fs0"]["tier"] == "fs"
    # untraced real run: hub still measures, but stats stay schema-frozen
    assert "telemetry" not in _real_run(tmp_path, trace=False).stats()
    # traced sim run: the simulator has no hub — models, not measurements
    assert "telemetry" not in _sim_run(trace=True).stats()


def test_measured_duration_real_only(tmp_path):
    real = _real_run(tmp_path, trace=False)
    done = [t for t in real.scheduler.completed if t.is_io]
    assert done
    for t in done:
        assert t.measured_duration is not None and t.measured_duration > 0
        # measured covers the successful attempt only; end-to-end duration
        # also counts pool queueing and argument resolution
        assert t.measured_duration <= t.duration + 0.25
    sim = _sim_run(trace=False)
    assert all(t.measured_duration is None for t in sim.scheduler.completed)


# ------------------------------------------------------- event stream shape
def test_real_telemetry_events_match_frozen_schema(tmp_path):
    rec = _real_run(tmp_path, trace=True).recorder
    tel = [ev for ev in rec.events if ev["type"] == "telemetry"]
    assert len(tel) == 4, "one telemetry event per successful I/O op"
    for ev in rec.events:
        et = ev["type"]
        assert et in EVENT_SCHEMA, f"unknown event type {et!r}"
        fields = EVENT_SCHEMA[et]
        for f, types in fields.items():
            assert f in ev, f"{et} event missing field {f!r}: {ev}"
            assert isinstance(ev[f], types), \
                f"{et}.{f} is {type(ev[f]).__name__}: {ev}"
        extra = set(ev) - set(fields) - {"type"}
        assert not extra, f"{et} event has undeclared fields {extra}"
    for ev in tel:
        assert ev["mb"] == pytest.approx(0.5)
        assert ev["wall_s"] > 0 and ev["mbps"] > 0


def test_timeline_and_perfetto_carry_measured_series(tmp_path):
    rec = _real_run(tmp_path, trace=True).recorder
    rows = rec.timeline.telemetry_rows("ssd0")
    assert rows
    for row in rows:
        assert set(row) == set(MetricsTimeline.TELEMETRY_FIELDS)
    evs = json.loads(perfetto.dumps(rec))["traceEvents"]
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert "measured_mbs" in counters
    assert "measured_inflight" in counters


def test_sim_traces_stay_byte_identical_with_telemetry_wiring():
    """The hub is real-backend-only: sim event streams carry no telemetry
    events and stay byte-deterministic run-to-run."""
    rec1 = _sim_run(trace=True).recorder
    rec2 = _sim_run(trace=True).recorder
    assert rec1.to_jsonl() == rec2.to_jsonl()
    assert perfetto.dumps(rec1) == perfetto.dumps(rec2)
    assert not any(ev["type"] == "telemetry" for ev in rec1.events)


# ------------------------------------------- hub accounting + fit pipeline
def test_hub_inflight_failed_and_window():
    hub = TelemetryHub(window_s=5.0)
    dev = StorageDevice(name="d0", tier="ssd")
    assert hub.on_launch(0.0, dev) == 1
    assert hub.on_launch(0.1, dev) == 2
    hub.on_complete(1.0, dev, 10.0, 1.0, launch_inflight=2)
    hub.on_complete(1.5, dev, 0.0, None, failed=True, launch_inflight=2)
    d = hub.summary()["devices"]["d0"]
    assert d["n_ops"] == 1 and d["n_failed"] == 1
    assert d["inflight"] == 0
    assert d["n_samples"] == 1, "failed ops record no throughput sample"
    assert d["mbps"] == pytest.approx(10.0)       # 10 MB over a 1 s span
    assert d["stream_mbps"] == pytest.approx(10.0)


def test_fit_samples_recovers_congestion_curve():
    # k=1 streams at 100 MB/s, k=4 still 100 MB/s each (aggregate 400),
    # k=8 collapses to 40 MB/s each (aggregate 320 < 400: past the knee)
    samples = [(1.0, 50.0, 0.5, 1), (2.0, 50.0, 0.5, 1),
               (3.0, 25.0, 0.25, 4), (3.1, 25.0, 0.25, 4),
               (4.0, 10.0, 0.25, 8), (4.1, 10.0, 0.25, 8)]
    fit = fit_samples(samples)
    assert fit["per_stream_cap"] == pytest.approx(100.0)
    assert fit["bandwidth"] == pytest.approx(400.0)
    assert fit["max_k"] == 8 and fit["n_samples"] == 6
    # knee = 400/100 = 4; over = 8-4 = 4; alpha = (400/320 - 1)/4
    assert fit["congestion_alpha"] == pytest.approx(0.0625)
    assert fit_samples([(1.0, 0.0, 0.5, 1)]) is None, \
        "latency-only ops can't constrain a bandwidth model"


def test_fit_tiers_and_apply_tier_config():
    hub = TelemetryHub()
    dev = StorageDevice(name="d0", tier="ssd")
    for t in (1.0, 2.0, 3.0):
        hub.on_launch(t - 0.5, dev)
        hub.on_complete(t, dev, 50.0, 0.5, launch_inflight=1)
    cfg = fit_tiers(hub)
    assert set(cfg) == {"ssd"}
    assert cfg["ssd"]["per_stream_cap"] == pytest.approx(100.0)
    cluster = _two_tier_cluster()
    n = apply_tier_config(cluster, cfg)
    assert n == 1, "only the ssd tier appears in the fit"
    ssd = next(d for d in cluster.devices if d.tier == "ssd")
    fs = next(d for d in cluster.devices if d.tier == "fs")
    assert ssd.bandwidth == pytest.approx(cfg["ssd"]["bandwidth"])
    assert ssd.per_stream_cap == pytest.approx(100.0)
    assert ssd.available_bw == ssd.bandwidth
    assert ssd.congestion_knee == max(1, int(ssd.bandwidth
                                             / ssd.per_stream_cap))
    assert fs.bandwidth == 300, "unlisted tiers keep their parameters"


# ------------------------------------- scheduler feedback (the bugfix unit)
class _StubTuner:
    def __init__(self):
        self.observed = []
        self.completed = []

    def observe(self, constraint, duration):
        self.observed.append((constraint, duration))

    def on_task_complete(self, duration):
        self.completed.append(duration)

    def learning(self):
        return True  # keep the learning node held: no release bookkeeping


def _io_task(cluster, measured, declared_end, granted_bw=8.0):
    defn = TaskDef(fn=lambda: None, name="w", task_type=TaskType.IO)
    t = TaskInstance(defn, (), {})
    w = cluster.workers[0]
    t.worker = w
    t.device = w.tiers[0]
    t.granted_bw = granted_bw
    t.device.allocate(granted_bw)
    t.start_time = 0.0
    t.end_time = declared_end
    t.measured_duration = measured
    t.tuner_key = "w@ssd"
    return t


def test_on_complete_feeds_measured_wall_time_not_declared_duration():
    """Bugfix: the drift monitor and the epoch tuner must see the measured
    attempt wall time when the backend recorded one — task.duration also
    counts pool queueing and retry backoff."""
    cluster = _two_tier_cluster()
    sched = Scheduler(cluster, launch=lambda t, w: None)
    stub = _StubTuner()
    sched.tuners["w@ssd"] = stub
    sched.drift_config = DriftConfig()
    # drift path: measured 0.25 s wins over the 10 s end-to-end duration
    t1 = _io_task(cluster, measured=0.25, declared_end=10.0)
    sched.on_complete(t1)
    assert stub.observed == [(8.0, 0.25)]
    # epoch path: same preference for the measured signal
    t2 = _io_task(cluster, measured=0.5, declared_end=10.0)
    t2.epoch = object()
    sched.on_complete(t2)
    assert stub.completed == [0.5]
    # sim fallback: no measurement recorded -> the modelled duration feeds
    # through unchanged (bit-identical golden logs depend on this)
    t3 = _io_task(cluster, measured=None, declared_end=10.0)
    sched.on_complete(t3)
    assert stub.observed[-1] == (8.0, 10.0)


@pytest.mark.slow
def test_drift_recalibrates_from_measured_real_durations(tmp_path):
    """End-to-end: an auto-tuned signature learns a fast curve from warm
    tasks, then the real workload slows 10-20x — the measured wall times
    feed AutoTuner.observe and trigger a recalibration."""
    _fresh_ids()

    @constraint(storageBW="auto(100,100,2)")
    @io
    @task(returns=1)
    def probe(dt):
        time.sleep(dt)
        return dt

    ssd = StorageDevice(name="ssd0", bandwidth=200, per_stream_cap=100,
                        tier="ssd")
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2,
                                          io_executors=4, tiers=[ssd])])
    rt = IORuntime(cluster, backend=RealBackend(),
                   drift=DriftConfig(window=4, min_observations=3,
                                     threshold=2.0))
    with rt:
        warm = [probe(0.004, io_mb=0.0) for _ in range(2)]
        rt.wait_on(*warm)          # learning epoch (k=2) concludes here
        for _ in range(6):
            probe(0.08, io_mb=0.0)
        rt.barrier(final=True)
    tuners = list(rt.scheduler.tuners.values())
    assert len(tuners) == 1
    assert tuners[0].n_recalibrations >= 1
    assert tuners[0].summary()["drift_window"]["n_obs"] >= 0


# ---------------------------------------------------------------------- CLI
def test_compare_cli_smoke(tmp_path):
    script = tmp_path / "tiny.py"
    out_dir = tmp_path / "payload"
    out_dir.mkdir()
    script.write_text(
        "import os\n"
        "from repro.core import (Cluster, IORuntime, SimBackend,\n"
        "                        StorageDevice, WorkerNode, io, task)\n"
        "@io\n"
        "@task(returns=1)\n"
        "def put(dirpath, name, mb):\n"
        "    if dirpath:\n"
        "        p = os.path.join(dirpath, name)\n"
        "        with open(p, 'wb') as f:\n"
        "            f.write(b'x' * int(mb * (1 << 20)))\n"
        "            f.flush()\n"
        "            os.fsync(f.fileno())\n"
        "    return name\n"
        "dev = StorageDevice(name='d0', tier='ssd')\n"
        "cluster = Cluster(workers=[WorkerNode(name='w0', cpus=1,\n"
        "                                      io_executors=4,\n"
        "                                      tiers=[dev])])\n"
        f"out = {str(out_dir)!r}\n"
        "with IORuntime(cluster, backend=SimBackend()) as rt:\n"
        "    for i in range(3):\n"
        "        put(out, f'f{i}.bin', 0.25, io_mb=0.25,\n"
        "            storage_tier='ssd')\n"
        "    rt.barrier(final=True)\n")
    fit = tmp_path / "fit.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.compare", str(script),
         "--tier-base", str(tmp_path / "tiers"), "--fit", str(fit),
         "--json"],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(proc.stdout)
    assert len(doc) == 1
    rep = doc[0]["report"]
    assert rep["n_pairs"] == 3
    assert rep["median_abs_rel_error"] is not None
    assert "report_fitted" in doc[0], "--fit must re-run the sim leg"
    assert doc[0]["tier_fit"]["ssd"]["fitted"] is not None
    fitted = json.loads(fit.read_text())
    assert fitted["tiers"]["ssd"]["bandwidth"] > 0


def test_compare_cli_missing_file_exits_2():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.compare", "/no/such/script.py"],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 2


# ------------------------------------------------------ bench trajectory
def test_history_append_read_and_torn_lines(tmp_path):
    hist = tmp_path / "BENCH_history.jsonl"
    append_history(str(hist), bench="b", metric="m", value=1.0)
    append_history(str(hist), bench="b", metric="m", value=2.0,
                   direction="max", seed=7)
    with open(hist, "a") as f:
        f.write('{"torn": ')  # killed writer: unparsable last line
    entries = read_history(str(hist))
    assert [e["value"] for e in entries] == [1.0, 2.0]
    assert entries[1]["direction"] == "max" and entries[1]["seed"] == 7
    with pytest.raises(ValueError):
        append_history(str(hist), bench="b", metric="m", value=0.0,
                       direction="sideways")


def test_check_regress_directions(tmp_path):
    hist = tmp_path / "h.jsonl"
    # min-direction metric: 1.5 vs median(1.0, 1.0) = +50% -> regressed
    for v in (1.0, 1.0, 1.5):
        append_history(str(hist), bench="sched", metric="seconds", value=v)
    # min-direction within tolerance: +10% < 15% -> ok
    for v in (1.0, 1.0, 1.1):
        append_history(str(hist), bench="sched", metric="other", value=v)
    # max-direction metric: 50 vs median(100, 100) = -50% -> regressed
    for v in (100.0, 100.0, 50.0):
        append_history(str(hist), bench="serve", metric="tput", value=v,
                       direction="max")
    # single entry: no trajectory, skipped
    append_history(str(hist), bench="solo", metric="x", value=1.0)
    findings = {(f["bench"], f["metric"]): f
                for f in check_regress(str(hist), threshold=0.15)}
    assert findings[("sched", "seconds")]["regressed"] is True
    assert findings[("sched", "seconds")]["baseline"] == pytest.approx(1.0)
    assert findings[("sched", "other")]["regressed"] is False
    assert findings[("serve", "tput")]["regressed"] is True
    assert ("solo", "x") not in findings


def test_run_check_regress_exit_codes(tmp_path):
    hist = tmp_path / "h.jsonl"
    cmd = [sys.executable, "-m", "benchmarks.run", "--check-regress",
           "--history", str(hist)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stderr  # no trajectory: nothing to do
    for v in (1.0, 1.0, 5.0):
        append_history(str(hist), bench="b", metric="m", value=v)
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 1
    assert "REGRESSED" in proc.stdout
