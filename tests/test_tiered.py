"""Multi-tier storage hierarchy: structure, tier-aware placement, spill,
per-(signature, tier) autotuners, drain/prefetch movement, submission-time
constraint validation (ISSUE 2 tentpole)."""
import pytest

from repro.core import (Cluster, IORuntime, RealBackend, SchedulerError,
                        SimBackend, StorageDevice, WorkerNode, constraint,
                        cross_tier_time, io, read_floor_time, task)


def tiered_cluster(**kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("cpus", 4)
    kw.setdefault("io_executors", 8)
    kw.setdefault("ssd_bw", 200.0)
    kw.setdefault("bb_bw", 400.0)
    kw.setdefault("fs_bw", 100.0)
    return Cluster.make_tiered(**kw)


# ---------------------------------------------------------------- structure
def test_make_tiered_structure():
    c = tiered_cluster(n_workers=3)
    assert c.tier_names() == ["ssd", "bb", "fs"]
    # ssd per worker; bb and fs shared single instances
    assert len(c.devices) == 3 + 2
    bbs = {id(w.tier_device("bb")) for w in c.workers}
    fss = {id(w.tier_device("fs")) for w in c.workers}
    assert len(bbs) == 1 and len(fss) == 1
    # storage stays the fastest-tier alias (seed compatibility)
    for w in c.workers:
        assert w.storage is w.tiers[0] and w.storage.tier == "ssd"
    assert c.has_tier("bb") and not c.has_tier("tape")
    assert c.tier_spec("fs").name == "shared-fs"


def test_single_tier_worker_unchanged():
    w = WorkerNode(name="w", cpus=2, io_executors=4)
    assert w.tiers == [w.storage]
    with pytest.raises(ValueError):
        WorkerNode(name="x", storage=StorageDevice(name="a"),
                   tiers=[StorageDevice(name="b")])


def test_cross_tier_time_helpers():
    src = StorageDevice(name="s", bandwidth=100.0)
    dst = StorageDevice(name="d", bandwidth=50.0, per_stream_cap=10.0)
    assert read_floor_time(src, 200.0) == 2.0
    # write side dominates: one stream at 10 MB/s -> 20s
    assert cross_tier_time(src, dst, 200.0, k=1) == 20.0


# ---------------------------------------------------------------- placement
def test_tier_hint_pins_placement():
    cluster = tiered_cluster()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=20, tier="bb")
        @io
        @task()
        def to_bb(i):
            pass

        @constraint(storageBW=20)
        @io
        @task()
        def anywhere(i):
            pass
        for i in range(4):
            to_bb(i, io_mb=10)
            anywhere(i, io_mb=10)
        rt.barrier(final=True)
        done = rt.scheduler.completed
    assert all(t.device.tier == "bb" for t in done if t.defn.name == "to_bb")
    # tier-agnostic tasks take the fastest tier with budget: the ssd
    assert all(t.device.tier == "ssd" for t in done
               if t.defn.name == "anywhere")


def test_call_time_tier_override_beats_decorator():
    cluster = tiered_cluster()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=20, tier="bb")
        @io
        @task()
        def wr(i):
            pass
        wr(0, io_mb=5)
        wr(1, io_mb=5, storage_tier="fs")
        rt.barrier(final=True)
        tiers = {t.args[0]: t.device.tier for t in rt.scheduler.completed}
    assert tiers[0] == "bb" and tiers[1] == "fs"


def test_saturated_fast_tier_spills_down_hierarchy():
    # ssd budget holds 2 x 100; the rest of the burst must spill to bb
    # (400 -> 4 more) and then fs (100 -> 1) instead of queueing
    cluster = tiered_cluster(n_workers=1, ssd_bw=200.0, bb_bw=400.0,
                             fs_bw=100.0, io_executors=16)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=100)
        @io
        @task()
        def wr(i):
            pass
        for i in range(7):
            wr(i, io_mb=50)
        rt.barrier(final=True)
        done = rt.scheduler.completed
    first_wave = sorted(t.device.tier for t in done
                        if t.start_time == 0.0)
    assert first_wave == ["bb", "bb", "bb", "bb", "fs", "ssd", "ssd"]


def test_per_tier_autotuners():
    cluster = tiered_cluster(n_workers=3)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto")
        @io
        @task()
        def ck(i):
            pass
        for i in range(60):
            ck(i, io_mb=20)                       # default tier (ssd)
            ck(i, io_mb=20, storage_tier="fs")    # fs-pinned
        rt.barrier(final=True)
        tuners = rt.scheduler.tuners
    assert set(tuners) == {"ck", "ck@fs"}
    # each tuner models the device it learned on
    assert tuners["ck"].device_bw == 200.0
    assert tuners["ck@fs"].device_bw == 100.0
    epoch_tiers = {t.device.tier for t in rt.scheduler.completed
                   if t.epoch is not None}
    assert epoch_tiers == {"ssd", "fs"}


# ------------------------------------------- submission-time validation
def test_unknown_tier_raises_at_submission():
    cluster = tiered_cluster()
    with pytest.raises(SchedulerError, match="tape"):
        with IORuntime(cluster, backend=SimBackend()):
            @io
            @task()
            def wr(i):
                pass
            wr(0, io_mb=1, storage_tier="tape")  # raises HERE, not at barrier


def test_unsatisfiable_bw_on_tier_raises_even_if_other_tier_fits():
    cluster = tiered_cluster()  # fs 100 < 150 < bb 400
    with pytest.raises(SchedulerError, match="exceeds every device"):
        with IORuntime(cluster, backend=SimBackend()):
            @constraint(storageBW=150, tier="fs")
            @io
            @task()
            def wr(i):
                pass
            wr(0, io_mb=1)
    # a bw too big for ssd (200) and fs (100) is still satisfiable without
    # a hint: the hierarchy walk grants it on the bb (400)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=250)
        @io
        @task()
        def wr2(i):
            pass
        wr2(0, io_mb=1)
        rt.barrier(final=True)
        assert rt.scheduler.completed[0].device.tier == "bb"


def test_unknown_tier_raises_even_when_not_immediately_ready():
    """Validation happens at submission proper (before the task enters the
    graph), so a doomed class with pending dependencies still raises at the
    call site — never from a completion fan-out — and leaves no
    half-registered state behind."""
    cluster = tiered_cluster()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @task(returns=1)
        def prod():
            pass

        @io
        @task()
        def wr(x):
            pass
        f = prod(duration=0.1)
        with pytest.raises(SchedulerError, match="tape"):
            wr(f, io_mb=1, storage_tier="tape")
        # the same doomed class raises again on retry (not cached as ok)
        with pytest.raises(SchedulerError, match="tape"):
            wr(f, io_mb=1, storage_tier="tape")
        rt.barrier(final=True)
    assert rt.graph.unfinished == 0
    assert len(rt.scheduler.completed) == 1  # only prod ever entered


def test_shared_tier_learning_isolated_across_workers():
    """While a tuner calibrates on a *shared* tier (burst buffer), traffic
    from every worker must stay off that device — node-level isolation alone
    would let w1 pollute the epoch measurements taken on w0."""
    cluster = tiered_cluster(n_workers=3)
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW="auto", tier="bb")
        @io
        @task()
        def ck_bb(i):
            pass

        @constraint(storageBW=10, tier="bb")
        @io
        @task()
        def wr_bb(i):
            pass
        for i in range(40):
            ck_bb(i, io_mb=16)
            wr_bb(i, io_mb=4)
        rt.barrier(final=True)
        done = rt.scheduler.completed
    epochs = [t for t in done if t.epoch is not None]
    assert epochs and all(t.device.tier == "bb" for t in epochs)
    for t in done:
        if t.defn.name != "wr_bb":
            continue
        for e in epochs:  # no static bb write may overlap any epoch task
            assert t.start_time >= e.end_time - 1e-9 or \
                t.end_time <= e.start_time + 1e-9, (t.tid, e.tid)


# ------------------------------------------------------------ data movement
def test_sim_drain_charges_destination_tier():
    cluster = tiered_cluster()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @constraint(storageBW=40)
        @io
        @task(returns=1)
        def wr(i):
            pass
        f = wr(0, io_mb=80)
        rt.drain(f, to_tier="fs", from_tier="ssd", io_mb=80, storage_bw=25)
        rt.prefetch(None, to_tier="bb", from_tier="fs", io_mb=16)
        rt.barrier(final=True)
        st = rt.stats()
    by_tier = {}
    for d in st["devices"].values():
        by_tier[d["tier"]] = by_tier.get(d["tier"], 0.0) + d["bytes_written"]
    assert by_tier["ssd"] == 80.0    # original write
    assert by_tier["fs"] == 80.0     # drained copy
    assert by_tier["bb"] == 16.0     # prefetch staged up
    # the drain waited for its producer (read floor also lower-bounds it)
    drains = [t for t in rt.scheduler.completed
              if t.defn.name == "tier_drain"]
    wrs = [t for t in rt.scheduler.completed if t.defn.name == "wr"]
    assert drains[0].start_time >= wrs[0].end_time - 1e-9


def test_wait_on_cancelled_descendant_returns_instead_of_hanging():
    """sim_fail fault injection: waiting on a future downstream of the
    failure must return (the cancelled task's future resolves to None), not
    hang the drain with an unrelated error."""
    from repro.core import TaskState
    cluster = tiered_cluster()
    with IORuntime(cluster, backend=SimBackend()) as rt:
        @io
        @task(returns=1)
        def wr(i):
            pass

        @task(returns=1)
        def child(x):
            pass
        a = wr(0, io_mb=5, sim_fail=True)
        b = child(a)
        assert rt.wait_on(b) is None
        states = {t.defn.name: t.state for t in rt.graph.tasks.values()}
        assert states == {"wr": TaskState.FAILED, "child": TaskState.FAILED}
        rt.barrier(final=True)
    assert rt.graph.unfinished == 0


def test_move_with_unmapped_tier_dir_raises(tmp_path):
    ssd_dir = tmp_path / "ssd"
    ssd_dir.mkdir()
    (ssd_dir / "f.bin").write_bytes(b"data")
    dev = StorageDevice(name="d", bandwidth=1000, per_stream_cap=500)
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                          storage=dev)])
    backend = RealBackend(tier_dirs={"ssd": ssd_dir})  # no "fs" mapping
    with IORuntime(cluster, backend=backend) as rt:
        with pytest.raises(ValueError, match="fs"):
            rt.drain(None, to_tier="fs", from_tier="ssd", path="f.bin")
        with pytest.raises(ValueError, match="from_tier"):
            rt.drain(None, to_tier="ssd", path="f.bin")


def test_real_backend_drain_moves_file(tmp_path):
    ssd_dir, fs_dir = tmp_path / "ssd", tmp_path / "fs"
    ssd_dir.mkdir(), fs_dir.mkdir()
    payload = b"x" * 4096
    (ssd_dir / "blob.bin").write_bytes(payload)
    dev = StorageDevice(name="d", bandwidth=1000, per_stream_cap=500)
    cluster = Cluster(workers=[WorkerNode(name="w0", cpus=2, io_executors=4,
                                          storage=dev)])
    backend = RealBackend(tier_dirs={"ssd": ssd_dir, "fs": fs_dir})
    with IORuntime(cluster, backend=backend) as rt:
        fut = rt.drain(None, to_tier="fs", from_tier="ssd",
                       io_mb=len(payload) / 1e6, path="blob.bin")
        out = rt.wait_on(fut)
    assert out == str(fs_dir / "blob.bin")
    assert (fs_dir / "blob.bin").read_bytes() == payload
