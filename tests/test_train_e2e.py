"""End-to-end integration: train -> checkpoint -> kill -> resume."""
import pytest
import json

import jax.numpy as jnp

from repro.launch.train import PRESETS, train


pytestmark = pytest.mark.slow  # jax model / e2e tier (CI runs -m "not slow")


def test_train_loss_improves(tmp_path):
    out = train(PRESETS["5m"], steps=16, batch=2, seq=32, ckpt_dir=None,
                ckpt_every=0, io_aware=True)
    assert out["steps_run"] == 16
    # every step's loss is measured on a different (noisy) batch of 2, so the
    # endpoints alone are dominated by batch variance: compare window means
    ls = out["losses"]
    assert sum(ls[-3:]) / 3 < sum(ls[:3]) / 3


def test_resume_continues_from_checkpoint(tmp_path):
    ck = tmp_path / "ck"
    out1 = train(PRESETS["5m"], steps=6, batch=2, seq=32, ckpt_dir=str(ck),
                 ckpt_every=3, io_aware=True)
    out2 = train(PRESETS["5m"], steps=10, batch=2, seq=32, ckpt_dir=str(ck),
                 ckpt_every=3, io_aware=True, resume=True)
    # resumed from step 5 -> only 4 more steps run
    assert out2["steps_run"] == 4
    # deterministic data + restored state: the continued run must match a
    # straight 10-step run's tail losses closely
    full = train(PRESETS["5m"], steps=10, batch=2, seq=32, ckpt_dir=None,
                 ckpt_every=0, io_aware=True)
    for a, b in zip(out2["losses"], full["losses"][6:]):
        assert abs(a - b) < 0.05, (out2["losses"], full["losses"][6:])


def test_baseline_mode_syncs(tmp_path):
    ck = tmp_path / "ck"
    out = train(PRESETS["5m"], steps=4, batch=2, seq=32, ckpt_dir=str(ck),
                ckpt_every=2, io_aware=False)
    assert out["steps_run"] == 4
    assert (ck / "step_00000003").exists()
